"""Paper Fig. 5: achievable sparsity per pruning technique.

Quick mode (default, used by ``benchmarks.run``): the full Algorithm 1
loop (train → prune → eval-gate → rewind) on a reduced CNN with
synthetic CIFAR-like data — validates the ORDERING (LTP ≥ ReaLPrune >
Block ≈ CAP) and the no-accuracy-drop gate in minutes on CPU.  The
paper-scale run lives in ``examples/prune_cnn_lottery.py``.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_FIG5_REMAINING, Timer, csv_line
from repro.configs import CNNConfig, ConvSpec, PruneConfig
from repro.core import algorithm as alg
from repro.core.masks import apply_masks, cnn_prunable
from repro.data import SyntheticImages
from repro.models import cnn as cnn_lib
from repro.optim import exponential_epoch_decay, masked, sgd

# calibration: overparameterised enough for the synthetic task that
# moderate coarse-granularity prunes pass the accuracy gate (matches
# examples/quickstart.py, which exercises keep+undo+switch visibly)
CFG = CNNConfig(
    name="mini-vgg", family="cnn",
    convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True),
           ConvSpec(64), ConvSpec(64)),
    fc=(), num_classes=10, image_size=16)
DATA = SyntheticImages(image_size=16, noise=0.25, seed=0)
STEPS = 80


def _train_eval(rng):
    params0, bn0 = cnn_lib.init_params(rng, CFG)
    holder = {"bn": bn0}

    def train_fn(params, masks):
        opt = masked(sgd(exponential_epoch_decay(0.05, 0.95, 40)), masks)
        opt_state = opt.init(params)
        state = bn0
        params = apply_masks(params, masks)

        @jax.jit
        def step(params, opt_state, state, batch):
            def lf(p):
                loss, (nst, _) = cnn_lib.loss_fn(p, state, CFG, batch,
                                                 train=True)
                return loss, nst
            (loss, nst), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, nst, loss

        for i in range(STEPS):
            b = DATA.batch(i, 64)
            params, opt_state, state, _ = step(
                params, opt_state, state,
                {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])})
        holder["bn"] = state
        return params

    def eval_fn(params, masks):
        accs = []
        for i in range(3):
            b = DATA.batch(10_000 + i, 128)
            accs.append(float(cnn_lib.accuracy(
                params, holder["bn"], CFG, jnp.asarray(b["images"]),
                jnp.asarray(b["labels"]))))
        return float(np.mean(accs))

    return params0, train_fn, eval_fn


def run(quick: bool = True) -> Dict[str, float]:
    rng = jax.random.PRNGKey(0)
    pc = PruneConfig(prune_fraction=0.15, max_iters=12,
                     accuracy_tolerance=0.02)
    results = {}
    lines = []
    for method in ("realprune", "ltp", "block", "cap"):
        params0, train_fn, eval_fn = _train_eval(rng)
        with Timer() as t:
            if method == "realprune":
                res = alg.realprune(
                    init_params=params0, train_fn=train_fn, eval_fn=eval_fn,
                    prunable=cnn_prunable,
                    conv_pred=lambda p: "convs" in p or "shortcuts" in p,
                    cfg=pc)
            else:
                res = alg.lottery_baseline(
                    init_params=params0, train_fn=train_fn, eval_fn=eval_fn,
                    prunable=cnn_prunable,
                    conv_pred=lambda p: "convs" in p or "shortcuts" in p,
                    cfg=pc, method=method)
        results[method] = res.sparsity
        paper = 1.0 - PAPER_FIG5_REMAINING[method]
        lines.append(csv_line(
            f"fig5_sparsity_{method}", t.us,
            f"measured_sparsity={res.sparsity:.3f};paper={paper:.3f};"
            f"iters={len(res.history)}"))
    for line in lines:
        print(line)
    return results


if __name__ == "__main__":
    run()
