"""Paper Fig. 5: achievable sparsity per pruning technique.

Quick mode (default, used by ``benchmarks.run``): the full Algorithm 1
loop (train → prune → eval-gate → rewind) through the ``repro.api``
session layer on a reduced CNN with synthetic CIFAR-like data —
validates the ORDERING (LTP ≥ ReaLPrune > Block ≈ CAP) and the
no-accuracy-drop gate in minutes on CPU.  The paper-scale run lives in
``examples/prune_cnn_lottery.py``.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import (METHOD_GRANULARITIES, PAPER_FIG5_REMAINING,
                               Timer, csv_line)
from repro.api import CNNAdapter, PruningSession
from repro.configs import CNNConfig, ConvSpec, PruneConfig
from repro.data import SyntheticImages

# calibration: overparameterised enough for the synthetic task that
# moderate coarse-granularity prunes pass the accuracy gate (matches
# examples/quickstart.py, which exercises keep+undo+switch visibly)
CFG = CNNConfig(
    name="mini-vgg", family="cnn",
    convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True),
           ConvSpec(64), ConvSpec(64)),
    fc=(), num_classes=10, image_size=16)
STEPS = 80


def _adapter():
    return CNNAdapter(
        CFG, data=SyntheticImages(image_size=16, noise=0.25, seed=0),
        steps=STEPS, batch_size=64, lr=0.05, lr_decay=0.95, decay_every=40,
        eval_batches=3, eval_batch_size=128)


def run(quick: bool = True) -> Dict[str, float]:
    pc = PruneConfig(prune_fraction=0.15, max_iters=12,
                     accuracy_tolerance=0.02)
    results = {}
    lines = []
    for method, grans in METHOD_GRANULARITIES.items():
        session = PruningSession(_adapter(), pc, granularities=grans)
        with Timer() as t:
            res = session.run()
        results[method] = res.sparsity
        paper = 1.0 - PAPER_FIG5_REMAINING[method]
        lines.append(csv_line(
            f"fig5_sparsity_{method}", t.us,
            f"measured_sparsity={res.sparsity:.3f};paper={paper:.3f};"
            f"iters={len(res.history)}"))
    for line in lines:
        print(line)
    return results


if __name__ == "__main__":
    run()
