"""Paper Fig. 6: ReRAM crossbars required vs unpruned (iso-performance).

Deterministic: runs the real group-pruning machinery to each method's
published Fig.-5 sparsity on the FULL VGG-11/16/19 + ResNet-18 configs,
maps masks onto 128×128 crossbars, and applies the iso-performance
replication from the pipelined execution model.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import (CONV_PRED, PAPER_FIG5_REMAINING,
                               PAPER_FIG6_SAVINGS, Timer, cnn_params,
                               csv_line, hw_report, masks_at_sparsity)
from repro.core import perf_model as pm
from repro.core.hardware import cnn_activation_volumes
from repro.core.masks import path_str

CNNS = ("vgg11", "vgg16", "vgg19", "resnet18")


def xbars_per_layer(report):
    return {l.path: l.stats.xbars_needed_packed for l in report.layers}


def run() -> Dict[str, Dict[str, float]]:
    out = {}
    lines = []
    for method, remaining in PAPER_FIG5_REMAINING.items():
        target = 1.0 - remaining
        ratios = []
        with Timer() as t:
            for name in CNNS:
                cfg, params = cnn_params(name)
                masks = masks_at_sparsity(params, target, method)
                rep = hw_report(name, masks)
                vols = cnn_activation_volumes(cfg)
                unpruned = pm.conv_layer_perf(
                    cfg, {l.path: l.stats.n_xbars for l in rep.layers}, vols)
                pruned = pm.conv_layer_perf(cfg, xbars_per_layer(rep), vols)
                iso = pm.iso_perf_xbars(unpruned, pruned)
                ratios.append(iso["savings"])
        mean_savings = float(np.mean(ratios))
        out[method] = {"savings": mean_savings,
                       "paper": PAPER_FIG6_SAVINGS[method]}
        lines.append(csv_line(
            f"fig6_xbar_savings_{method}", t.us,
            f"measured={mean_savings:.3f};paper={PAPER_FIG6_SAVINGS[method]:.3f};"
            + ";".join(f"{n}={r:.3f}" for n, r in zip(CNNS, ratios))))
    for line in lines:
        print(line)
    return out


if __name__ == "__main__":
    run()
