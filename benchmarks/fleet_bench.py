"""Fleet scaling: scheduling throughput + router overhead vs engines.

One CPU container runs every engine, so wall-clock cannot show real
multi-engine speedup — the engines' jitted steps execute serially
inside ``FleetRouter.pump``.  What the fleet layer CAN prove here is a
*scheduling* claim and a *cost* claim:

  * **scheduling throughput** — with E engines, each router tick pumps
    E schedulers, so a fixed request burst drains in monotonically
    fewer ticks (and monotonically more tokens per tick) as E grows.
    That is the quantity that turns into real tokens/s the moment each
    engine owns its own accelerator.
  * **router overhead** — the router's own bookkeeping (dispatch,
    health sweep, finish accounting; ``FleetRouter.dispatch_s``) must
    stay under 5% of the time spent inside engine steps
    (``FleetRouter.step_s``), or the control plane is eating the
    scale-out it exists to provide.

Wall-clock tokens/s and queue-wait (TTFT) percentiles are recorded for
completeness but are CPU/interpret-mode numbers — scheduling-only, not
a hardware claim (the README says so next to BENCH_fleet.json).

Both claims are asserted at record time, same as the other benches, so
a regression cannot silently write a JSON that contradicts the README.
``benchmarks/run.py fleet --json`` persists to ``BENCH_fleet.json``.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Timer, csv_line
from repro.configs import get_arch, scaled_down
from repro.models import transformer as tfm
from repro.serve import ServeEngine
from repro.serve.fleet import FleetRouter

ENGINE_SWEEP = (1, 2, 4)
REQUESTS = 12
PROMPT_LEN = 16
BUDGET = 8
SLOTS = 2          # per-engine decode slots: 1 engine must run waves


def _measure(cfg, params, n_engines: int) -> Dict:
    engines = [ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                           decode_fn=tfm.decode_step, batch_slots=SLOTS,
                           capacity=64)
               for _ in range(n_engines)]
    router = FleetRouter(engines)
    rng = np.random.default_rng(0)
    ticks = 0
    with Timer() as t:
        for _ in range(REQUESTS):
            prompt = rng.integers(1, cfg.vocab_size,
                                  size=PROMPT_LEN).astype(np.int32)
            router.submit(prompt, max_new_tokens=BUDGET)
        while not router.idle:           # drain, counting router ticks
            router.pump(1)
            ticks += 1
    rep = router.report
    assert rep.requests == REQUESTS
    assert rep.tokens_generated == REQUESTS * BUDGET
    overhead = router.dispatch_s / max(router.step_s, 1e-9)
    return {
        "engines": n_engines,
        "requests": REQUESTS,
        "tokens": rep.tokens_generated,
        "ticks": ticks,
        "tokens_per_tick": rep.tokens_generated / ticks,
        "wall_s": t.us / 1e6,
        "tokens_per_s": rep.tokens_per_s,
        "ttft_p50_ms": rep.ttft_p50 * 1e3,
        "ttft_p95_ms": rep.ttft_p95 * 1e3,
        "tps_p50": rep.tps_p50,
        "tps_p95": rep.tps_p95,
        "dispatch_s": router.dispatch_s,
        "step_s": router.step_s,
        "dispatch_overhead": overhead,
        "timing_basis": "cpu-scheduling-only",
        "interpret": True,
        "backend": jax.default_backend(),
    }


def run() -> List[Dict]:
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    records: List[Dict] = []
    for n in ENGINE_SWEEP:
        rec = _measure(cfg, params, n)
        rec["name"] = f"fleet_engines_{n}"
        records.append(rec)
        print(csv_line(
            rec["name"], rec["wall_s"] * 1e6 / rec["tokens"],
            f"ticks={rec['ticks']};tok_per_tick={rec['tokens_per_tick']:.2f};"
            f"ttft_p50_ms={rec['ttft_p50_ms']:.1f};"
            f"ttft_p95_ms={rec['ttft_p95_ms']:.1f};"
            f"dispatch_overhead={rec['dispatch_overhead']:.4f}"))

    # the headline claims, checked at record time
    for prev, cur in zip(records, records[1:]):
        assert cur["ticks"] <= prev["ticks"], \
            "more engines must drain the burst in no more router ticks"
        assert cur["tokens_per_tick"] >= prev["tokens_per_tick"], \
            "scheduling throughput (tokens/tick) must be monotone in engines"
    for rec in records:
        assert rec["dispatch_overhead"] < 0.05, \
            f"router dispatch overhead {rec['dispatch_overhead']:.4f} " \
            f"is >= 5% of engine step time at {rec['engines']} engines"
    return records


if __name__ == "__main__":
    run()
