"""Paper Fig. 7: training speedup vs unpruned under iso-area.

Pruned masks free crossbars; the waterfill replicates slow layers with
the freed budget; speedup = pipelined time ratio (3-pass training).

Two accountings are reported:
  * ``raw``        — the paper's literal 24576-crossbar budget with OUR
    (dense, row-packed) weight→crossbar mapping.  Our unpruned nets use
    only ~20-50% of the chip, so replication headroom exists even
    unpruned, and speedups land at ~3×.
  * ``calibrated`` — chip budget scaled so the unpruned model uses 95%
    of storage, matching the paper's own utilisation (Fig. 8: weights of
    C11-C17 alone ">80% of the ReRAM crossbars").  This isolates the
    paper's claimed mechanism (pruning frees replication budget) from
    the mapping-density difference, and reproduces the ~20× band.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import (PAPER_FIG5_REMAINING, PAPER_FIG7_SPEEDUP,
                               Timer, cnn_params, csv_line, hw_report,
                               masks_at_sparsity)
from repro.core import perf_model as pm
from repro.core.hardware import cnn_activation_volumes

CNNS = ("vgg11", "vgg16", "vgg19", "resnet18")
CALIBRATED_UTIL = 0.95


def _layer_perfs(name, method, target):
    cfg, params = cnn_params(name)
    masks = masks_at_sparsity(params, target, method)
    rep = hw_report(name, masks)
    vols = cnn_activation_volumes(cfg)
    unpruned = pm.conv_layer_perf(
        cfg, {l.path: l.stats.n_xbars for l in rep.layers}, vols)
    pruned_acts = {l.path: vols[l.path] * l.alive_outputs
                   / max(l.total_outputs, 1)
                   for l in rep.layers if l.path in vols}
    pruned = pm.conv_layer_perf(
        cfg, {l.path: l.stats.xbars_needed_packed for l in rep.layers},
        pruned_acts)
    return unpruned, pruned


def run() -> Dict[str, Dict[str, float]]:
    out = {}
    lines = []
    for method, remaining in PAPER_FIG5_REMAINING.items():
        target = 1.0 - remaining
        raw, cal = [], []
        with Timer() as t:
            for name in CNNS:
                unpruned, pruned = _layer_perfs(name, method, target)
                raw.append(pm.iso_area_speedup(unpruned, pruned))
                storage = sum(l.xbars + l.act_xbars for l in unpruned)
                budget = int(storage / CALIBRATED_UTIL)
                cal.append(pm.iso_area_speedup(unpruned, pruned,
                                               budget=budget))
        out[method] = {"raw": float(np.mean(raw)),
                       "calibrated": float(np.mean(cal))}
        paper = PAPER_FIG7_SPEEDUP.get(method)
        extra = f";paper={paper:.1f}" if paper else ""
        lines.append(csv_line(
            f"fig7_speedup_{method}", t.us,
            f"raw={np.mean(raw):.2f}x;calibrated={np.mean(cal):.2f}x{extra};"
            + ";".join(f"{n}={s:.1f}x" for n, s in zip(CNNS, cal))))
    for line in lines:
        print(line)
    return out


if __name__ == "__main__":
    run()
