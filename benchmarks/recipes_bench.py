"""Staged-recipe benchmark: the tiny CNN through the paper schedule +
int8 QAT.

Runs the full recipe interpreter (``repro.api.PruningSession``) on the
fig5-calibrated mini-VGG (the repo's tiny CNN whose synthetic task is
overparameterised enough for gated prune rounds to pass) and reports
one record per STAGE: rounds executed, accuracy at stage exit, overall
sparsity, and the live-crossbar (tile) fraction of the committed masks
— the per-stage trajectory the paper's schedule-ablation discussion
reads off.

CSV lines go to stdout like every other bench; ``benchmarks.run
recipes --json`` wraps the records into ``BENCH_recipes.json``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Timer, csv_line
from benchmarks.fig5_sparsity import _adapter
from repro.api import (PruningSession, Recipe, prune_stage,
                       quantize_stage)
from repro.configs import PruneConfig
from repro.core.hardware import analyze_masks

NAME = "mini_vgg"
ROUNDS = 8              # global prune-round budget
RECIPE = Recipe(
    name="tiny-cnn-paper-quant",
    description="paper schedule at the fig5 calibration (15%/round) "
                "plus int8 QAT",
    stages=(prune_stage("filter", rate=0.15),
            prune_stage("channel", rate=0.15),
            prune_stage("index", rate=0.15),
            quantize_stage(8)))


def _live_tile_fraction(masks, conv_pred, geometry) -> float:
    """Fraction of crossbars (MXU tiles) still holding any live weight —
    strict count, no repacking, so it matches what bsmm can skip."""
    rep = analyze_masks(masks, conv_pred,
                        xbar_rows=geometry.rows, xbar_cols=geometry.cols)
    return rep.xbars_needed_strict / max(rep.xbars_unpruned, 1)


def run(quick: bool = True) -> List[Dict]:
    adapter = _adapter()
    per_stage: Dict[int, Dict] = {}

    def observe(event):
        # session.masks is the committed state after this event, so the
        # last observation per stage is that stage's exit trajectory
        rec = per_stage.setdefault(event.stage_idx, {
            "stage": event.stage, "stage_idx": event.stage_idx,
            "kind": event.kind, "rounds": 0, "accepted_rounds": 0})
        rec["rounds"] += 1
        rec["accepted_rounds"] += int(event.accepted)
        rec["accuracy"] = event.accuracy
        rec["sparsity"] = (event.sparsity_after if event.accepted
                           else event.sparsity_before)
        # recomputed per round (only the stage-exit value survives):
        # a host-side mask walk, milliseconds at this model size and
        # dwarfed by the round's retrain
        rec["live_tile_fraction"] = _live_tile_fraction(
            session.masks, adapter.conv_pred, session.geometry)

    session = PruningSession(
        adapter,
        PruneConfig(max_iters=ROUNDS, accuracy_tolerance=0.02),
        recipe=RECIPE, callbacks=[observe])
    with Timer() as t:
        res = session.run()

    records = [per_stage[i] for i in sorted(per_stage)]
    lines = [csv_line(
        f"recipes_{NAME}_{r['stage'].replace(':', '_')}",
        t.us / max(len(res.history), 1),
        f"rounds={r['rounds']};acc={r['accuracy']:.3f};"
        f"sparsity={r['sparsity']:.3f};"
        f"live_tiles={r['live_tile_fraction']:.3f}")
        for r in records]

    rep = session.hardware_report()
    records.append({
        "stage": "final",
        "stage_idx": len(session.recipe.stages),
        "kind": "summary",
        "recipe": session.recipe.name,
        "sparsity": res.sparsity,
        "live_tile_fraction": _live_tile_fraction(
            res.masks, adapter.conv_pred, session.geometry),
        "quantize_bits": session.quantize_bits,
        "xbar_savings": rep.xbar_savings,
        "weight_bytes": rep.weight_bytes(),
    })
    lines.append(csv_line(
        f"recipes_{NAME}_final", t.us,
        f"sparsity={res.sparsity:.3f};"
        f"live_tiles={records[-1]['live_tile_fraction']:.3f};"
        f"xbar_savings={rep.xbar_savings:.3f};"
        f"qbits={session.quantize_bits}"))
    for line in lines:
        print(line)
    return records


if __name__ == "__main__":
    run()
