"""Kernel microbenchmarks: block-sparse matmul tile-skip scaling.

Wall-clock on this CPU container is NOT TPU time; the meaningful derived
quantities are the tile-density (= compute/bandwidth cost on TPU) and
the interpret-mode consistency vs the oracle.  ``us_per_call`` is the
jnp oracle's CPU time (compiled), reported for completeness.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, csv_line
from repro.kernels.bsmm import compact_tile_indices
from repro.kernels.ops import tile_bitmap, tile_density
from repro.kernels.ref import bsmm_ref


def run():
    rng = np.random.RandomState(0)
    M = K = N = 512
    b = 128
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    ref_fn = jax.jit(lambda x, w, m: bsmm_ref(x, w, m, b, b))
    for density in (1.0, 0.5, 0.25, 0.05):
        tm = (rng.rand(K // b, N // b) < density).astype(np.int32)
        if density == 1.0:
            tm[:] = 1
        idx, counts, kmax = compact_tile_indices(tm)
        out = ref_fn(x, w, jnp.asarray(tm))
        out.block_until_ready()
        with Timer() as t:
            for _ in range(10):
                ref_fn(x, w, jnp.asarray(tm)).block_until_ready()
        live = tm.mean()
        # kernel K-grid = max live tiles per column (skipped MXU passes)
        grid_frac = kmax / tm.shape[0]
        print(csv_line(
            f"bsmm_density_{density}", t.us / 10,
            f"live_tiles={live:.3f};kgrid_frac={grid_frac:.3f};"
            f"tpu_compute_saving={1 - grid_frac:.3f}"))


if __name__ == "__main__":
    run()
