"""Kernel microbenchmarks: block-sparse TRAINING-step tile-skip scaling.

Times one value_and_grad step — forward + dx + dw, all through the
block-sparse Pallas kernels (``bsmm_apply``'s custom VJP) — against the
dense jnp step, at several tile densities.  Alongside wall-clock it
reports the *predicted* TPU saving from the plan's static metadata:

    fwd passes  = kmax / Kt      (max live K-tiles per output column)
    dx  passes  = nmax / Nt      (transposed plan)
    dw  tiles   = live / total   (only live (bk, bn) grad tiles built)

On this CPU container the kernels run in interpret mode, so wall-clock
is an emulation proxy, NOT TPU time — the derived tile fractions are
the quantity the paper's training-speedup claim maps to.  On a real TPU
backend the kernels compile natively (interpret off) and the measured
saving should track the prediction.

``run()`` prints the CSV lines every bench module emits AND returns
machine-readable records; ``benchmarks/run.py --json`` persists them to
``BENCH_kernels.json`` so the repo accumulates a benchmark trajectory.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, csv_line
from repro.core.perf_model import bsmm_train_cost
from repro.kernels.bsmm import default_interpret, make_tile_plan, plan_matmul

DENSITIES = (1.0, 0.5, 0.25, 0.0625)


def _mask_at_density(rng, K: int, N: int, b: int, density: float):
    """Elementwise mask whose TILE density is exactly ``density``."""
    Kt, Nt = K // b, N // b
    n_live = max(int(round(density * Kt * Nt)), 0)
    flat = np.zeros(Kt * Nt, np.int32)
    flat[rng.choice(Kt * Nt, n_live, replace=False)] = 1
    bitmap = flat.reshape(Kt, Nt)
    return np.repeat(np.repeat(bitmap, b, 0), b, 1).astype(np.float32)


def _time_step(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    with Timer() as t:
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
    return t.us / iters


def run(M: int = 256, K: int = 512, N: int = 512, b: int = 128,
        iters: int = 10) -> List[Dict]:
    rng = np.random.RandomState(0)
    interpret = default_interpret()
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)

    def dense_step(w):
        def loss(w):
            return jnp.sum(jnp.square(x @ w))
        return jax.value_and_grad(loss)(w)

    us_dense = _time_step(jax.jit(dense_step), w, iters=iters)
    records: List[Dict] = []
    us_full_plan = None           # density-1.0 kernel run: the anchor that
    for density in DENSITIES:     # isolates tile-skip from interpret overhead
        mask = _mask_at_density(rng, K, N, b, density)
        plan = make_tile_plan(mask, tile=b, interpret=interpret)
        wm = jnp.asarray(np.asarray(w) * mask)

        def sparse_step(w, plan=plan):
            def loss(w):
                return jnp.sum(jnp.square(plan_matmul(x, w, plan)))
            return jax.value_and_grad(loss)(w)

        us_sparse = _time_step(jax.jit(sparse_step), wm, iters=iters)
        if us_full_plan is None:
            us_full_plan = us_sparse
        Kt, Nt = K // b, N // b
        fwd_frac = plan.kmax / Kt
        dx_frac = plan.nmax / Nt
        dw_frac = plan.live_tiles / plan.total_tiles
        predicted_cost = (fwd_frac + dx_frac + dw_frac) / 3.0
        # the K306-audited analytic model: per-kernel passes/FLOPs/HBM
        # bytes for this exact plan (what the TPU regen compares against)
        cost = bsmm_train_cost(plan, M, bm=b)
        rec = {
            "name": f"bsmm_train_density_{density}",
            "shape": [M, K, N],
            "tile": b,
            "tile_density": dw_frac,
            "kmax": plan.kmax, "kt": Kt,
            "nmax": plan.nmax, "nt": Nt,
            "live_tiles": plan.live_tiles,
            "total_tiles": plan.total_tiles,
            "us_dense": us_dense,
            "us_sparse": us_sparse,
            "measured_saving": 1.0 - us_sparse / us_dense,
            "measured_saving_vs_full_plan": 1.0 - us_sparse / us_full_plan,
            "predicted_saving": 1.0 - predicted_cost,
            "predicted_cost": {
                k: {"passes": c.passes, "flops": c.flops,
                    "hbm_bytes": c.hbm_bytes}
                for k, c in cost.items()},
            "interpret": interpret,
            "backend": jax.default_backend(),
        }
        records.append(rec)
        print(csv_line(
            rec["name"], us_sparse,
            f"tile_density={dw_frac:.3f};kgrid_frac={fwd_frac:.3f};"
            f"ngrid_frac={dx_frac:.3f};"
            f"predicted_saving={rec['predicted_saving']:.3f};"
            f"measured_saving={rec['measured_saving']:.3f};"
            f"vs_full_plan={rec['measured_saving_vs_full_plan']:.3f}"))
    return records


if __name__ == "__main__":
    run()
