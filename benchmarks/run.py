"""Benchmark harness: one module per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  fig5_sparsity   — paper Fig. 5 (achievable sparsity per method)
  fig6_crossbars  — paper Fig. 6 (crossbar savings, iso-performance)
  fig7_speedup    — paper Fig. 7 (training speedup, iso-area)
  fig8_layerwise  — paper Fig. 8 (ResNet-18 per-layer xbars/time)
  kernels_bench   — block-sparse matmul tile-skip scaling
  roofline        — corrected roofline table from the dry-run cache

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run fig6``
"""
import sys


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    print("name,us_per_call,derived")
    mods = []
    if which in ("all", "fig8"):
        from benchmarks import fig8_layerwise
        mods.append(fig8_layerwise)
    if which in ("all", "fig6"):
        from benchmarks import fig6_crossbars
        mods.append(fig6_crossbars)
    if which in ("all", "fig7"):
        from benchmarks import fig7_speedup
        mods.append(fig7_speedup)
    if which in ("all", "kernels"):
        from benchmarks import kernels_bench
        mods.append(kernels_bench)
    if which in ("all", "roofline"):
        from benchmarks import roofline
        mods.append(roofline)
    if which in ("all", "fig5"):
        from benchmarks import fig5_sparsity
        mods.append(fig5_sparsity)
    for m in mods:
        m.run()


if __name__ == '__main__':
    main()
