"""Benchmark harness: one module per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  fig5_sparsity   — paper Fig. 5 (achievable sparsity per method)
  fig6_crossbars  — paper Fig. 6 (crossbar savings, iso-performance)
  fig7_speedup    — paper Fig. 7 (training speedup, iso-area)
  fig8_layerwise  — paper Fig. 8 (ResNet-18 per-layer xbars/time)
  kernels_bench   — block-sparse train-step (fwd+bwd) tile-skip scaling
  recipes_bench   — staged recipe (paper-quant) per-stage trajectory
  paging_bench    — paged-KV decode bytes/step vs capacity & live context
  fleet_bench     — fleet scheduling throughput + router overhead vs engines
  roofline        — corrected roofline table from the dry-run cache

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run fig6``
JSON:    ``PYTHONPATH=src python -m benchmarks.run kernels --json``
         writes ``BENCH_kernels.json``;
         ``... recipes --json`` writes ``BENCH_recipes.json`` (per-stage
         accuracy/sparsity/live-tile records for the tiny CNN recipe);
         ``... paging --json`` writes ``BENCH_paging.json``;
         ``... fleet --json`` writes ``BENCH_fleet.json`` (timings are
         CPU scheduling-only — see the module docstring).
"""
import argparse
import json
import platform

# benches whose run() returns machine-readable records --json can dump
_JSON_BENCHES = {"kernels": "BENCH_kernels.json",
                 "recipes": "BENCH_recipes.json",
                 "paging": "BENCH_paging.json",
                 "fleet": "BENCH_fleet.json"}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("which", nargs="?", default="all",
                    choices=["all", "fig5", "fig6", "fig7", "fig8",
                             "kernels", "recipes", "paging", "fleet",
                             "roofline"])
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write the bench's records to PATH (default "
                         "BENCH_<bench>.json; needs `kernels` or "
                         "`recipes` in the run)")
    ap.add_argument("--force", action="store_true",
                    help="allow an interpret-mode run to overwrite a "
                         "record produced on a real backend")
    opts = ap.parse_args()
    which, json_path = opts.which, opts.json
    print("name,us_per_call,derived")
    mods = []
    if which in ("all", "fig8"):
        from benchmarks import fig8_layerwise
        mods.append(fig8_layerwise)
    if which in ("all", "fig6"):
        from benchmarks import fig6_crossbars
        mods.append(fig6_crossbars)
    if which in ("all", "fig7"):
        from benchmarks import fig7_speedup
        mods.append(fig7_speedup)
    if which in ("all", "kernels"):
        from benchmarks import kernels_bench
        mods.append(kernels_bench)
    if which in ("all", "recipes"):
        from benchmarks import recipes_bench
        mods.append(recipes_bench)
    if which in ("all", "paging"):
        from benchmarks import paging_bench
        mods.append(paging_bench)
    if which in ("all", "fleet"):
        from benchmarks import fleet_bench
        mods.append(fleet_bench)
    if which in ("all", "roofline"):
        from benchmarks import roofline
        mods.append(roofline)
    if which in ("all", "fig5"):
        from benchmarks import fig5_sparsity
        mods.append(fig5_sparsity)
    records = {}
    for m in mods:
        out = m.run()
        for bench in _JSON_BENCHES:
            if m.__name__.endswith(f"{bench}_bench"):
                records[bench] = out
    if json_path is not None:
        if not records:
            raise SystemExit("--json needs a record-producing bench in "
                             "the run (`kernels`, `recipes`, `paging`, "
                             "or `all`)")
        if json_path and len(records) > 1:
            raise SystemExit(
                "--json PATH is ambiguous with multiple record benches "
                "in one run (`all` produces several); drop the PATH to "
                "get the default BENCH_<bench>.json names, or run one "
                "bench at a time")
        import os

        import jax

        from repro.kernels.bsmm import default_interpret
        interpret = bool(default_interpret())
        for bench, recs in records.items():
            path = json_path or _JSON_BENCHES[bench]
            # kernel-timing benches: refuse to clobber a real-backend
            # record with an interpret-mode (CPU emulation) one — the
            # numbers are not comparable (TPU bring-up runbook step 3
            # regenerates these non-interpret on hardware)
            if bench in ("kernels", "paging") and interpret \
                    and not opts.force and os.path.exists(path):
                try:
                    with open(path) as f:
                        prev = json.load(f)
                except (OSError, ValueError):
                    prev = {}
                if prev.get("interpret_mode") is False:
                    raise SystemExit(
                        f"{path} holds a non-interpret "
                        f"({prev.get('backend')}) record; this run is "
                        f"interpret-mode and would bury it. Re-run "
                        f"with --force to overwrite anyway.")
            payload = {
                "bench": bench,
                "backend": jax.default_backend(),
                "interpret_mode": interpret,
                "python": platform.python_version(),
                "jax": jax.__version__,
                "records": recs,
            }
            with open(path, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            print(f"# wrote {path} ({len(recs)} records)")


if __name__ == '__main__':
    main()
