"""Benchmark harness: one module per paper table/figure + kernel/roofline.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

  fig5_sparsity   — paper Fig. 5 (achievable sparsity per method)
  fig6_crossbars  — paper Fig. 6 (crossbar savings, iso-performance)
  fig7_speedup    — paper Fig. 7 (training speedup, iso-area)
  fig8_layerwise  — paper Fig. 8 (ResNet-18 per-layer xbars/time)
  kernels_bench   — block-sparse train-step (fwd+bwd) tile-skip scaling
  roofline        — corrected roofline table from the dry-run cache

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run fig6``
JSON:    ``PYTHONPATH=src python -m benchmarks.run kernels --json``
         writes ``BENCH_kernels.json`` (machine-readable kernel records:
         measured step-time saving vs the tile-density/kmax prediction).
"""
import argparse
import json
import platform


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("which", nargs="?", default="all",
                    choices=["all", "fig5", "fig6", "fig7", "fig8",
                             "kernels", "roofline"])
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="write the kernel-bench records to PATH "
                         "(default BENCH_kernels.json)")
    opts = ap.parse_args()
    which, json_path = opts.which, opts.json
    print("name,us_per_call,derived")
    mods = []
    if which in ("all", "fig8"):
        from benchmarks import fig8_layerwise
        mods.append(fig8_layerwise)
    if which in ("all", "fig6"):
        from benchmarks import fig6_crossbars
        mods.append(fig6_crossbars)
    if which in ("all", "fig7"):
        from benchmarks import fig7_speedup
        mods.append(fig7_speedup)
    if which in ("all", "kernels"):
        from benchmarks import kernels_bench
        mods.append(kernels_bench)
    if which in ("all", "roofline"):
        from benchmarks import roofline
        mods.append(roofline)
    if which in ("all", "fig5"):
        from benchmarks import fig5_sparsity
        mods.append(fig5_sparsity)
    kernel_records = None
    for m in mods:
        out = m.run()
        if m.__name__.endswith("kernels_bench"):
            kernel_records = out
    if json_path is not None:
        if kernel_records is None:
            raise SystemExit("--json needs the kernels bench in the run "
                             "(use `kernels` or `all`)")
        import jax
        payload = {
            "bench": "kernels",
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "records": kernel_records,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {json_path} ({len(kernel_records)} records)")


if __name__ == '__main__':
    main()
