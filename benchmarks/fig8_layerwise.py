"""Paper Fig. 8: per-layer crossbars + compute time, unpruned ResNet-18.

Reproduces the motivating observation: C1-C5 dominate execution time
while C11-C17 hold >60-80% of the crossbars.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import Timer, cnn_params, csv_line
from repro.core import crossbar as xb
from repro.core import perf_model as pm


def run() -> Dict[str, List[float]]:
    with Timer() as t:
        cfg, params = cnn_params("resnet18")
        xbars = {}
        for i, spec in enumerate(cfg.convs):
            w = np.asarray(params["convs"][i]["w"])
            grid = xb.grid_of(xb.conv_to_matrix(w).shape)
            xbars[f"convs/{i}/w"] = grid.n_xbars
        layers = pm.conv_layer_perf(cfg, xbars)
        total_xb = sum(l.xbars for l in layers)
        total_t = sum(l.out_positions for l in layers)
        xb_frac = [l.xbars / total_xb for l in layers]
        t_frac = [l.out_positions / total_t for l in layers]
    early_time = sum(t_frac[:5])
    late_xbars = sum(xb_frac[10:])
    print(csv_line(
        "fig8_resnet18_layerwise", t.us,
        f"time_frac_C1-C5={early_time:.3f};xbar_frac_C11-C17={late_xbars:.3f};"
        + ";".join(f"C{i+1}={f:.4f}" for i, f in enumerate(t_frac))))
    print(csv_line(
        "fig8_resnet18_xbars", 0.0,
        ";".join(f"C{i+1}={f:.4f}" for i, f in enumerate(xb_frac))))
    return {"xbar_frac": xb_frac, "time_frac": t_frac}


if __name__ == "__main__":
    run()
