"""Shared helpers for the paper-figure benchmarks.

The hardware-savings and speedup figures (6-8) are deterministic
consequences of (masks × crossbar mapping × execution model).  To
evaluate them on the paper's FULL-SIZE CNNs without hours of CPU
training, ``masks_at_sparsity`` drives the real group-pruning machinery
(same code as Algorithm 1's line 4) on randomly-initialised weights to
each method's published achievable sparsity (paper Fig. 5).  The
training-dependent claim (those sparsities are reachable with no
accuracy loss) is validated separately at reduced scale by
``fig5_sparsity`` and ``examples/prune_cnn_lottery.py``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.configs import get_cnn
from repro.core import masks as masks_lib
from repro.core.algorithm import prune_step
from repro.core.hardware import analyze_masks, cnn_activation_volumes
from repro.core.masks import cnn_prunable, sparsity_fraction
from repro.models import cnn as cnn_lib

# paper Fig. 5: % weights REMAINING after pruning (by method)
PAPER_FIG5_REMAINING = {
    "realprune": 0.045,   # 95.5% pruned
    "ltp": 0.028,         # 97.2%
    "block": 0.127,       # 87.3%
    "cap": 0.125,         # 87.5%
}
PAPER_FIG6_SAVINGS = {"realprune": 0.772, "ltp": 0.589, "block": 0.587,
                      "cap": 0.590}
PAPER_FIG7_SPEEDUP = {"realprune": 19.7}

METHOD_GRANULARITIES = {
    "realprune": ["filter", "channel", "index"],
    "ltp": ["ltp"],
    "block": ["block"],
    "cap": ["cap"],
}

CONV_PRED = lambda p: "convs" in p or "shortcuts" in p    # noqa: E731


def cnn_params(name: str, seed: int = 0):
    cfg = get_cnn(name)
    params, _ = cnn_lib.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def masks_at_sparsity(params, target_sparsity: float, method: str,
                      frac_per_iter: float = 0.25, max_iters: int = 40,
                      geometry=None):
    """Iterate the method's prune step until the target sparsity.

    For realprune the coarse→fine schedule advances on a fixed budget
    (filter to ~40%, channel to ~70%, index beyond) — the accuracy-gated
    switching of Algorithm 1 replaced by the sparsity budget (no
    training in this deterministic mode).  ``geometry`` (a
    ``TileGeometry``) selects a non-default crossbar size.
    """
    grans = METHOD_GRANULARITIES[method]
    masks = masks_lib.make_masks(params, cnn_prunable)
    g = 0
    switch_at = {0: 0.40, 1: 0.70} if method == "realprune" else {}
    for _ in range(max_iters):
        s = sparsity_fraction(masks)
        if s >= target_sparsity:
            break
        while g in switch_at and s >= switch_at[g] and g + 1 < len(grans):
            g += 1
        frac = min(frac_per_iter,
                   (target_sparsity - s) / max(1e-9, 1.0 - s))
        masks = prune_step(params, masks, grans[g], frac, CONV_PRED,
                           geometry=geometry)
    return masks


def hw_report(name: str, masks, geometry=None):
    cfg = get_cnn(name)
    kw = {}
    if geometry is not None:
        kw = {"xbar_rows": geometry.rows, "xbar_cols": geometry.cols}
    return analyze_masks(masks, CONV_PRED,
                         activation_volumes=cnn_activation_volumes(cfg),
                         **kw)


def csv_line(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
