"""Paged-KV decode bandwidth: bytes/step vs capacity and live context.

The point of paging is that decode-attention bandwidth scales with the
LIVE context (blocks actually holding tokens), not with the allocated
capacity — a dense per-slot cache reads its full ``capacity`` tokens of
K and V every step regardless of how short the request is.

Two sweeps over a tiny llama-family model, one request per run:

  * ``capacity`` sweep — fixed live context, growing ``kv_blocks``:
    paged bytes/token must stay FLAT while the dense oracle's per-step
    read (``capacity × token_bytes``) grows linearly with capacity.
  * ``context`` sweep — fixed ``kv_blocks``, growing prompt length:
    paged bytes/token must grow linearly (in ``BLOCK_TOKENS`` steps)
    with the live context.

Bytes are the engine's own analytic accounting (``ServeReport
.kv_bytes_per_token`` = block_bytes × blocks gathered per decode call);
wall-clock on this CPU container is interpret-mode emulation and is
recorded for completeness only.

``run()`` prints the CSV lines every bench module emits AND returns
machine-readable records; ``benchmarks/run.py paging --json`` persists
them to ``BENCH_paging.json``.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Timer, csv_line
from repro.configs import get_arch, scaled_down
from repro.kernels.paged_attention import BLOCK_TOKENS
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine
from repro.serve.paging import blocks_needed

CTX_SWEEP = (32, 160, 288)          # 1, 2, 3 live blocks
KV_BLOCKS_SWEEP = (4, 8, 16)        # capacity 384 → 1920 tokens
FIXED_CTX = 160
FIXED_KV_BLOCKS = 16
BUDGET = 4


def _measure(cfg, params, ctx: int, kv_blocks: int) -> Dict:
    eng = ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                      decode_fn=tfm.decode_step, batch_slots=2,
                      capacity=BLOCK_TOKENS, kv_blocks=kv_blocks)
    assert eng.paged
    prompt = (np.arange(ctx, dtype=np.int32) % (cfg.vocab_size - 1)) + 1
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=BUDGET))
    with Timer() as t:
        done = eng.run()
    assert len(done) == 1 and done[0].done
    rep = eng.report
    token_bytes = rep.kv_block_bytes / BLOCK_TOKENS
    return {
        "live_context": ctx,
        "kv_blocks": kv_blocks,
        "capacity_tokens": eng.max_context,
        "live_blocks": blocks_needed(ctx + BUDGET, BLOCK_TOKENS),
        "kv_blocks_peak": rep.kv_blocks_peak,
        "kv_block_bytes": rep.kv_block_bytes,
        "paged_bytes_per_token": rep.kv_bytes_per_token,
        "dense_bytes_per_token": eng.max_context * token_bytes,
        "us_per_decode_step": t.us / max(rep.decode_steps, 1),
        "decode_steps": rep.decode_steps,
        "interpret": True,
        "backend": jax.default_backend(),
    }


def run() -> List[Dict]:
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    records: List[Dict] = []

    for kv_blocks in KV_BLOCKS_SWEEP:
        rec = _measure(cfg, params, FIXED_CTX, kv_blocks)
        rec["name"] = f"paging_capacity_{rec['capacity_tokens']}"
        rec["sweep"] = "capacity"
        records.append(rec)
        print(csv_line(
            rec["name"], rec["us_per_decode_step"],
            f"ctx={FIXED_CTX};capacity={rec['capacity_tokens']};"
            f"paged_B_per_tok={rec['paged_bytes_per_token']:.0f};"
            f"dense_B_per_tok={rec['dense_bytes_per_token']:.0f}"))

    for ctx in CTX_SWEEP:
        rec = _measure(cfg, params, ctx, FIXED_KV_BLOCKS)
        rec["name"] = f"paging_context_{ctx}"
        rec["sweep"] = "context"
        records.append(rec)
        print(csv_line(
            rec["name"], rec["us_per_decode_step"],
            f"ctx={ctx};capacity={rec['capacity_tokens']};"
            f"live_blocks={rec['live_blocks']};"
            f"paged_B_per_tok={rec['paged_bytes_per_token']:.0f};"
            f"dense_B_per_tok={rec['dense_bytes_per_token']:.0f}"))

    # the headline claims, checked at record time so a regression cannot
    # silently write a JSON that contradicts the README
    cap = [r for r in records if r["sweep"] == "capacity"]
    assert len({r["paged_bytes_per_token"] for r in cap}) == 1, \
        "paged bytes/token must be flat in capacity"
    ctxs = [r for r in records if r["sweep"] == "context"]
    per_block = ctxs[0]["kv_block_bytes"]
    for r in ctxs:
        assert r["paged_bytes_per_token"] == r["live_blocks"] * per_block, \
            "paged bytes/token must be linear in live blocks"
    return records


if __name__ == "__main__":
    run()
