"""Roofline table: scan-corrected terms for every dry-run cell.

Reads ``dryrun_results.json`` (written by ``repro.launch.dryrun``),
applies the scan-trip-count correction κ (see ``repro.launch.costs``),
and prints one CSV row per (arch × shape × mesh) cell with the three
terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from benchmarks.common import csv_line
from repro.configs import get_arch, get_shape
from repro.launch.costs import corrected_roofline

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def load_corrected(path: str = RESULTS) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        records = json.load(f)
    out = []
    for rec in records:
        if rec.get("status") != "OK":
            out.append(rec)
            continue
        cfg = get_arch(rec["arch"])
        shape = get_shape(rec["shape"])
        rec = dict(rec)
        rec["roofline_corrected"] = corrected_roofline(
            rec["roofline"], cfg, shape)
        out.append(rec)
    return out


def run():
    records = load_corrected()
    if not records:
        print(csv_line("roofline_missing", 0.0,
                       f"no {RESULTS}; run python -m repro.launch.dryrun"))
        return
    for rec in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                              r["mesh"])):
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec["status"] == "SKIP":
            print(csv_line(name, 0.0, f"SKIP;{rec['reason'][:60]}"))
            continue
        if rec["status"] == "FAIL":
            print(csv_line(name, 0.0, f"FAIL;{rec['error'][:60]}"))
            continue
        r = rec["roofline_corrected"]
        gib = rec["memory"]["total_bytes_per_device"] / 2 ** 30
        print(csv_line(
            name, rec.get("compile_s", 0) * 1e6,
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};"
            f"bottleneck={r['bottleneck']};kappa={r['kappa']:.1f};"
            f"useful={r.get('useful_flops_ratio', 0):.3f};"
            f"mfu={r.get('mfu', 0):.4f};mem_gib={gib:.1f}"))


if __name__ == "__main__":
    run()
