"""Continuous-batching serving engine: slot refill mid-decode, ticket
generations for zero-drain hot-swap.

The scheduler keeps a fixed array of decode *slots*.  Each request is
prefilled on its own (padded to a length bucket, masked via
``valid_len`` so padding never leaks into attention) and its caches are
spliced into a free slot's cache lanes; all slots then advance through
ONE jitted decode step per token, each at its own sequence position
(per-slot cache indices).  The moment a slot's request finishes — EOS,
token budget, or deadline expiry — the next queued request is prefilled
and spliced in while the other slots keep decoding.  No request ever
waits for a batch-mate, and no request's output depends on its
batch-mates.

**Ticket generations.**  The engine's params/plan/jitted-fns bundle is
a *generation*.  ``swap(params, masks)`` installs a new generation
without draining traffic: requests already in slots keep decoding on
the generation that prefilled them (identical params, caches and
sampling stream — their outputs are bit-identical to a swap-free run),
while every subsequent admission prefills on the new ticket.  A drained
old generation is retired automatically; ``rollback`` discards a
just-installed generation that has not served traffic yet (the ticket
manager's smoke-verification path).

The engine is drivable two ways: ``run()`` serves the queue to
completion (the original batch surface), while ``step()`` advances one
scheduler tick — refill, deadline sweep, one decode per live
generation — so a front-end (``serve.frontend``) can interleave
admission, streaming, health checks and hot-swaps between ticks.

This is the LM-serving analogue of the paper's "train the pruned model"
story: hand the engine the ticket's masks and the decode projections are
routed through the block-sparse Pallas kernel (``kernels.bsmm``), so
decode compute/bandwidth scales with the live-tile count exactly as the
paper's crossbar count scales with surviving 128×128 blocks.

**Paged KV cache.**  For all-global-attention architectures the engine
replaces the per-slot dense caches with per-generation *block pools*
(``serve.paging.BlockPool`` over ``models.transformer`` paged caches):
each slot holds a block table into a shared pool of ``BLOCK_TOKENS``-
token KV blocks, decode attends through the paged Pallas kernel
(``kernels.paged_attention``), and KV bytes/step scale with *live
context* instead of allocated capacity — the KV-state analogue of the
live-tile story above.  Admission becomes dynamic: a request is
admitted when ``ceil((prompt + budget) / BLOCK)`` blocks are free, so a
prompt longer than the dense ``capacity`` serves fine on an idle
engine (the static ``oversize`` limit moves out to
``(kv_blocks - 1) * BLOCK``); when blocks are short the request waits
at the head of the FIFO queue and is admitted as finished requests
release their blocks.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Deque, List, Optional, Sequence, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import BLOCK_TOKENS
from repro.serve.paging import BlockPool, blocks_needed
from repro.serve.ticket import PlanStats, build_decode_plan


class SubmitRejected(ValueError):
    """Structured admission rejection.

    ``reason`` is machine-readable:

      * ``"capacity"``     — bounded intake queue is full.  The ONLY
        retryable reason: capacity frees as slots drain, so front-ends
        park these in their wait queue.
      * ``"oversize"``     — prompt + budget exceeds KV-cache capacity.
      * ``"empty_prompt"`` — no prompt tokens.
      * ``"bad_budget"``   — ``max_new_tokens < 1``.
      * ``"unhealthy"``    — the engine's health gate is closed (e.g.
        heartbeat missed); admission stops, in-flight decode continues.

    Subclasses ``ValueError`` so pre-control-plane callers that caught
    the bare failure keep working.
    """

    RETRYABLE = ("capacity",)

    def __init__(self, reason: str, message: str, uid=None):
        self.reason = reason
        self.uid = uid
        super().__init__(message)

    @property
    def retryable(self) -> bool:
        return self.reason in self.RETRYABLE


@dataclass
class EngineHealth:
    healthy: bool = True
    reason: str = "ok"


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32 — decoder prompt
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    # enc-dec lane: precomputed encoder frames (T_enc, d_model); the
    # prompt above stays the decoder prompt
    frames: Optional[np.ndarray] = None
    # seconds from submission after which the request is cancelled —
    # mid-decode cancellation frees the slot for the next admission
    deadline_s: Optional[float] = None
    # streaming: called with each token the moment it is sampled
    on_token: Optional[Callable[[int], None]] = None
    # pending -> queued/waiting -> active -> done | expired | rejected
    status: str = "pending"
    generation: Optional[int] = None    # ticket generation that served it
    submitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at


@dataclass
class ServeReport:
    """Cumulative scheduler/throughput accounting (see ``report``)."""
    requests: int = 0
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    slot_occupancy: float = 0.0     # mean busy-slot fraction per decode step
    wall_s: float = 0.0
    tokens_per_s: float = 0.0
    bsmm_enabled: bool = False
    routed_matmuls: int = 0
    live_tiles: int = 0
    total_tiles: int = 0
    skipped_tile_fraction: float = 0.0
    # per-request latency distribution (seconds / tokens-per-second)
    ttft_p50: float = 0.0
    ttft_p95: float = 0.0
    tps_p50: float = 0.0
    tps_p95: float = 0.0
    deadline_misses: int = 0
    swaps: int = 0                  # committed hot-swaps (rollbacks undo)
    # paged-KV accounting (zeros when the engine runs dense caches)
    paged: bool = False
    kv_blocks: int = 0              # pool size per generation (incl. scratch)
    kv_blocks_live: int = 0         # blocks holding live context right now
    kv_blocks_peak: int = 0         # max simultaneous live blocks (all gens)
    kv_block_bytes: int = 0         # KV bytes per block across all layers
    kv_bytes_per_token: float = 0.0  # mean KV bytes read per decoded token


@dataclass
class _Generation:
    """One ticket's serving bundle: params + plan + jitted fns + the
    slot lanes it is decoding.  Swaps append a new one; old ones drain."""
    gid: int
    params: Any
    masks: Any
    plan: Any
    plan_stats: PlanStats
    prefill_exact: Callable
    prefill_masked: Callable
    prefill_frames: Callable
    decode: Callable
    slot_reqs: List[Optional[Request]]
    slot_gens: List[Optional[Any]]
    cur: np.ndarray
    slot_caches: Any = None
    served: int = 0                 # requests prefilled on this ticket
    # paged-KV state (None / unused when the engine runs dense caches)
    pool: Optional[BlockPool] = None
    paged_caches: Any = None        # block pools, one per attention layer
    decode_paged: Optional[Callable] = None
    adopt: Optional[Callable] = None
    tables: Optional[np.ndarray] = None       # (slots, NB) int32
    lens: Optional[np.ndarray] = None         # (slots,) int32 tokens written
    slot_nblocks: Optional[np.ndarray] = None  # blocks allocated per slot
    sized: dict = field(default_factory=dict)  # per-capacity jitted prefills

    def active_count(self) -> int:
        return sum(1 for r in self.slot_reqs if r is not None)

    def free_slot(self, s: int) -> None:
        self.slot_reqs[s] = None
        self.slot_gens[s] = None


def _default_buckets(limit: int) -> List[int]:
    """Power-of-two prefill buckets capped at the largest *admissible*
    prefill length.  ``max_new_tokens >= 1`` means no admitted prompt is
    ever longer than ``limit - 1`` tokens, so a bucket at ``limit``
    would compile a prefill closure no request can reach."""
    top = max(limit - 1, 1)
    out, b = [], 8
    while b < top:
        out.append(b)
        b *= 2
    out.append(top)
    return out


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class ServeEngine:
    """Continuous-batching scheduler over pure prefill/decode functions.

    ``masks`` (optional): the pruned ticket's mask pytree — turns on
    block-sparse decode (``use_bsmm`` can force it off; it is never
    forced on without masks).  ``decode_fn`` must then accept a
    ``plan=`` kwarg (``models.transformer.decode_step`` does).

    ``queue_limit`` bounds the intake queue: beyond it ``submit``
    rejects with the retryable ``"capacity"`` reason (None = unbounded,
    the legacy batch behaviour).  ``clock`` injects a time source for
    deadline tests.  ``heartbeat``/``heartbeat_worker`` wire a
    ``distributed.fault_tolerance.HeartbeatMonitor``: every scheduler
    tick beats, so a wedged decode step surfaces as a stale heartbeat
    the front-end turns into an unhealthy admission gate.

    Oversized requests — ``len(prompt) + max_new_tokens > capacity`` —
    are rejected at ``submit`` (``SubmitRejected("oversize")``) rather
    than silently decoding past the KV-cache capacity.  With paged KV
    the static limit moves out to ``max_context`` and admission becomes
    dynamic (see below).

    ``paged`` (default None = auto) switches decode onto the paged KV
    cache: auto-enables when the architecture supports it
    (``transformer.supports_paged_decode``) and ``decode_fn`` is the
    stock ``transformer.decode_step`` (custom decode fns keep dense
    slot caches — they never learned the paged protocol).  ``kv_blocks``
    sizes each generation's block pool (default: one scratch block +
    enough blocks for every slot at dense ``capacity``, so the default
    paged engine admits at least the dense engine's load); block id 0
    is the scratch block idle table rows point at.
    """

    def __init__(self, *, params, cfg, prefill_fn, decode_fn,
                 batch_slots: int = 8, capacity: int = 512,
                 greedy: Optional[bool] = None, temperature: float = 0.0,
                 sample_seed: int = 0, masks=None,
                 use_bsmm: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 queue_limit: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 heartbeat=None, heartbeat_worker: str = "engine",
                 paged: Optional[bool] = None,
                 kv_blocks: Optional[int] = None,
                 mesh=None, rules=None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        # -- SPMD: a (data, model) Mesh + ShardingRules shard every
        # generation's params, tile plans and slot/paged KV caches with
        # NamedShardings, and the jitted closures trace with the rules'
        # activation constrainer installed (scoped — it never leaks
        # into other engines' traces).  GSPMD then partitions the same
        # scheduler code; on a 1-device mesh all specs are replicated
        # and the engine is bit-identical to the meshless path.
        self.mesh = mesh
        if rules is None and mesh is not None:
            from repro.distributed.sharding import ShardingRules
            rules = ShardingRules(mesh,
                                  head_dim=getattr(cfg, "head_dim", None))
        self.rules = rules
        self.cfg = cfg
        self.capacity = capacity
        self.slots = batch_slots
        # greedy=None (default) derives from temperature, so passing
        # temperature=0.8 alone turns sampling on; an explicit greedy
        # wins over temperature
        self.greedy = (temperature <= 0.0) if greedy is None else greedy
        self.temperature = temperature
        self.sample_seed = sample_seed
        self._prefill_fn = prefill_fn
        self._decode_fn = decode_fn

        # interpret=None → emulate the Pallas kernel everywhere except
        # on a real TPU backend (interpret mode is a correctness path,
        # not a fast path)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = interpret
        self._use_bsmm = use_bsmm

        # -- masked (bucketed) vs exact-length prefill ------------------
        try:
            from repro.models.transformer import supports_masked_prefill
            self._masked_prefill = supports_masked_prefill(cfg)
        except Exception:
            self._masked_prefill = False

        # -- paged KV cache ---------------------------------------------
        self._tfm = None
        paged_ok = False
        try:
            from repro.models import transformer as _tfm
            self._tfm = _tfm
            paged_ok = (_tfm.supports_paged_decode(cfg)
                        and decode_fn is _tfm.decode_step)
        except Exception:
            pass
        if paged is None:
            paged = paged_ok
        elif paged and not paged_ok:
            raise ValueError(
                "paged=True needs a paged-capable architecture (all-global-"
                "attention) and the stock transformer.decode_step decode_fn")
        self.paged = bool(paged)
        if self.paged:
            if kv_blocks is None:
                kv_blocks = self.slots * blocks_needed(capacity,
                                                       BLOCK_TOKENS) + 1
            if kv_blocks < 2:
                raise ValueError(f"kv_blocks must be >= 2, got {kv_blocks}")
            self.kv_blocks = int(kv_blocks)
            self.max_context = (self.kv_blocks - 1) * BLOCK_TOKENS
        else:
            self.kv_blocks = 0
            self.max_context = capacity

        self._buckets = sorted(prefill_buckets) if prefill_buckets \
            else _default_buckets(self.max_context)

        self.queue_limit = queue_limit
        self.clock = clock or time.perf_counter
        self.heartbeat = heartbeat
        self.heartbeat_worker = heartbeat_worker
        self.health = EngineHealth()

        self.queue: Deque[Request] = deque()
        self._axes = None
        self._splice = None              # built lazily from the first prefill
        self._gens: List[_Generation] = []
        self._next_gid = 0
        self._finished: List[Request] = []
        self._prefills = 0
        self._decode_steps = 0
        self._tokens = 0
        self._busy_acc = 0
        self._deadline_misses = 0
        self._swaps = 0
        self._kv_bytes = 0           # analytic KV bytes read by paged decode
        self._kv_tokens = 0          # tokens decoded on the paged path
        self._kv_peak = 0            # peak live blocks across generations
        self._block_bytes = 0        # KV bytes per block across all layers
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        self._install_generation(params, masks, use_bsmm)

    # -- SPMD plumbing -----------------------------------------------------
    def _constrained(self, fn):
        """Wrap a closure body so ITS traces see this engine's
        activation constraints.  The previously installed rules are
        restored afterwards, so engines with different meshes (or none)
        coexist in one process — including the single-device oracle an
        engine is verified against."""
        if self.rules is None:
            return fn
        rules = self.rules

        def wrapped(*args):
            from repro.distributed import sharding as _sharding
            prev = _sharding.installed()
            _sharding.install(rules)
            try:
                return fn(*args)
            finally:
                _sharding.install(prev)

        return wrapped

    def _shard_caches(self, caches):
        """NamedShardings for freshly created slot/paged cache arrays
        (decode outputs inherit the placement GSPMD propagates)."""
        if self.rules is None:
            return caches
        return jax.device_put(caches, self.rules.cache_shardings(caches))

    # -- generations (the hot-swap machinery) ------------------------------
    def _install_generation(self, params, masks, use_bsmm) -> int:
        # the ticket's TilePlans drive BOTH serving paths: prefill
        # projections skip the same dead tiles decode skips.  The
        # plan= kwarg is passed only when a plan exists, so unpruned
        # engines keep working with prefill/decode fns that never
        # learned to accept it (``models.transformer``'s do).
        plan, stats = (build_decode_plan(masks, interpret=self._interpret)
                       if masks is not None else (None, PlanStats()))
        if use_bsmm is False:
            plan, stats = None, PlanStats()
        elif use_bsmm and plan is None:
            raise ValueError("use_bsmm=True needs masks with routable "
                             "dense projections")
        if self.rules is not None:
            params = jax.device_put(params,
                                    self.rules.params_shardings(params))
            if plan is not None:
                plan = self.rules.shard_plan(plan)
        cfg, capacity = self.cfg, self.capacity
        prefill_fn, decode_fn = self._prefill_fn, self._decode_fn
        plankw = {} if plan is None else {"plan": plan}
        gen = _Generation(
            gid=self._next_gid, params=params, masks=masks, plan=plan,
            plan_stats=stats,
            prefill_exact=jax.jit(self._constrained(
                lambda p, toks: prefill_fn(p, cfg, {"tokens": toks},
                                           capacity, **plankw))),
            prefill_masked=jax.jit(self._constrained(
                lambda p, toks, vl: prefill_fn(p, cfg, {"tokens": toks},
                                               capacity, valid_len=vl,
                                               **plankw))),
            prefill_frames=jax.jit(self._constrained(
                lambda p, toks, fr: prefill_fn(p, cfg,
                                               {"tokens": toks,
                                                "frames": fr},
                                               capacity, **plankw))),
            decode=jax.jit(self._constrained(
                lambda p, caches, tok: decode_fn(p, cfg, caches, tok,
                                                 **plankw))),
            slot_reqs=[None] * self.slots,
            slot_gens=[None] * self.slots,
            cur=np.zeros((self.slots,), np.int32))
        if self.paged:
            tfm = self._tfm
            gen.pool = BlockPool(self.kv_blocks)
            gen.paged_caches = self._shard_caches(
                tfm.make_paged_caches(cfg, self.kv_blocks))
            if not self._block_bytes:
                spec = tfm.paged_cache_spec(cfg, self.kv_blocks)
                total = sum(int(np.prod(s.shape)) * s.dtype.itemsize
                            for s in jax.tree.leaves(spec))
                self._block_bytes = total // self.kv_blocks
            gen.decode_paged = jax.jit(self._constrained(
                lambda p, caches, tok, tables, lens: tfm.decode_step_paged(
                    p, cfg, caches, tok, tables, lens, **plankw)))
            gen.adopt = jax.jit(self._constrained(
                lambda paged, dense, blocks: tfm.adopt_prefill(
                    cfg, paged, dense, blocks)))
            nb = self.kv_blocks - 1     # one request may hold every block
            gen.tables = np.zeros((self.slots, nb), np.int32)
            gen.lens = np.zeros((self.slots,), np.int32)
            gen.slot_nblocks = np.zeros((self.slots,), np.int64)
        self._next_gid += 1
        self._gens.append(gen)
        return gen.gid

    @property
    def current_generation(self) -> int:
        """Generation id new admissions will prefill on."""
        return self._gens[-1].gid

    @property
    def generations(self) -> Tuple[_Generation, ...]:
        """Live ticket generations, oldest → newest.  A read-only view
        for verification tooling (``repro.analysis`` checks each
        generation's plan against its masks and traces its closures);
        the scheduler itself only ever touches ``self._gens``."""
        return tuple(self._gens)

    def swap(self, params, masks=None, use_bsmm: Optional[bool] = None
             ) -> int:
        """Install a new ticket generation WITHOUT draining traffic.

        In-flight requests finish on the generation (params + tile
        plans + caches) that prefilled them; every admission from this
        call on prefills on the new ticket.  Returns the new generation
        id (``rollback`` it if a post-swap verification fails)."""
        if use_bsmm is None:
            use_bsmm = self._use_bsmm
        gid = self._install_generation(params, masks, use_bsmm)
        self._swaps += 1
        return gid

    def rollback(self, gid: int) -> None:
        """Discard a just-swapped generation that has served nothing.

        The ticket manager swaps, smoke-verifies against the ticket's
        recorded fingerprint, and rolls back on mismatch — admissions
        in between are impossible because the scheduler is not stepped
        during verification."""
        gen = self._gens[-1]
        if gen.gid != gid:
            raise ValueError(f"generation {gid} is not the newest "
                             f"swapped-in generation")
        if gen.served or gen.active_count():
            raise RuntimeError(f"generation {gid} already served "
                               f"{gen.served} request(s); cannot roll back")
        if len(self._gens) == 1:
            raise ValueError("cannot roll back the only live generation")
        self._gens.pop()
        self._swaps -= 1

    def _gen_by_gid(self, gid: int) -> _Generation:
        for g in self._gens:
            if g.gid == gid:
                return g
        raise KeyError(f"no live generation {gid}")

    # -- health ------------------------------------------------------------
    def set_health(self, healthy: bool, reason: str = "ok") -> None:
        self.health = EngineHealth(healthy, reason)

    def evict_all(self) -> List[Request]:
        """Failover drain: remove every queued and in-slot request
        WITHOUT finishing it.  Slots free, paged blocks (and unspent
        reservations) return to their pools, and the requests come back
        unfinished (status ``"evicted"``, emitted tokens kept) so a
        fleet router can re-dispatch them onto surviving engines —
        re-prefilling from prompt + emitted tokens continues a greedy
        stream exactly where this engine left it."""
        out: List[Request] = []
        for gen in self._gens:
            for s in range(self.slots):
                req = gen.slot_reqs[s]
                if req is not None:
                    self._free_slot(gen, s)
                    req.status = "evicted"
                    out.append(req)
        while self.queue:
            req = self.queue.popleft()
            req.status = "evicted"
            out.append(req)
        return out

    # -- request intake ----------------------------------------------------
    def submit(self, req: Request) -> None:
        if not self.health.healthy:
            raise SubmitRejected(
                "unhealthy", f"request {req.uid}: engine is unhealthy "
                f"({self.health.reason}); admission stopped", req.uid)
        n = len(req.prompt)
        if n < 1:
            raise SubmitRejected(
                "empty_prompt", f"request {req.uid}: empty prompt", req.uid)
        if req.max_new_tokens < 1:
            raise SubmitRejected(
                "bad_budget", f"request {req.uid}: max_new_tokens must be "
                f">= 1, got {req.max_new_tokens}", req.uid)
        if n + req.max_new_tokens > self.max_context:
            what = (f"paged KV limit ((kv_blocks-1)*BLOCK = "
                    f"{self.max_context})" if self.paged
                    else f"KV-cache capacity ({self.capacity})")
            raise SubmitRejected(
                "oversize",
                f"request {req.uid}: prompt ({n}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds {what}; shorten the "
                "request or raise capacity",
                req.uid)
        if self.queue_limit is not None \
                and len(self.queue) >= self.queue_limit:
            raise SubmitRejected(
                "capacity", f"request {req.uid}: intake queue full "
                f"({self.queue_limit}); retry when slots free", req.uid)
        if req.submitted_at is None:
            req.submitted_at = self.clock()
        req.status = "queued"
        self.queue.append(req)

    # -- sampling ----------------------------------------------------------
    def _gen_for(self, req: Request):
        # per-request stream: sampling stays batch-invariant too
        return np.random.default_rng((self.sample_seed, req.uid))

    def _sample_row(self, logits_row: np.ndarray, gen) -> int:
        """Greedy argmax, or temperature sampling via the Gumbel trick.

        ``temperature <= 0`` degrades to argmax so callers can sweep a
        temperature schedule down to deterministic decoding.
        """
        if self.greedy or self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        g = gen.gumbel(size=z.shape)
        return int(np.argmax(z + g))

    # -- cache plumbing ----------------------------------------------------
    # Cache leaves are NOT uniformly batch-leading: scan-stacked segments
    # are (reps, B, ...) with the batch axis second.  The model reports
    # each leaf's batch axis (``transformer.cache_batch_axes``); leaves
    # whose ndim equals their axis (scalar cache indices) get a slot
    # axis appended.
    def _cache_axes(self, proto):
        if self._axes is None:
            try:
                from repro.models.transformer import cache_batch_axes
                self._axes = cache_batch_axes(self.cfg, proto)
            except Exception:
                self._axes = jax.tree.map(lambda _: 0, proto)
        return self._axes

    def _empty_slot_caches(self, proto):
        """Zeros shaped like ``proto`` with the batch axis = slot count."""
        def mk(leaf, a):
            leaf = jnp.asarray(leaf)
            if leaf.ndim <= a:           # scalar index: append slot axis
                return jnp.zeros((*leaf.shape, self.slots), leaf.dtype)
            shape = list(leaf.shape)
            shape[a] = self.slots
            return jnp.zeros(tuple(shape), leaf.dtype)
        return self._shard_caches(
            jax.tree.map(mk, proto, self._cache_axes(proto)))

    def _make_splice(self, proto):
        """Jitted: copy a single-request prefill cache into slot lanes."""
        axes = self._cache_axes(proto)

        def impl(slot_caches, new_caches, slot):
            def sp(dst, src, a):
                src = jnp.asarray(src)
                lane = (slice(None),) * a + (slot,)
                if src.ndim <= a:        # scalar index leaf
                    return dst.at[lane].set(src)
                return dst.at[lane].set(jnp.take(src, 0, axis=a))
            return jax.tree.map(sp, slot_caches, new_caches, axes)

        return jax.jit(impl)

    def _bucket(self, n: int) -> int:
        for b in self._buckets:
            if b >= n:
                return b
        return self._buckets[-1]

    def _sized_prefill(self, gen: _Generation, masked: bool):
        """Paged-mode prefill closures: the dense cache capacity is the
        *padded prompt length* (``toks.shape[1]``, static at trace), not
        the engine capacity — the cache only exists long enough to be
        scattered into pool blocks, so sizing it to the prompt keeps
        adopt cost linear in the prompt.  One jitted fn per generation;
        jax retraces per bucket exactly like the dense closures."""
        key = "masked" if masked else "exact"
        fn = gen.sized.get(key)
        if fn is None:
            cfg, prefill_fn = self.cfg, self._prefill_fn
            plankw = {} if gen.plan is None else {"plan": gen.plan}
            if masked:
                fn = jax.jit(self._constrained(lambda p, toks, vl: prefill_fn(
                    p, cfg, {"tokens": toks}, toks.shape[1], valid_len=vl,
                    **plankw)))
            else:
                fn = jax.jit(self._constrained(lambda p, toks: prefill_fn(
                    p, cfg, {"tokens": toks}, toks.shape[1], **plankw)))
            gen.sized[key] = fn
        return fn

    def _prefill_request(self, gen: _Generation, req: Request, rng):
        """Single-request prefill → (first sampled token, caches, S).

        ``rng`` is the request's sampling stream — shared with the
        decode loop so prefill and decode draws never reuse noise.
        ``S`` is the dense cache length actually prefilled (the padded
        prompt length in paged mode; the engine capacity otherwise).
        """
        prompt = np.asarray(req.prompt, np.int32)
        n = len(prompt)
        if req.frames is not None:
            # enc-dec lane: encoder frames ride along; exact-length
            # decoder prefill (frames shape is config-static, so the
            # trace caches like the bucketed path)
            frames = np.asarray(req.frames, np.float32)
            logits, caches = gen.prefill_frames(
                gen.params, jnp.asarray(prompt[None]),
                jnp.asarray(frames[None]))
            S = self.capacity
        elif self._masked_prefill:
            S = self._bucket(n)
            toks = np.zeros((1, S), np.int32)
            toks[0, :n] = prompt                       # right-pad
            fn = self._sized_prefill(gen, True) if self.paged \
                else gen.prefill_masked
            logits, caches = fn(gen.params, jnp.asarray(toks),
                                jnp.asarray([n], jnp.int32))
            S = S if self.paged else self.capacity
        else:
            fn = self._sized_prefill(gen, False) if self.paged \
                else gen.prefill_exact
            logits, caches = fn(gen.params, jnp.asarray(prompt[None]))
            S = n if self.paged else self.capacity
        tok = self._sample_row(np.asarray(logits[0, -1]), rng)
        return tok, caches, S

    # -- lifecycle helpers -------------------------------------------------
    def _finish(self, req: Request, status: str,
                out: Optional[List[Request]] = None) -> None:
        req.done = True
        req.status = status
        req.finished_at = self.clock()
        self._finished.append(req)
        if out is not None:
            out.append(req)

    def _emit_token(self, req: Request, tok: int) -> None:
        req.tokens.append(tok)
        self._tokens += 1
        if req.first_token_at is None:
            req.first_token_at = self.clock()
        if req.on_token is not None:
            req.on_token(tok)

    def _expired(self, req: Request) -> bool:
        return (req.deadline_s is not None and req.submitted_at is not None
                and self.clock() - req.submitted_at > req.deadline_s)

    def expire(self, req: Request) -> None:
        """Mark a not-yet-admitted request deadline-expired (the
        front-end's wait-queue sweep books misses here so the report
        counts every miss once)."""
        self._deadline_misses += 1
        self._finish(req, "expired")

    def _expire_queue(self, out: List[Request]) -> None:
        keep: Deque[Request] = deque()
        while self.queue:
            req = self.queue.popleft()
            if self._expired(req):
                self._deadline_misses += 1
                self._finish(req, "expired", out)
            else:
                keep.append(req)
        self.queue = keep

    def _free_slot(self, gen: _Generation, s: int) -> None:
        """Release a slot AND its paged-KV state: blocks (plus any
        unspent reservation) go back to the generation's pool, the
        table row resets to the scratch block, the length to zero."""
        req = gen.slot_reqs[s]
        if gen.pool is not None and req is not None:
            gen.pool.release(req.uid)
            gen.tables[s, :] = 0
            gen.lens[s] = 0
            gen.slot_nblocks[s] = 0
        gen.free_slot(s)

    def _expire_slots(self, out: List[Request]) -> None:
        # mid-decode cancellation: the slot is freed NOW and refilled
        # this same tick — an expired request never blocks admission
        for gen in self._gens:
            for s in range(self.slots):
                req = gen.slot_reqs[s]
                if req is not None and self._expired(req):
                    self._deadline_misses += 1
                    self._finish(req, "expired", out)
                    self._free_slot(gen, s)

    # -- the scheduler -----------------------------------------------------
    def _adopt_request(self, gen: _Generation, req: Request, s: int,
                       caches, n: int, S: int) -> None:
        """Scatter a request's dense prefill caches into pool blocks and
        point slot ``s``'s table row at them.  Blocks are drawn from the
        request's reservation; table entries past the prompt (the padded
        bucket tail) stay on the scratch block — pad keys land there or
        in the last real block's tail, both masked by ``lens``."""
        nb_real = blocks_needed(n, BLOCK_TOKENS)
        nb_total = blocks_needed(S, BLOCK_TOKENS)
        blocks = [gen.pool.alloc(req.uid) for _ in range(nb_real)]
        blocks += [0] * (nb_total - nb_real)
        gen.paged_caches = gen.adopt(gen.paged_caches, caches,
                                     jnp.asarray(blocks, jnp.int32))
        gen.tables[s, :] = 0
        gen.tables[s, :nb_real] = blocks[:nb_real]
        gen.lens[s] = n
        gen.slot_nblocks[s] = nb_real

    def _refill(self, out: List[Request]) -> None:
        gen = self._gens[-1]            # admissions target: newest ticket
        for s in range(self.slots):
            while gen.slot_reqs[s] is None and self.queue:
                req = self.queue.popleft()
                if self._expired(req):
                    self._deadline_misses += 1
                    self._finish(req, "expired", out)
                    continue
                n = len(req.prompt)
                if gen.pool is not None:
                    # dynamic admission: the request enters a slot only
                    # when its whole block budget can be reserved —
                    # every later alloc is then guaranteed, so decode
                    # never deadlocks mid-stream.  Short on blocks →
                    # the request waits at the FIFO head (no reorder)
                    # until finished requests release theirs.
                    need = blocks_needed(n + req.max_new_tokens,
                                         BLOCK_TOKENS)
                    if not gen.pool.can_reserve(need):
                        self.queue.appendleft(req)
                        return
                    gen.pool.reserve(req.uid, need)
                rng = self._gen_for(req)
                tok, caches, S = self._prefill_request(gen, req, rng)
                self._prefills += 1
                gen.served += 1
                req.generation = gen.gid
                req.status = "active"
                self._emit_token(req, tok)
                if ((req.eos_id is not None and tok == req.eos_id)
                        or req.max_new_tokens <= 1):
                    if gen.pool is not None:
                        gen.pool.release(req.uid)
                    self._finish(req, "done", out)   # done at prefill
                    continue
                if gen.pool is not None:
                    self._adopt_request(gen, req, s, caches, n, S)
                else:
                    if gen.slot_caches is None:
                        gen.slot_caches = self._empty_slot_caches(caches)
                        if self._splice is None:
                            self._splice = self._make_splice(caches)
                    gen.slot_caches = self._splice(gen.slot_caches, caches,
                                                   jnp.asarray(s, jnp.int32))
                gen.slot_reqs[s] = req
                gen.slot_gens[s] = rng
                gen.cur[s] = tok
        self._kv_peak = max(self._kv_peak, self.kv_blocks_live)

    def _decode_gen(self, gen: _Generation, out: List[Request]) -> None:
        active = [s for s in range(self.slots)
                  if gen.slot_reqs[s] is not None]
        if not active:
            return
        if gen.pool is not None:
            # alloc-on-append: the block the new token lands in
            # (lens // BLOCK) must exist before the decode step writes
            # it.  Draws come from the request's reservation, so they
            # cannot fail.
            for s in active:
                req = gen.slot_reqs[s]
                while gen.slot_nblocks[s] <= gen.lens[s] // BLOCK_TOKENS:
                    pid = gen.pool.alloc(req.uid)
                    gen.tables[s, gen.slot_nblocks[s]] = pid
                    gen.slot_nblocks[s] += 1
            self._kv_peak = max(self._kv_peak, self.kv_blocks_live)
            # copy the host-side table/len arrays at the device boundary:
            # jnp.asarray of a numpy array may alias its buffer on CPU,
            # and the scheduler mutates these in place while the decode
            # step is still dispatching (async) — aliasing would race
            logits, gen.paged_caches = gen.decode_paged(
                gen.params, gen.paged_caches,
                jnp.asarray(gen.cur[:, None].copy()),
                jnp.asarray(gen.tables.copy()), jnp.asarray(gen.lens.copy()))
            # analytic bytes: the kernel gathers ceil((len+1)/BLOCK)
            # live blocks per active row — bandwidth scales with live
            # context, independent of capacity/kv_blocks
            self._kv_bytes += self._block_bytes * sum(
                blocks_needed(int(gen.lens[s]) + 1, BLOCK_TOKENS)
                for s in active)
            self._kv_tokens += len(active)
            gen.lens[active] += 1
        else:
            logits, gen.slot_caches = gen.decode(
                gen.params, gen.slot_caches, jnp.asarray(gen.cur[:, None]))
        self._decode_steps += 1
        self._busy_acc += len(active)
        logits_h = np.asarray(logits[:, 0])
        for s in active:
            req = gen.slot_reqs[s]
            tok = self._sample_row(logits_h[s], gen.slot_gens[s])
            self._emit_token(req, tok)
            gen.cur[s] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.tokens) >= req.max_new_tokens):
                self._finish(req, "done", out)
                self._free_slot(gen, s)  # freed: refilled next tick

    def step(self) -> List[Request]:
        """One scheduler tick: deadline sweep, slot refill (newest
        generation), one decode step per generation with live slots,
        retire drained generations, heartbeat.  Returns the requests
        that finished this tick."""
        if self._t0 is None:
            self._t0 = self.clock()
        out: List[Request] = []
        self._expire_queue(out)
        self._expire_slots(out)
        if self.queue:
            self._refill(out)
        for gen in list(self._gens):
            self._decode_gen(gen, out)
        newest = self._gens[-1]
        self._gens = [g for g in self._gens
                      if g is newest or g.active_count()]
        self._t_last = self.clock()
        if self.heartbeat is not None:
            self.heartbeat.beat(self.heartbeat_worker)
        return out

    @property
    def idle(self) -> bool:
        return not self.queue and all(g.active_count() == 0
                                      for g in self._gens)

    @property
    def kv_blocks_live(self) -> int:
        """Blocks holding live context, summed over live generations."""
        return sum(g.pool.live for g in self._gens if g.pool is not None)

    def run(self) -> List[Request]:
        """Serve everything in the queue to completion (continuous).

        Returns the requests that finished during this call;
        ``self.report`` holds the cumulative accounting.
        """
        start = len(self._finished)
        while not self.idle:
            self.step()
        return self._finished[start:]

    # -- verification ------------------------------------------------------
    def smoke_decode(self, prompt, max_new: int, *,
                     gid: Optional[int] = None, frames=None) -> List[int]:
        """Greedy-decode one probe prompt through a generation's jitted
        prefill/decode WITHOUT touching slot state or the queue — the
        ticket manager verifies a swapped-in generation against the
        ticket's recorded fingerprint before committing to it."""
        gen = self._gens[-1] if gid is None else self._gen_by_gid(gid)
        prompt = np.asarray(prompt, np.int32)
        if frames is not None:
            logits, caches = gen.prefill_frames(
                gen.params, jnp.asarray(prompt[None]),
                jnp.asarray(np.asarray(frames, np.float32)[None]))
        elif len(prompt) + max_new > self.capacity:
            # probe longer than the dense capacity (possible in paged
            # mode, where admission allows it): verify through a
            # right-sized dense prefill/decode pair instead
            cap = len(prompt) + max_new
            key = ("smoke", cap)
            fns = gen.sized.get(key)
            if fns is None:
                cfg, prefill_fn = self.cfg, self._prefill_fn
                decode_fn = self._decode_fn
                plankw = {} if gen.plan is None else {"plan": gen.plan}
                fns = (jax.jit(self._constrained(lambda p, toks: prefill_fn(
                           p, cfg, {"tokens": toks}, cap, **plankw))),
                       jax.jit(self._constrained(
                           lambda p, caches, tok: decode_fn(
                               p, cfg, caches, tok, **plankw))))
                gen.sized[key] = fns
            pf, dec = fns
            logits, caches = pf(gen.params, jnp.asarray(prompt[None]))
            tok = int(np.argmax(np.asarray(logits[0, -1])))
            out = [tok]
            for _ in range(max_new - 1):
                logits, caches = dec(gen.params, caches,
                                     jnp.asarray([[tok]], jnp.int32))
                tok = int(np.argmax(np.asarray(logits[0, 0])))
                out.append(tok)
            return out
        else:
            logits, caches = gen.prefill_exact(gen.params,
                                               jnp.asarray(prompt[None]))
        tok = int(np.argmax(np.asarray(logits[0, -1])))
        out = [tok]
        for _ in range(max_new - 1):
            logits, caches = gen.decode(gen.params, caches,
                                        jnp.asarray([[tok]], jnp.int32))
            tok = int(np.argmax(np.asarray(logits[0, 0])))
            out.append(tok)
        return out

    # -- accounting --------------------------------------------------------
    @property
    def report(self) -> ServeReport:
        """Live cumulative report; latency percentiles come from every
        finished request's timestamps (TTFT = first token − submission;
        tokens/s = tokens over total request latency)."""
        fin = self._finished
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None
                else 0.0)
        ttft = [r.ttft for r in fin if r.ttft is not None]
        tps = [len(r.tokens) / max(r.finished_at - r.submitted_at, 1e-9)
               for r in fin
               if r.tokens and r.finished_at is not None
               and r.submitted_at is not None]
        cur = self._gens[-1]
        st = cur.plan_stats
        return ServeReport(
            requests=len(fin),
            prefills=self._prefills,
            decode_steps=self._decode_steps,
            tokens_generated=self._tokens,
            slot_occupancy=(self._busy_acc / (self._decode_steps
                                              * self.slots)
                            if self._decode_steps else 0.0),
            wall_s=wall,
            tokens_per_s=self._tokens / wall if wall > 0 else 0.0,
            bsmm_enabled=cur.plan is not None,
            routed_matmuls=st.routed,
            live_tiles=st.live_tiles,
            total_tiles=st.total_tiles,
            skipped_tile_fraction=st.skipped_tile_fraction,
            ttft_p50=_pct(ttft, 50), ttft_p95=_pct(ttft, 95),
            tps_p50=_pct(tps, 50), tps_p95=_pct(tps, 95),
            deadline_misses=self._deadline_misses,
            swaps=self._swaps,
            paged=self.paged,
            kv_blocks=self.kv_blocks,
            kv_blocks_live=self.kv_blocks_live,
            kv_blocks_peak=self._kv_peak,
            kv_block_bytes=self._block_bytes,
            kv_bytes_per_token=(self._kv_bytes / self._kv_tokens
                                if self._kv_tokens else 0.0),
        )
