"""Continuous-batching serving engine: slot refill mid-decode.

The scheduler keeps a fixed array of decode *slots*.  Each request is
prefilled on its own (padded to a length bucket, masked via
``valid_len`` so padding never leaks into attention) and its caches are
spliced into a free slot's cache lanes; all slots then advance through
ONE jitted decode step per token, each at its own sequence position
(per-slot cache indices).  The moment a slot's request finishes — EOS
or token budget — the next queued request is prefilled and spliced in
while the other slots keep decoding.  No request ever waits for a
batch-mate, and no request's output depends on its batch-mates.

This is the LM-serving analogue of the paper's "train the pruned model"
story: hand the engine the ticket's masks and the decode projections are
routed through the block-sparse Pallas kernel (``kernels.bsmm``), so
decode compute/bandwidth scales with the live-tile count exactly as the
paper's crossbar count scales with surviving 128×128 blocks.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.ticket import PlanStats, build_decode_plan


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeReport:
    """Per-``run()`` throughput accounting."""
    requests: int = 0
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    slot_occupancy: float = 0.0     # mean busy-slot fraction per decode step
    wall_s: float = 0.0
    tokens_per_s: float = 0.0
    bsmm_enabled: bool = False
    routed_matmuls: int = 0
    live_tiles: int = 0
    total_tiles: int = 0
    skipped_tile_fraction: float = 0.0


def _default_buckets(capacity: int) -> List[int]:
    out, b = [], 8
    while b < capacity:
        out.append(b)
        b *= 2
    out.append(capacity)
    return out


class ServeEngine:
    """Continuous-batching scheduler over pure prefill/decode functions.

    ``masks`` (optional): the pruned ticket's mask pytree — turns on
    block-sparse decode (``use_bsmm`` can force it off; it is never
    forced on without masks).  ``decode_fn`` must then accept a
    ``plan=`` kwarg (``models.transformer.decode_step`` does).

    Oversized requests — ``len(prompt) + max_new_tokens > capacity`` —
    are rejected at ``submit`` with ``ValueError`` rather than silently
    decoding past the KV-cache capacity.
    """

    def __init__(self, *, params, cfg, prefill_fn, decode_fn,
                 batch_slots: int = 8, capacity: int = 512,
                 greedy: Optional[bool] = None, temperature: float = 0.0,
                 sample_seed: int = 0, masks=None,
                 use_bsmm: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 prefill_buckets: Optional[Sequence[int]] = None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        self.params = params
        self.cfg = cfg
        self.capacity = capacity
        self.slots = batch_slots
        # greedy=None (default) derives from temperature, so passing
        # temperature=0.8 alone turns sampling on; an explicit greedy
        # wins over temperature
        self.greedy = (temperature <= 0.0) if greedy is None else greedy
        self.temperature = temperature
        self.sample_seed = sample_seed

        # -- pruned-ticket decode plan (static, baked into the jit) ----
        # interpret=None → emulate the Pallas kernel everywhere except
        # on a real TPU backend (interpret mode is a correctness path,
        # not a fast path)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._plan, self._plan_stats = (build_decode_plan(
            masks, interpret=interpret) if masks is not None
            else (None, PlanStats()))
        if use_bsmm is False:
            self._plan, self._plan_stats = None, PlanStats()
        elif use_bsmm and self._plan is None:
            raise ValueError("use_bsmm=True needs masks with routable "
                             "dense projections")

        # -- masked (bucketed) vs exact-length prefill ------------------
        try:
            from repro.models.transformer import supports_masked_prefill
            self._masked_prefill = supports_masked_prefill(cfg)
        except Exception:
            self._masked_prefill = False
        self._buckets = sorted(prefill_buckets) if prefill_buckets \
            else _default_buckets(capacity)

        # the ticket's TilePlans drive BOTH serving paths: prefill
        # projections skip the same dead tiles decode skips.  The
        # plan= kwarg is passed only when a plan exists, so unpruned
        # engines keep working with prefill/decode fns that never
        # learned to accept it (``models.transformer``'s do).
        plankw = {} if self._plan is None else {"plan": self._plan}
        self._prefill_exact = jax.jit(
            lambda p, toks: prefill_fn(p, cfg, {"tokens": toks},
                                       capacity, **plankw))
        self._prefill_masked = jax.jit(
            lambda p, toks, vl: prefill_fn(p, cfg, {"tokens": toks},
                                           capacity, valid_len=vl,
                                           **plankw))
        self._decode = jax.jit(
            lambda p, caches, tok: decode_fn(p, cfg, caches, tok,
                                             **plankw))
        self._axes = None
        self._splice = None              # built lazily from the first prefill

        self.queue: Deque[Request] = deque()
        self.report = ServeReport()

    # -- request intake ----------------------------------------------------
    def submit(self, req: Request):
        n = len(req.prompt)
        if n < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.uid}: max_new_tokens must be "
                             f">= 1, got {req.max_new_tokens}")
        if n + req.max_new_tokens > self.capacity:
            raise ValueError(
                f"request {req.uid}: prompt ({n}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds KV-cache capacity "
                f"({self.capacity}); shorten the request or raise capacity")
        self.queue.append(req)

    # -- sampling ----------------------------------------------------------
    def _gen_for(self, req: Request):
        # per-request stream: sampling stays batch-invariant too
        return np.random.default_rng((self.sample_seed, req.uid))

    def _sample_row(self, logits_row: np.ndarray, gen) -> int:
        """Greedy argmax, or temperature sampling via the Gumbel trick.

        ``temperature <= 0`` degrades to argmax so callers can sweep a
        temperature schedule down to deterministic decoding.
        """
        if self.greedy or self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        g = gen.gumbel(size=z.shape)
        return int(np.argmax(z + g))

    # -- cache plumbing ----------------------------------------------------
    # Cache leaves are NOT uniformly batch-leading: scan-stacked segments
    # are (reps, B, ...) with the batch axis second.  The model reports
    # each leaf's batch axis (``transformer.cache_batch_axes``); leaves
    # whose ndim equals their axis (scalar cache indices) get a slot
    # axis appended.
    def _cache_axes(self, proto):
        if self._axes is None:
            try:
                from repro.models.transformer import cache_batch_axes
                self._axes = cache_batch_axes(self.cfg, proto)
            except Exception:
                self._axes = jax.tree.map(lambda _: 0, proto)
        return self._axes

    def _empty_slot_caches(self, proto):
        """Zeros shaped like ``proto`` with the batch axis = slot count."""
        def mk(leaf, a):
            leaf = jnp.asarray(leaf)
            if leaf.ndim <= a:           # scalar index: append slot axis
                return jnp.zeros((*leaf.shape, self.slots), leaf.dtype)
            shape = list(leaf.shape)
            shape[a] = self.slots
            return jnp.zeros(tuple(shape), leaf.dtype)
        return jax.tree.map(mk, proto, self._cache_axes(proto))

    def _make_splice(self, proto):
        """Jitted: copy a single-request prefill cache into slot lanes."""
        axes = self._cache_axes(proto)

        def impl(slot_caches, new_caches, slot):
            def sp(dst, src, a):
                src = jnp.asarray(src)
                lane = (slice(None),) * a + (slot,)
                if src.ndim <= a:        # scalar index leaf
                    return dst.at[lane].set(src)
                return dst.at[lane].set(jnp.take(src, 0, axis=a))
            return jax.tree.map(sp, slot_caches, new_caches, axes)

        return jax.jit(impl)

    def _bucket(self, n: int) -> int:
        for b in self._buckets:
            if b >= n:
                return b
        return self.capacity

    def _prefill_request(self, req: Request, gen):
        """Single-request prefill → (first sampled token, caches).

        ``gen`` is the request's sampling stream — shared with the
        decode loop so prefill and decode draws never reuse noise.
        """
        prompt = np.asarray(req.prompt, np.int32)
        n = len(prompt)
        if self._masked_prefill:
            S = self._bucket(n)
            toks = np.zeros((1, S), np.int32)
            toks[0, :n] = prompt                       # right-pad
            logits, caches = self._prefill_masked(
                self.params, jnp.asarray(toks),
                jnp.asarray([n], jnp.int32))
        else:
            logits, caches = self._prefill_exact(
                self.params, jnp.asarray(prompt[None]))
        tok = self._sample_row(np.asarray(logits[0, -1]), gen)
        return tok, caches

    # -- the scheduler -----------------------------------------------------
    def run(self) -> List[Request]:
        """Serve everything in the queue to completion (continuous).

        Returns finished requests; ``self.report`` holds the run's
        throughput accounting.
        """
        t0 = time.perf_counter()
        finished: List[Request] = []
        slot_reqs: List[Optional[Request]] = [None] * self.slots
        slot_gens: List[Optional[object]] = [None] * self.slots
        cur = np.zeros((self.slots,), np.int32)
        slot_caches = None
        decode_steps = prefills = tokens = busy_acc = 0

        def finish(req: Request):
            req.done = True
            finished.append(req)

        while True:
            # refill every free slot before the next decode step
            for s in range(self.slots):
                while slot_reqs[s] is None and self.queue:
                    req = self.queue.popleft()
                    gen = self._gen_for(req)
                    tok, caches = self._prefill_request(req, gen)
                    prefills += 1
                    tokens += 1
                    req.tokens.append(tok)
                    if ((req.eos_id is not None and tok == req.eos_id)
                            or req.max_new_tokens <= 1):
                        finish(req)      # done at prefill; slot stays free
                        continue
                    if slot_caches is None:
                        slot_caches = self._empty_slot_caches(caches)
                        if self._splice is None:
                            self._splice = self._make_splice(caches)
                    slot_caches = self._splice(slot_caches, caches,
                                               jnp.asarray(s, jnp.int32))
                    slot_reqs[s] = req
                    slot_gens[s] = gen
                    cur[s] = tok
            active = [s for s in range(self.slots)
                      if slot_reqs[s] is not None]
            if not active:
                break
            logits, slot_caches = self._decode(self.params, slot_caches,
                                               jnp.asarray(cur[:, None]))
            decode_steps += 1
            busy_acc += len(active)
            logits_h = np.asarray(logits[:, 0])
            for s in active:
                req = slot_reqs[s]
                tok = self._sample_row(logits_h[s], slot_gens[s])
                req.tokens.append(tok)
                tokens += 1
                cur[s] = tok
                if ((req.eos_id is not None and tok == req.eos_id)
                        or len(req.tokens) >= req.max_new_tokens):
                    finish(req)
                    slot_reqs[s] = None  # freed: refilled next loop turn
                    slot_gens[s] = None

        wall = time.perf_counter() - t0
        st = self._plan_stats
        self.report = ServeReport(
            requests=len(finished),
            prefills=prefills,
            decode_steps=decode_steps,
            tokens_generated=tokens,
            slot_occupancy=(busy_acc / (decode_steps * self.slots)
                            if decode_steps else 0.0),
            wall_s=wall,
            tokens_per_s=tokens / wall if wall > 0 else 0.0,
            bsmm_enabled=self._plan is not None,
            routed_matmuls=st.routed,
            live_tiles=st.live_tiles,
            total_tiles=st.total_tiles,
            skipped_tile_fraction=st.skipped_tile_fraction,
        )
        return finished
