"""Batched serving engine: prefill/decode with a fixed-slot batch.

A minimal continuous-batching scheduler over the pure ``prefill`` /
``decode_step`` functions: requests are queued, packed into the next
free slots of the running decode batch, and emitted as they hit EOS or
their token budget.  Jitted steps; cache lives on device between calls.

This is the LM-serving analogue of the paper's "train the pruned model"
story: the pruned (ticket) weights drop straight in — serving benefits
from the same tile sparsity via the bsmm kernel.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, *, params, cfg, prefill_fn, decode_fn,
                 batch_slots: int = 8, capacity: int = 512,
                 greedy: Optional[bool] = None, temperature: float = 0.0,
                 sample_seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.capacity = capacity
        self.slots = batch_slots
        # greedy=None (default) derives from temperature, so passing
        # temperature=0.8 alone turns sampling on; an explicit greedy
        # wins over temperature
        self.greedy = (temperature <= 0.0) if greedy is None else greedy
        self.temperature = temperature
        self._rng = np.random.default_rng(sample_seed)
        self._prefill = jax.jit(
            lambda p, batch: prefill_fn(p, cfg, batch, capacity))
        self._decode = jax.jit(
            lambda p, caches, tok: decode_fn(p, cfg, caches, tok))
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        """Greedy argmax, or temperature sampling via the Gumbel trick.

        ``temperature <= 0`` degrades to argmax so callers can sweep a
        temperature schedule down to deterministic decoding.
        """
        if self.greedy or self.temperature <= 0.0:
            return np.argmax(logits, axis=-1)
        z = logits.astype(np.float64) / self.temperature
        g = self._rng.gumbel(size=z.shape)
        return np.argmax(z + g, axis=-1)

    def run(self) -> List[Request]:
        """Serve everything in the queue to completion (batch at a time).

        Requests are grouped into fixed-size decode batches; each group
        is prefilled together (prompts padded to a common length).
        """
        finished: List[Request] = []
        while self.queue:
            group = [self.queue.popleft()
                     for _ in range(min(self.slots, len(self.queue)))]
            max_prompt = max(len(r.prompt) for r in group)
            toks = np.zeros((len(group), max_prompt), np.int32)
            for i, r in enumerate(group):
                toks[i, max_prompt - len(r.prompt):] = r.prompt  # left-pad
            logits, caches = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
            last = self._sample(np.asarray(logits[:, -1]))
            for i, r in enumerate(group):
                t = int(last[i])
                r.tokens.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    r.done = True
            budget = max(r.max_new_tokens for r in group)
            cur = last.astype(np.int32)
            for _ in range(budget - 1):
                logits, caches = self._decode(self.params, caches,
                                              jnp.asarray(cur[:, None]))
                cur = self._sample(np.asarray(logits[:, 0]))
                alive = False
                for i, r in enumerate(group):
                    if r.done or len(r.tokens) >= r.max_new_tokens:
                        r.done = True
                        continue
                    t = int(cur[i])
                    r.tokens.append(t)
                    if r.eos_id is not None and t == r.eos_id:
                        r.done = True
                    else:
                        alive = True
                if not alive:
                    break
            for r in group:
                r.done = True
                finished.append(r)
        return finished
