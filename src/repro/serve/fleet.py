"""Fleet router: N continuous-batching engines behind one dispatcher.

The scale-out layer above ``ServeEngine``/``ServeFrontend`` — the
serving analogue of the paper's "one ReRAM chip cannot hold the model"
premise: one engine cannot hold the traffic, so the fleet spreads it.

* **Dispatch** — every logical request is tracked in a ``FleetRecord``
  and handed to the least-loaded live engine (slot occupancy + intake +
  wait queue, ties broken by free paged-KV blocks).  Each engine keeps
  its own continuous-batching scheduler and ``ServeFrontend``-style
  wait queue; the router never reaches into a scheduler mid-flight.
* **Failover** — engines beat a shared
  ``distributed.fault_tolerance.HeartbeatMonitor`` once per scheduler
  tick.  A stale worker (or an explicit ``kill``) fails the engine:
  its waiting AND in-flight requests are evicted and re-dispatched
  onto survivors in original submission order.  A continuation
  re-prefills from prompt + the tokens already emitted, so a *greedy*
  stream resumes exactly where the dead engine left it — no request is
  lost, none is duplicated (sampled decode also loses nothing, but the
  per-request noise stream restarts, so continuation tokens may
  differ).  A failed engine whose beats RESUME after the failure is
  re-admitted for new dispatches (flap re-admission).
* **Reporting** — ``report`` merges per-engine ``ServeReport``s with
  fleet-level percentiles recomputed over logical records, so a
  request that moved engines is counted once, with its true
  end-to-end latency.
* **Hot-swap** — ``TicketManager.swap(router, name)`` fans a
  zero-drain swap across every live engine with all-or-nothing
  rollback (``swap_targets`` is the hook it dispatches on).

``repro.analysis.verify_fleet`` checks the accounting invariants
(every uid finishes exactly once; merged totals equal per-engine
sums) — lint rule P116.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import Request, ServeEngine, SubmitRejected
from repro.serve.frontend import ServeFrontend


@dataclass
class FleetRecord:
    """One logical request, across however many engines it touches."""
    uid: Any
    prompt: np.ndarray
    max_new_tokens: int
    seq: int                              # fleet-wide FIFO position
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None
    frames: Optional[np.ndarray] = None
    on_token: Optional[Callable[[int], None]] = None
    tokens: List[int] = field(default_factory=list)
    engine: Optional[int] = None          # current engine index
    req: Optional[Request] = None         # current engine-level request
    status: str = "pending"
    redispatches: int = 0
    submitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status in ("done", "expired", "rejected")

    @property
    def generation(self) -> Optional[int]:
        return self.req.generation if self.req is not None else None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at


@dataclass
class FleetReport:
    """Merged fleet accounting: totals are sums over engines, latency
    percentiles are recomputed over LOGICAL records (a request that
    failed over is one sample with its true end-to-end latency)."""
    engines: int = 0
    live_engines: int = 0
    requests: int = 0                 # logical finished (done + expired)
    tokens_generated: int = 0         # across every engine it touched
    failovers: int = 0
    redispatched: int = 0
    swaps: int = 0
    wall_s: float = 0.0
    tokens_per_s: float = 0.0
    ttft_p50: float = 0.0             # submit → first token (queue wait
    ttft_p95: float = 0.0             # + prefill, fleet-level)
    tps_p50: float = 0.0
    tps_p95: float = 0.0
    deadline_misses: int = 0
    per_engine: List[Any] = field(default_factory=list)


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class FleetRouter:
    """Least-loaded dispatch + failover drain over N serve engines.

    ``engines`` are ready-built ``ServeEngine``s (sharded or not — the
    router is mesh-agnostic).  ``monitor`` wires heartbeat-driven
    failover: each engine beats ``<worker_prefix><i>`` once per tick,
    and ``pump`` fails over any live engine the monitor reports dead.
    Without a monitor, only explicit ``kill(i)`` fails engines.

    All engines should share one ``clock`` (pass it to the engines and
    the monitor) so deadlines and failover agree on time; the router
    reads time from the first engine.
    """

    def __init__(self, engines: Sequence[ServeEngine], *,
                 monitor=None, max_queue: int = 64,
                 worker_prefix: str = "engine"):
        if not engines:
            raise ValueError("FleetRouter needs at least one engine")
        self.monitor = monitor
        self.frontends = [ServeFrontend(e, max_queue=max_queue)
                          for e in engines]
        self._workers: List[str] = []
        for i, fe in enumerate(self.frontends):
            eng = fe.engine
            if monitor is not None and eng.heartbeat is None:
                eng.heartbeat = monitor
                eng.heartbeat_worker = f"{worker_prefix}{i}"
            self._workers.append(eng.heartbeat_worker)
        self.live = set(range(len(self.frontends)))
        self._failed: Dict[int, float] = {}   # idx → clock at failure
        self.records: Dict[Any, FleetRecord] = {}
        self.finished: List[FleetRecord] = []
        self.rejected: List[FleetRecord] = []
        self.failovers = 0
        self.redispatched = 0
        self._uids = itertools.count()
        self._seq = itertools.count()
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None
        # real-time instrumentation: router bookkeeping vs engine step
        # (the fleet bench asserts dispatch overhead < 5% of step time)
        self.dispatch_s = 0.0
        self.step_s = 0.0

    # -- clock -------------------------------------------------------------
    @property
    def clock(self):
        return self.frontends[0].engine.clock

    # -- dispatch ----------------------------------------------------------
    def _load(self, i: int):
        """Least-loaded key: slots + intake + wait queue, then free KV
        blocks (more free blocks wins), then index for determinism."""
        fe = self.frontends[i]
        eng = fe.engine
        active = sum(g.active_count() for g in eng.generations)
        free_blocks = sum(g.pool.available for g in eng.generations
                          if g.pool is not None)
        return (active + len(eng.queue) + len(fe.waiting),
                -free_blocks, i)

    def _engine_request(self, rec: FleetRecord) -> Request:
        """Engine-level request for a (possibly resumed) record: the
        prompt is the original prompt plus every token already emitted,
        the budget is what remains — greedy decode continues the stream
        bit-exactly."""
        prompt = rec.prompt
        if rec.tokens:
            prompt = np.concatenate(
                [np.asarray(prompt, np.int32),
                 np.asarray(rec.tokens, np.int32)])

        def shim(tok: int, rec=rec) -> None:
            rec.tokens.append(tok)
            if rec.first_token_at is None:
                rec.first_token_at = self.clock()
            if rec.on_token is not None:
                rec.on_token(tok)

        return Request(uid=rec.uid, prompt=np.asarray(prompt, np.int32),
                       max_new_tokens=rec.max_new_tokens - len(rec.tokens),
                       eos_id=rec.eos_id, deadline_s=rec.deadline_s,
                       frames=rec.frames, on_token=shim,
                       submitted_at=rec.submitted_at)

    def _dispatch(self, rec: FleetRecord, *, force: bool = False) -> None:
        """Hand ``rec`` to the least-loaded live engine.  ``force``
        (failover path) bypasses the wait-queue cap: an evicted request
        was already admitted once and must not be lost to backpressure.
        """
        if not self.live:
            raise RuntimeError(
                f"request {rec.uid}: no live engines to dispatch onto")
        i = min(self.live, key=self._load)
        fe = self.frontends[i]
        req = self._engine_request(rec)
        rec.engine, rec.req = i, req
        try:
            fe.engine.submit(req)
        except SubmitRejected as e:
            if e.retryable and (force or len(fe.waiting) < fe.max_queue):
                req.status = "waiting"
                fe.waiting.append(req)
            else:
                rec.status = req.status = "rejected"
                self.rejected.append(rec)
                raise
        rec.status = req.status

    def submit(self, prompt=None, *, uid=None, max_new_tokens: int = 16,
               eos_id=None, deadline_s: Optional[float] = None,
               frames=None, on_token=None) -> FleetRecord:
        """Admit one logical request to the fleet.

        Returns its ``FleetRecord`` (live view: ``tokens`` grows as the
        owning engine decodes; ``status`` ends at done/expired).
        Raises ``SubmitRejected`` exactly like a single engine would."""
        t0 = time.perf_counter()
        if prompt is None:
            raise ValueError("submit() needs a prompt")
        uid = next(self._uids) if uid is None else uid
        if uid in self.records:
            raise ValueError(f"duplicate request uid {uid!r}")
        rec = FleetRecord(uid=uid, prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens,
                          seq=next(self._seq), eos_id=eos_id,
                          deadline_s=deadline_s, frames=frames,
                          on_token=on_token, submitted_at=self.clock())
        if self._t0 is None:
            self._t0 = rec.submitted_at
        self.records[uid] = rec
        try:
            self._dispatch(rec)
        finally:
            self.dispatch_s += time.perf_counter() - t0
        return rec

    # -- failover ----------------------------------------------------------
    def kill(self, i: int) -> List[FleetRecord]:
        """Fail engine ``i`` NOW (deterministic failure injection; the
        heartbeat path calls the same drain).  Returns the re-dispatched
        records."""
        return self._fail(i, reason="killed")

    def _fail(self, i: int, reason: str) -> List[FleetRecord]:
        if i not in self.live:
            return []
        self.live.discard(i)
        now = self.monitor.clock() if self.monitor is not None \
            else self.clock()
        self._failed[i] = now
        self.failovers += 1
        fe = self.frontends[i]
        fe.engine.set_health(False, f"failover: {reason}")
        orphans = list(fe.engine.evict_all())
        while fe.waiting:
            req = fe.waiting.popleft()
            req.status = "evicted"
            orphans.append(req)
        recs = sorted((self.records[r.uid] for r in orphans),
                      key=lambda rec: rec.seq)
        for rec in recs:                       # FIFO order preserved
            rec.redispatches += 1
            self.redispatched += 1
            self._dispatch(rec, force=True)
        return recs

    def _check_fleet_health(self) -> None:
        if self.monitor is None:
            return
        dead = set(self.monitor.dead_workers())
        for i in sorted(self.live):
            if self._workers[i] in dead:
                self._fail(i, reason="heartbeat stale")
        # flap re-admission: a failed engine whose beats resumed AFTER
        # the failure comes back for new dispatches (its old work
        # already moved — nothing is duplicated)
        now = self.monitor.clock()
        for i in sorted(self._failed):
            age = self.monitor.age(self._workers[i])
            if age is None or age > self.monitor.deadline_s:
                continue
            if (now - age) > self._failed[i]:
                self._readmit(i)

    def _readmit(self, i: int) -> None:
        self._failed.pop(i, None)
        self.frontends[i].engine.set_health(True)
        self.live.add(i)

    # -- the event loop ----------------------------------------------------
    def _book_finished(self, fin: List[Request],
                       done: List[FleetRecord]) -> None:
        for req in fin:
            rec = self.records.get(req.uid)
            if rec is None or rec.req is not req or rec.done:
                continue
            rec.status = req.status
            rec.finished_at = req.finished_at \
                if req.finished_at is not None else self.clock()
            self.finished.append(rec)
            done.append(rec)

    def pump(self, steps: int = 1) -> List[FleetRecord]:
        """Advance the fleet ``steps`` ticks: health/failover sweep,
        then one frontend pump per live engine.  Returns the logical
        records that finished during the call."""
        done: List[FleetRecord] = []
        for _ in range(steps):
            t0 = time.perf_counter()
            self._check_fleet_health()
            t1 = time.perf_counter()
            self.dispatch_s += t1 - t0
            for i in sorted(self.live):
                s0 = time.perf_counter()
                fin = self.frontends[i].pump(1)
                self.step_s += time.perf_counter() - s0
                b0 = time.perf_counter()
                self._book_finished(fin, done)
                self.dispatch_s += time.perf_counter() - b0
            self._t_last = self.clock()
        return done

    def drain(self, max_steps: int = 1_000_000) -> List[FleetRecord]:
        """Pump until every live engine and wait queue is empty."""
        done: List[FleetRecord] = []
        for _ in range(max_steps):
            if self.idle:
                break
            done.extend(self.pump(1))
        return done

    @property
    def idle(self) -> bool:
        return all(self.frontends[i].idle for i in self.live)

    # -- hot-swap ----------------------------------------------------------
    def swap_targets(self):
        """(index, engine) for every live engine — the hook
        ``TicketManager.swap`` fans the all-or-nothing fleet swap over.
        """
        return [(i, self.frontends[i].engine) for i in sorted(self.live)]

    # -- accounting --------------------------------------------------------
    @property
    def report(self) -> FleetReport:
        fin = self.finished
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None
                else 0.0)
        tokens = sum(len(r.tokens) for r in self.records.values())
        ttft = [r.ttft for r in fin if r.ttft is not None]
        tps = [len(r.tokens) / max(r.finished_at - r.submitted_at, 1e-9)
               for r in fin
               if r.tokens and r.finished_at is not None
               and r.submitted_at is not None]
        per = [fe.engine.report for fe in self.frontends]
        return FleetReport(
            engines=len(self.frontends),
            live_engines=len(self.live),
            requests=len(fin),
            tokens_generated=tokens,
            failovers=self.failovers,
            redispatched=self.redispatched,
            swaps=sum(p.swaps for p in per),
            wall_s=wall,
            tokens_per_s=tokens / wall if wall > 0 else 0.0,
            ttft_p50=_pct(ttft, 50), ttft_p95=_pct(ttft, 95),
            tps_p50=_pct(tps, 50), tps_p95=_pct(tps, 95),
            deadline_misses=sum(p.deadline_misses for p in per),
            per_engine=per,
        )
