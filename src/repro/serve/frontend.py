"""Async-style serving front-end: streaming, admission control, health.

``ServeFrontend`` wraps a ``ServeEngine`` with the request-facing half
of the control plane:

* **Streaming** — ``submit`` returns a ``StreamHandle``; iterating it
  yields tokens as the scheduler produces them (the iterator pumps the
  scheduler between yields, so a single-threaded caller still sees
  per-token streaming).  A per-request ``on_token`` callback fires the
  moment each token is sampled, including for requests the caller never
  iterates.
* **Admission control** — the engine's intake queue is bounded
  (``queue_limit``, default = slot count); when it is full, submissions
  park in the front-end's bounded *wait queue* and drain FIFO as slots
  free.  ONLY the retryable ``"capacity"`` rejection is parked —
  structural rejections (empty prompt, oversize, bad budget, unhealthy)
  are re-raised to the caller immediately, because retrying cannot fix
  them.
* **Deadlines** — requests carry ``deadline_s`` (relative to
  admission).  The engine cancels expired slots mid-decode; the
  front-end sweeps its wait queue with the same clock so a request that
  never reached a slot still counts as a deadline miss.
* **Health** — if a ``HeartbeatMonitor`` is wired in, every ``pump``
  checks whether the engine's decode-loop heartbeat went stale and
  flips the engine's health gate: admission stops (``submit`` raises
  ``SubmitRejected("unhealthy")``) while in-flight decode is left
  alone.  When beats resume, the gate reopens automatically.

The front-end is deliberately synchronous + re-entrant (``pump`` is the
event loop's tick), so it composes with any outer loop — the CLI
daemon, ``launch/serve.py``, or a test driving a fake clock.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Deque, Iterator, List, Optional

import numpy as np

from repro.serve.engine import Request, ServeEngine, SubmitRejected


class StreamHandle:
    """Per-request streaming view.

    Iterate to receive tokens as they are generated::

        handle = frontend.submit(prompt, max_new_tokens=32)
        for tok in handle:
            print(tok)

    Iteration pumps the front-end until this request finishes (done,
    expired, or rejected), yielding each new token exactly once.
    ``result()`` blocks (pumps) to completion and returns the Request.
    """

    def __init__(self, frontend: "ServeFrontend", request: Request):
        self.frontend = frontend
        self.request = request

    @property
    def uid(self):
        return self.request.uid

    @property
    def tokens(self) -> List[int]:
        return self.request.tokens

    @property
    def done(self) -> bool:
        return self.request.done or self.request.status == "rejected"

    @property
    def status(self) -> str:
        return self.request.status

    def __iter__(self) -> Iterator[int]:
        i = 0
        while True:
            toks = self.request.tokens
            while i < len(toks):
                yield toks[i]
                i += 1
            if self.done:
                return
            fe = self.frontend
            if fe.engine.idle and not fe.waiting:
                return          # nothing in flight: no more tokens ever
            fe.pump(1)

    def result(self) -> Request:
        for _ in self:
            pass
        return self.request


class ServeFrontend:
    """Admission + streaming + health layer over one ``ServeEngine``.

    ``max_queue`` bounds the wait queue; a capacity rejection with the
    wait queue already full is re-raised to the caller (backpressure all
    the way out).  The engine's own intake queue is bounded to its slot
    count unless the caller configured ``queue_limit`` explicitly.
    """

    def __init__(self, engine: ServeEngine, *, max_queue: int = 64,
                 heartbeat=None, heartbeat_worker: Optional[str] = None):
        self.engine = engine
        if engine.queue_limit is None:
            engine.queue_limit = max(engine.slots, 1)
        if heartbeat is not None:
            engine.heartbeat = heartbeat
            if heartbeat_worker is not None:
                engine.heartbeat_worker = heartbeat_worker
        self.max_queue = max_queue
        self.waiting: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self._uids = itertools.count()

    @property
    def clock(self):
        return self.engine.clock

    # -- admission ---------------------------------------------------------
    def submit(self, prompt=None, *, request: Optional[Request] = None,
               uid=None, max_new_tokens: int = 16, eos_id=None,
               deadline_s: Optional[float] = None, frames=None,
               on_token=None) -> StreamHandle:
        """Admit a request (or park it when the engine is full).

        Returns a ``StreamHandle`` for the (possibly waiting) request.
        Raises ``SubmitRejected`` for non-retryable rejections and for
        capacity rejections once the wait queue itself is full.
        """
        req = request
        if req is None:
            if prompt is None:
                raise ValueError("submit() needs a prompt or a request")
            req = Request(uid=next(self._uids) if uid is None else uid,
                          prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          deadline_s=deadline_s, frames=frames,
                          on_token=on_token)
        self._check_health()
        if req.submitted_at is None:
            # deadline covers wait-queue time too: the clock starts at
            # admission, not at slot assignment
            req.submitted_at = self.clock()
        try:
            self.engine.submit(req)
        except SubmitRejected as e:
            if e.retryable and len(self.waiting) < self.max_queue:
                req.status = "waiting"
                self.waiting.append(req)
            else:
                req.status = "rejected"
                self.rejected.append(req)
                raise
        return StreamHandle(self, req)

    # -- health ------------------------------------------------------------
    def _check_health(self) -> None:
        hb, eng = self.engine.heartbeat, self.engine
        if hb is None:
            return
        if eng.heartbeat_worker in hb.dead_workers():
            if eng.health.healthy:
                eng.set_health(
                    False,
                    f"heartbeat from {eng.heartbeat_worker!r} older than "
                    f"{hb.deadline_s}s — decode loop presumed wedged")
        elif not eng.health.healthy \
                and eng.health.reason.startswith("heartbeat"):
            # beats resumed: reopen the gate we closed (never overrides
            # a health state someone else set for another reason)
            eng.set_health(True)

    # -- the event loop ----------------------------------------------------
    def _expire_waiting(self, out: List[Request]) -> None:
        keep: Deque[Request] = deque()
        while self.waiting:
            req = self.waiting.popleft()
            if (req.deadline_s is not None
                    and self.clock() - req.submitted_at > req.deadline_s):
                self.engine.expire(req)    # books the miss in the report
                out.append(req)
            else:
                keep.append(req)
        self.waiting = keep

    def _drain_waiting(self) -> None:
        while self.waiting:
            req = self.waiting[0]
            try:
                self.engine.submit(req)
            except SubmitRejected as e:
                if e.retryable:
                    return                 # still full: keep FIFO order
                self.waiting.popleft()     # structural: drop, don't retry
                req.status = "rejected"
                self.rejected.append(req)
            else:
                self.waiting.popleft()

    def pump(self, steps: int = 1) -> List[Request]:
        """Advance the control plane ``steps`` scheduler ticks:
        health check → wait-queue deadline sweep → FIFO drain into the
        engine → one engine tick.  Returns requests finished during the
        call (completed or expired)."""
        done: List[Request] = []
        for _ in range(steps):
            self._check_health()
            self._expire_waiting(done)
            self._drain_waiting()
            if self.engine.idle and not self.waiting:
                break
            done.extend(self.engine.step())
        self.finished.extend(done)
        return done

    def drain(self, max_steps: int = 1_000_000) -> List[Request]:
        """Pump until the engine and wait queue are both empty."""
        done: List[Request] = []
        for _ in range(max_steps):
            if self.engine.idle and not self.waiting:
                break
            done.extend(self.pump(1))
        return done

    @property
    def idle(self) -> bool:
        return self.engine.idle and not self.waiting
