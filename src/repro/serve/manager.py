"""Ticket manager: register exported tickets, verify, hot-swap live.

The deployment half of the lottery-ticket story: ``prune --ticket``
exports ``(w_init, masks)`` with the resolved recipe + quantize bits
embedded (PR 5), and this module turns those directories into
*serveable, verified, swappable* artifacts:

* ``load_ticket`` — ticket dir → (rewound params, masks, meta), with
  the stored mask keys/shapes validated against the serving config's
  template FIRST (``import_ticket`` silently skips mismatched keys,
  which would otherwise surface as a deep traceback much later).
* ``TicketManager.register`` — loads a candidate, rejects arch/recipe
  mismatches against the running config (``TicketError`` with a
  machine-readable ``reason``), and records its **accuracy
  fingerprint**: the greedy smoke-decode of a fixed probe prompt
  through a throwaway engine.  Greedy decode is deterministic, so the
  fingerprint pins the ticket's end-to-end numerics (params ⊙ masks,
  tile plans, cache layout) in a handful of tokens.
* ``TicketManager.swap`` — installs the candidate into a LIVE engine as
  a new generation (``ServeEngine.swap``: in-flight requests keep
  decoding on the old ticket, new admissions prefill on the new one),
  re-runs the smoke-decode *through the swapped-in generation*, and
  rolls the generation back if it disagrees with the recorded
  fingerprint.  Traffic is never drained either way.

No ``repro.api`` imports at module level — the manager sits below the
adapter layer (it needs only a params template + prunable predicate +
prefill/decode fns), and ``api.cli`` re-exports ``TicketMismatch`` from
here.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.engine import ServeEngine


class TicketError(RuntimeError):
    """Ticket rejected at registration/verification.

    ``reason``: ``"shape_mismatch"`` (stored masks do not fit the
    serving config's template), ``"arch_mismatch"`` (ticket metadata
    names a different arch), ``"recipe_mismatch"`` (manager requires a
    specific recipe), ``"unknown_ticket"`` (swap of an unregistered
    name)."""

    def __init__(self, reason: str, message: str):
        self.reason = reason
        super().__init__(message)


class TicketMismatch(TicketError):
    """Ticket on disk does not fit the serving parameter template
    (usually pruned at a different --scale or --arch)."""

    def __init__(self, message: str):
        super().__init__("shape_mismatch", message)


def load_ticket(path: str, params_template, prunable,
                arch_name: str = "?"):
    """Ticket dir → (rewound params, masks, meta) shaped like the
    template.  Raises ``TicketMismatch`` when the stored mask
    keys/shapes disagree with ``make_masks(params_template, prunable)``.
    """
    import jax

    from repro.core import lottery
    from repro.core.masks import make_masks, path_str

    masks_tmpl = make_masks(params_template, prunable)
    tmpl_shapes = {}

    def visit(p, leaf):
        if leaf is not None:
            tmpl_shapes[f"m:{path_str(p)}"] = tuple(leaf.shape)
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks_tmpl,
                                     is_leaf=lambda x: x is None)
    data = np.load(os.path.join(path, "ticket.npz"))
    stored = {k: tuple(data[k].shape) for k in data.files
              if k.startswith("m:")}
    if stored != tmpl_shapes:
        missing = sorted(set(tmpl_shapes) - set(stored))
        extra = sorted(set(stored) - set(tmpl_shapes))
        wrong = sorted(k for k in set(stored) & set(tmpl_shapes)
                       if stored[k] != tmpl_shapes[k])
        raise TicketMismatch(
            f"ticket at {path} does not match {arch_name}: "
            f"{len(missing)} masks missing, {len(extra)} unexpected, "
            f"{len(wrong)} wrong-shaped"
            + (f" (e.g. {wrong[0]}: {stored[wrong[0]]} vs "
               f"{tmpl_shapes[wrong[0]]})" if wrong else "")
            + " — was it pruned at a different --scale or --arch?")
    w, m = lottery.import_ticket(path, params_template, masks_tmpl)
    return lottery.rewind(w, m), m, lottery.ticket_meta(path)


@dataclass
class TicketRecord:
    """A registered, verified, fingerprinted ticket."""
    name: str
    path: str
    meta: dict
    params: Any
    masks: Any
    fingerprint: Tuple[int, ...]

    @property
    def recipe_name(self) -> Optional[str]:
        return (self.meta.get("recipe") or {}).get("name")

    @property
    def sparsity(self) -> Optional[float]:
        return self.meta.get("sparsity")


@dataclass
class SwapEvent:
    """Outcome of one hot-swap attempt (kept in ``history``)."""
    ticket: str
    gid: int
    accepted: bool
    reason: str = "ok"
    expected: Tuple[int, ...] = ()
    observed: Tuple[int, ...] = ()
    skipped_tile_fraction: float = 0.0
    engine: Optional[int] = None    # fleet swaps: which engine index


@dataclass
class FleetSwapEvent:
    """Outcome of one all-or-nothing fleet-wide swap.

    ``accepted`` iff EVERY live engine verified the candidate; when any
    engine's smoke-decode disagrees with the fingerprint, the engines
    already swapped are rolled back (``rolled_back``) and the fleet
    keeps serving the previous ticket everywhere — the fleet never
    splits across tickets."""
    ticket: str
    accepted: bool
    events: List[SwapEvent] = field(default_factory=list)
    rolled_back: int = 0
    reason: str = "ok"

    @property
    def gid(self) -> int:
        return self.events[0].gid if self.events else -1


class TicketManager:
    """Registry + verifier + hot-swapper for exported tickets.

    ``probe_prompt``/``probe_tokens`` define the accuracy fingerprint
    (greedy smoke-decode); for encoder-decoder configs a deterministic
    ``probe_frames`` is generated so the probe exercises the full
    frames→tokens lane.  ``expect_recipe`` (optional) pins deployments
    to one recipe name: candidates pruned with anything else are
    rejected at ``register`` time.
    """

    def __init__(self, *, cfg, params_template, prunable,
                 prefill_fn: Callable, decode_fn: Callable,
                 probe_prompt=None, probe_tokens: int = 8,
                 probe_frames=None, expect_recipe: Optional[str] = None):
        self.cfg = cfg
        self.params_template = params_template
        self.prunable = prunable
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        if probe_prompt is None:
            vocab = int(getattr(cfg, "vocab_size", 256) or 256)
            probe_prompt = (np.arange(1, 9) % max(vocab - 1, 1) + 1)
        self.probe_prompt = np.asarray(probe_prompt, np.int32)
        self.probe_tokens = probe_tokens
        if probe_frames is None and getattr(cfg, "is_encoder_decoder",
                                            False):
            rng = np.random.RandomState(0)
            probe_frames = rng.randn(cfg.encoder_seq_len,
                                     cfg.d_model).astype(np.float32) * 0.1
        self.probe_frames = probe_frames
        self.expect_recipe = expect_recipe
        self.tickets: Dict[str, TicketRecord] = {}
        self.active: Optional[str] = None
        self.history: List[SwapEvent] = []

    @classmethod
    def from_adapter(cls, adapter, *, seed: int = 0, **kw):
        """Build a manager for a registry adapter's serving surface."""
        import jax
        prefill_fn, decode_fn = adapter.serve_fns()
        return cls(cfg=adapter.cfg,
                   params_template=adapter.init_params(
                       jax.random.PRNGKey(seed)),
                   prunable=adapter.prunable,
                   prefill_fn=prefill_fn, decode_fn=decode_fn, **kw)

    # -- fingerprinting ----------------------------------------------------
    def _probe_engine(self, params, masks) -> ServeEngine:
        cap = len(self.probe_prompt) + self.probe_tokens + 1
        return ServeEngine(params=params, cfg=self.cfg,
                           prefill_fn=self.prefill_fn,
                           decode_fn=self.decode_fn,
                           batch_slots=1, capacity=cap, masks=masks)

    def fingerprint(self, params, masks) -> Tuple[int, ...]:
        """Greedy smoke-decode of the probe prompt on a throwaway
        engine — the reference the live swapped-in generation must
        reproduce exactly."""
        eng = self._probe_engine(params, masks)
        return tuple(eng.smoke_decode(self.probe_prompt,
                                      self.probe_tokens,
                                      frames=self.probe_frames))

    # -- registration ------------------------------------------------------
    def register(self, name: str, path: str) -> TicketRecord:
        """Load + verify a ticket against the running config.

        Raises ``TicketMismatch`` on shape mismatch and ``TicketError``
        (reasons ``"arch_mismatch"`` / ``"recipe_mismatch"``) on
        metadata disagreement."""
        params, masks, meta = load_ticket(
            path, self.params_template, self.prunable,
            arch_name=getattr(self.cfg, "name", "?"))
        meta = meta or {}
        arch = meta.get("arch")
        cfg_name = getattr(self.cfg, "name", None)
        if arch is not None and cfg_name is not None and arch != cfg_name:
            raise TicketError(
                "arch_mismatch",
                f"ticket {name!r} was pruned on arch {arch!r}; this "
                f"engine serves {cfg_name!r}")
        if self.expect_recipe is not None:
            rname = (meta.get("recipe") or {}).get("name")
            if rname != self.expect_recipe:
                raise TicketError(
                    "recipe_mismatch",
                    f"ticket {name!r} came from recipe {rname!r}; this "
                    f"deployment requires {self.expect_recipe!r}")
        rec = TicketRecord(name=name, path=path, meta=meta,
                           params=params, masks=masks,
                           fingerprint=self.fingerprint(params, masks))
        self.tickets[name] = rec
        return rec

    # -- serving -----------------------------------------------------------
    def make_engine(self, name: str, **engine_kw) -> ServeEngine:
        """Fresh engine serving a registered ticket."""
        rec = self._require(name)
        eng = ServeEngine(params=rec.params, cfg=self.cfg,
                          prefill_fn=self.prefill_fn,
                          decode_fn=self.decode_fn,
                          masks=rec.masks, **engine_kw)
        self.active = name
        return eng

    def _require(self, name: str) -> TicketRecord:
        if name not in self.tickets:
            raise TicketError(
                "unknown_ticket",
                f"ticket {name!r} is not registered "
                f"(have: {sorted(self.tickets)})")
        return self.tickets[name]

    def _swap_engine(self, engine: ServeEngine, name: str,
                     rec: TicketRecord,
                     engine_idx: Optional[int] = None) -> SwapEvent:
        """Install + verify on ONE engine (no manager state touched)."""
        gid = engine.swap(rec.params, masks=rec.masks)
        observed = tuple(engine.smoke_decode(self.probe_prompt,
                                             self.probe_tokens, gid=gid,
                                             frames=self.probe_frames))
        if observed != rec.fingerprint:
            engine.rollback(gid)
            return SwapEvent(
                ticket=name, gid=gid, accepted=False,
                reason="smoke-decode disagrees with recorded accuracy "
                       "fingerprint — rolled back",
                expected=rec.fingerprint, observed=observed,
                skipped_tile_fraction=engine.report.skipped_tile_fraction,
                engine=engine_idx)
        return SwapEvent(
            ticket=name, gid=gid, accepted=True,
            expected=rec.fingerprint, observed=observed,
            skipped_tile_fraction=engine.report.skipped_tile_fraction,
            engine=engine_idx)

    def swap(self, target, name: str):
        """Hot-swap a registered ticket into a live engine/front-end —
        or across a whole fleet.

        Single engine: installs the candidate as a new generation
        (traffic keeps flowing), smoke-decodes the probe THROUGH that
        generation, and rolls back if the output disagrees with the
        fingerprint recorded at registration.  The scheduler is not
        stepped between install and verdict, so a rolled-back
        generation never serves a request.  Returns a ``SwapEvent``.

        Fleet (``target`` exposes ``swap_targets()``, e.g.
        ``serve.fleet.FleetRouter``): the same install+verify fans over
        every live engine, ALL-OR-NOTHING — the first verification
        failure rolls back every engine already swapped, so the fleet
        never serves two tickets at once.  Zero-drain either way:
        in-flight requests finish on the generation that prefilled
        them.  Returns a ``FleetSwapEvent``."""
        rec = self._require(name)
        targets = getattr(target, "swap_targets", None)
        if targets is not None:
            committed: List[Tuple[ServeEngine, int]] = []
            events: List[SwapEvent] = []
            accepted = True
            for idx, engine in targets():
                ev = self._swap_engine(engine, name, rec, engine_idx=idx)
                events.append(ev)
                if not ev.accepted:
                    accepted = False
                    break
                committed.append((engine, ev.gid))
            if accepted:
                self.active = name
                fev = FleetSwapEvent(ticket=name, accepted=True,
                                     events=events)
            else:
                for engine, gid in reversed(committed):
                    engine.rollback(gid)
                fev = FleetSwapEvent(
                    ticket=name, accepted=False, events=events,
                    rolled_back=len(committed),
                    reason=f"engine {events[-1].engine} failed "
                           "verification — fleet rolled back")
            self.history.append(fev)
            return fev
        engine: ServeEngine = getattr(target, "engine", target)
        ev = self._swap_engine(engine, name, rec)
        if ev.accepted:
            self.active = name
        self.history.append(ev)
        return ev
