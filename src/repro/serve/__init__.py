from repro.serve.engine import (Request, ServeEngine,  # noqa: F401
                                ServeReport)
from repro.serve.ticket import PlanStats, build_decode_plan  # noqa: F401
