from repro.serve.engine import (EngineHealth, Request,  # noqa: F401
                                ServeEngine, ServeReport, SubmitRejected)
from repro.serve.fleet import (FleetRecord, FleetReport,  # noqa: F401
                               FleetRouter)
from repro.serve.frontend import ServeFrontend, StreamHandle  # noqa: F401
from repro.serve.manager import (FleetSwapEvent, SwapEvent,  # noqa: F401
                                 TicketError, TicketManager,
                                 TicketMismatch, TicketRecord, load_ticket)
from repro.serve.paging import (BlockPool, PoolError,  # noqa: F401
                                blocks_needed)
from repro.serve.ticket import PlanStats, build_decode_plan  # noqa: F401
