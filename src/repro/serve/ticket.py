"""Pruned-ticket → serving-kernel handoff (re-export shim).

The mask→``TilePlan`` walker lives in ``repro.models.plans``: it
describes the *model's* parameter structure (segments → positions →
attn/mlp projections) and is shared by the serving paths (here — ONE
plan drives both prefill and decode in ``ServeEngine``) and the
training retrain path (``repro.train.plans``), so neither layer has to
import the other.
"""
from repro.kernels.bsmm import GeometryError  # noqa: F401
from repro.models.plans import PlanStats, build_decode_plan  # noqa: F401
