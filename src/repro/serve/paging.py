"""Block-pool allocator for the paged KV cache.

``BlockPool`` is the host-side bookkeeping half of paging: a free-list
of physical block ids over the device-side pools that
``models.transformer.make_paged_caches`` allocates.  The engine owns one
pool per ticket *generation* (pools are part of the generation's cache
pytree, so a hot-swap neither copies nor fragments the old
generation's state — tables indirect, which is also why there is no
defragmentation: any free block serves any request).

Admission is reservation-based so it can be decided at submit/refill
time without deadlock: a request *reserves* ``ceil((prompt + budget) /
BLOCK)`` blocks up front, then draws them down one ``alloc`` at a time
as decode crosses block boundaries.  ``available`` subtracts
outstanding reservations from the free list, so two half-admitted
requests can never strand each other mid-decode — if the reservation
fits, every future ``alloc`` of that request is guaranteed.

Block id 0 (by default) is reserved as the *scratch* block: idle slot
rows in the block table point at it, so the decode kernel's gather
always reads resident memory and inactive-lane appends land somewhere
harmless.  It is never handed out.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


class PoolError(RuntimeError):
    """Violation of pool discipline (double-free, alloc w/o reserve...)."""


class BlockPool:
    """Free-list allocator over ``num_blocks`` physical KV blocks."""

    def __init__(self, num_blocks: int, *, reserved_ids: Tuple[int, ...] = (0,)):
        if num_blocks <= len(reserved_ids):
            raise ValueError(
                f"pool needs > {len(reserved_ids)} blocks, got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self.reserved_ids = tuple(int(i) for i in reserved_ids)
        # LIFO free list → recently-freed blocks are reused first (warm)
        self._free: List[int] = [i for i in range(num_blocks - 1, -1, -1)
                                 if i not in self.reserved_ids]
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}
        self.peak = 0

    # -- accounting ---------------------------------------------------------
    @property
    def live(self) -> int:
        """Blocks currently holding some request's KV state."""
        return sum(len(v) for v in self._owned.values())

    @property
    def outstanding(self) -> int:
        """Reserved-but-not-yet-allocated blocks."""
        return sum(self._reserved.values())

    @property
    def available(self) -> int:
        """Blocks admissible to *new* reservations right now."""
        return len(self._free) - self.outstanding

    def owned(self, uid: int) -> Tuple[int, ...]:
        return tuple(self._owned.get(uid, ()))

    def check(self) -> None:
        """Internal consistency: every block accounted for exactly once."""
        seen = set(self.reserved_ids)
        for pid in self._free:
            if pid in seen:
                raise PoolError(f"block {pid} double-tracked (free)")
            seen.add(pid)
        for uid, pids in self._owned.items():
            for pid in pids:
                if pid in seen:
                    raise PoolError(f"block {pid} double-tracked (uid {uid})")
                seen.add(pid)
        if len(seen) != self.num_blocks:
            raise PoolError(
                f"{self.num_blocks - len(seen)} blocks leaked "
                f"(free={len(self._free)} live={self.live})")
        if self.outstanding > len(self._free):
            raise PoolError("reservations exceed free blocks")

    # -- admission ----------------------------------------------------------
    def can_reserve(self, n: int) -> bool:
        return n <= self.available

    def reserve(self, uid: int, n: int) -> None:
        """Admit ``uid`` with a guaranteed budget of ``n`` blocks total."""
        if n <= 0:
            raise ValueError(f"reservation must be positive, got {n}")
        if uid in self._reserved or uid in self._owned:
            raise PoolError(f"uid {uid} already admitted")
        if not self.can_reserve(n):
            raise PoolError(
                f"cannot reserve {n} blocks ({self.available} available)")
        self._reserved[uid] = n
        self._owned[uid] = []

    # -- alloc / free -------------------------------------------------------
    def alloc(self, uid: int) -> int:
        """Draw one block from ``uid``'s reservation."""
        if uid not in self._owned:
            raise PoolError(f"uid {uid} not admitted")
        if self._reserved.get(uid, 0) <= 0:
            raise PoolError(f"uid {uid} reservation exhausted "
                            f"({len(self._owned[uid])} blocks drawn)")
        pid = self._free.pop()
        self._reserved[uid] -= 1
        self._owned[uid].append(pid)
        self.peak = max(self.peak, self.live)
        return pid

    def release(self, uid: int) -> Tuple[int, ...]:
        """Free everything ``uid`` holds (blocks + remaining reservation)."""
        if uid not in self._owned:
            raise PoolError(f"uid {uid} not admitted")
        pids = self._owned.pop(uid)
        self._reserved.pop(uid, None)
        self._free.extend(reversed(pids))
        return tuple(pids)


def blocks_needed(tokens: int, block: int) -> int:
    """ceil(tokens / block) — the admission formula's block count."""
    return -(-tokens // block)
