"""CNNs for the paper's own experiments: VGG-11/16/19 and ResNet-18.

Conv layers use ``lax.conv_general_dilated`` (NHWC/HWIO); normalisation
is functional BatchNorm (running stats carried in a separate ``state``
pytree, exactly as a production framework must for checkpointing).
These are the models the ReaLPrune paper prunes; ``core.crossbar`` maps
their conv weights onto 128×128 ReRAM crossbars with the paper's im2col
unroll.

Weight layout: conv kernels are (K, K, IC, OC) — the im2col unroll to
the (IC·K·K, OC) crossbar matrix is a pure reshape/transpose.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig, ConvSpec
from repro.kernels.bsmm import plan_matmul
from repro.models.layers import softmax_cross_entropy, xavier


def conv_init(rng, spec: ConvSpec, in_channels: int, dtype=jnp.float32):
    k = spec.kernel
    w = xavier(rng, (k, k, in_channels, spec.out_channels), dtype,
               in_axis=2, out_axis=3)
    return {"w": w}


def bn_init(channels: int, dtype=jnp.float32):
    return {"scale": jnp.ones((channels,), dtype),
            "bias": jnp.zeros((channels,), dtype)}


def bn_state_init(channels: int):
    return {"mean": jnp.zeros((channels,), jnp.float32),
            "var": jnp.ones((channels,), jnp.float32)}


def batchnorm(params, state, x, train: bool, momentum: float = 0.9,
              eps: float = 1e-5):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"], new_state


def conv2d(w, x, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def init_params(rng, cfg: CNNConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, len(cfg.convs) + len(cfg.fc) + 8)
    params = {"convs": [], "bns": [], "shortcuts": {}}
    state = {"bns": [], "shortcut_bns": {}}
    ic = cfg.in_channels
    for i, spec in enumerate(cfg.convs):
        params["convs"].append(conv_init(ks[i], spec, ic, dtype))
        params["bns"].append(bn_init(spec.out_channels, dtype))
        state["bns"].append(bn_state_init(spec.out_channels))
        if spec.residual and (spec.stride != 1 or spec.out_channels != ic):
            # 1x1 projection shortcut
            params["shortcuts"][str(i)] = {
                "w": xavier(jax.random.fold_in(ks[i], 7),
                            (1, 1, ic, spec.out_channels), dtype,
                            in_axis=2, out_axis=3)}
            params["bns_sc_" + str(i)] = bn_init(spec.out_channels, dtype)
            state["shortcut_bns"][str(i)] = bn_state_init(spec.out_channels)
        ic = spec.out_channels
    feat = ic
    params["fc"] = []
    for j, f in enumerate(cfg.fc):
        params["fc"].append(
            {"w": xavier(ks[len(cfg.convs) + j], (feat, f), dtype),
             "b": jnp.zeros((f,), dtype)})
        feat = f
    params["head"] = {
        "w": xavier(ks[-1], (feat, cfg.num_classes), dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype)}
    return params, state


def forward(params, state, cfg: CNNConfig, images, train: bool = False,
            plans=None):
    """images: (B, H, W, C) → logits (B, num_classes), new_state.

    ``ConvSpec.residual`` marks the FIRST conv of a 2-conv basic block
    (ResNet-18); plain convs (VGG) apply conv→BN→ReLU→(pool).

    ``plans`` (from ``repro.train.plans.cnn_train_plan``) routes the FC
    and head matmuls of a pruned ticket through the block-sparse kernel
    — fwd and bwd — during retraining: {"fc": [TilePlan|None, ...],
    "head": TilePlan|None}.  Conv layers stay on XLA's conv path (their
    crossbar accounting lives in ``core.crossbar``).
    """
    plans = plans or {}
    fc_plans = list(plans.get("fc") or ())
    fc_plans += [None] * (len(params["fc"]) - len(fc_plans))
    x = images.astype(params["head"]["w"].dtype)
    new_state = {"bns": [dict(s) for s in state["bns"]],
                 "shortcut_bns": dict(state["shortcut_bns"])}
    i = 0
    while i < len(cfg.convs):
        spec = cfg.convs[i]
        if spec.residual:
            res = x
            y = conv2d(params["convs"][i]["w"], x, spec.stride)
            y, new_state["bns"][i] = batchnorm(
                params["bns"][i], state["bns"][i], y, train)
            y = jax.nn.relu(y)
            y = conv2d(params["convs"][i + 1]["w"], y, cfg.convs[i + 1].stride)
            y, new_state["bns"][i + 1] = batchnorm(
                params["bns"][i + 1], state["bns"][i + 1], y, train)
            if str(i) in params["shortcuts"]:
                res = conv2d(params["shortcuts"][str(i)]["w"], res,
                             spec.stride)
                res, new_state["shortcut_bns"][str(i)] = batchnorm(
                    params["bns_sc_" + str(i)], state["shortcut_bns"][str(i)],
                    res, train)
            x = jax.nn.relu(y + res)
            if cfg.convs[i + 1].pool:
                x = maxpool2(x)
            i += 2
        else:
            y = conv2d(params["convs"][i]["w"], x, spec.stride)
            y, new_state["bns"][i] = batchnorm(
                params["bns"][i], state["bns"][i], y, train)
            x = jax.nn.relu(y)
            if spec.pool:
                x = maxpool2(x)
            i += 1
    # global average pool (CIFAR ResNet/VGG-small convention)
    x = jnp.mean(x, axis=(1, 2))
    for fc, fp in zip(params["fc"], fc_plans):
        x = plan_matmul(x, fc["w"], fp, bias=fc["b"], act="relu")
    logits = plan_matmul(x, params["head"]["w"], plans.get("head"),
                         bias=params["head"]["b"])
    return logits, new_state


def loss_fn(params, state, cfg: CNNConfig, batch, train: bool = True,
            plans=None):
    logits, new_state = forward(params, state, cfg, batch["images"], train,
                                plans=plans)
    ce = softmax_cross_entropy(logits, batch["labels"])
    return ce, (new_state, logits)


def accuracy(params, state, cfg: CNNConfig, images, labels) -> jax.Array:
    logits, _ = forward(params, state, cfg, images, train=False)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
