"""Whisper-style encoder-decoder backbone.

The audio frontend (two conv1d + GELU in real Whisper) is a STUB per the
assignment: inputs are precomputed mel-frame embeddings of shape
(batch, frames, d_model); a learned linear adapter stands in for the
conv stack.  Encoder: non-causal self-attention with sinusoidal
positions.  Decoder: causal self-attention + cross-attention with
learned positions.

Serving: ``prefill`` encodes audio and prefILLS the decoder prompt;
``decode_step`` consumes (self-KV cache, precomputed cross-K/V).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models.layers import (_dtype, apply_norm, embed, embed_init, mlp,
                                 mlp_init, norm_init, sinusoidal_positions,
                                 softmax_cross_entropy, unembed, xavier)


class CrossKV(NamedTuple):
    k: jax.Array  # (B, T_enc, H, hd)
    v: jax.Array


def _enc_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "norm1": norm_init(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim_,
                                  cfg.qkv_bias, dtype),
        "norm2": norm_init(cfg.norm, cfg.d_model, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                        cfg.mlp_bias, dtype),
    }


def _dec_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 4)
    p = _enc_layer_init(ks[0], cfg, dtype)
    p["norm_x"] = norm_init(cfg.norm, cfg.d_model, dtype)
    p["xattn"] = attn_lib.gqa_init(ks[1], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim_,
                                   cfg.qkv_bias, dtype)
    return p


def init_params(rng, cfg: ArchConfig):
    dtype = _dtype(cfg.dtype)
    n_enc, n_dec = cfg.n_encoder_layers, cfg.n_layers
    ks = jax.random.split(rng, n_enc + n_dec + 4)
    enc_layers = [_enc_layer_init(ks[i], cfg, dtype) for i in range(n_enc)]
    dec_layers = [_dec_layer_init(ks[n_enc + i], cfg, dtype)
                  for i in range(n_dec)]
    stack = lambda ls: jax.tree.map(lambda *xs: jnp.stack(xs), *ls)  # noqa
    return {
        "frame_adapter": xavier(ks[-1], (cfg.d_model, cfg.d_model), dtype),
        "embed": embed_init(ks[-2], cfg.padded_vocab, cfg.d_model, dtype),
        "enc": stack(enc_layers),
        "dec": stack(dec_layers),
        "enc_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }


def _mha_full(p, x, cfg, causal):
    """Bidirectional (encoder) or causal self-attention."""
    if causal:
        return attn_lib.gqa_forward(p, x, n_heads=cfg.n_heads,
                                    n_kv_heads=cfg.n_kv_heads,
                                    head_dim=cfg.head_dim_,
                                    rope_theta=cfg.rope_theta)
    B, S, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, cfg.n_heads, cfg.head_dim_)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim_)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim_)
    out = attn_lib.attend(q, k, v, causal=False, q_offset=0)
    return out.reshape(B, S, -1) @ p["wo"]


def _cross_kv(p, enc_out, cfg) -> CrossKV:
    B, T, _ = enc_out.shape
    k = (enc_out @ p["wk"] + p.get("bk", 0)).reshape(B, T, cfg.n_kv_heads,
                                                     cfg.head_dim_)
    v = (enc_out @ p["wv"] + p.get("bv", 0)).reshape(B, T, cfg.n_kv_heads,
                                                     cfg.head_dim_)
    return CrossKV(k, v)


def _cross_attend(p, x, ckv: CrossKV, cfg):
    B, S, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, cfg.n_heads, cfg.head_dim_)
    out = attn_lib.attend(q, ckv.k, ckv.v, causal=False, q_offset=0)
    return out.reshape(B, S, -1) @ p["wo"]


def encode(params, cfg: ArchConfig, frames):
    """frames: (B, T, d_model) stub embeddings → encoder output."""
    x = frames.astype(params["frame_adapter"].dtype) @ params["frame_adapter"]
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, p):
        h = apply_norm(cfg.norm, p["norm1"], x)
        x = x + _mha_full(p["attn"], h, cfg, causal=False)
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _decoder(params, cfg, tokens, enc_out, mode, caches=None, capacity=None):
    # decoder positions come from rope inside the self-attention (the
    # KV-cache index supplies absolute positions during decode)
    x = embed(params["embed"], tokens)
    new_caches = []
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta)
    n_dec = cfg.n_layers
    for i in range(n_dec):
        p = jax.tree.map(lambda t: t[i], params["dec"])
        h = apply_norm(cfg.norm, p["norm1"], x)
        if mode == "forward":
            x = x + _mha_full(p["attn"], h, cfg, causal=True)
        elif mode == "prefill":
            out, kv = attn_lib.gqa_make_cache(p["attn"], h,
                                              capacity=capacity, **kw)
            x = x + out
        else:
            out, kv = attn_lib.gqa_decode(p["attn"], caches[i]["self"], h, **kw)
            x = x + out
        h = apply_norm(cfg.norm, p["norm_x"], x)
        if mode == "decode":
            ckv = caches[i]["cross"]
        else:
            ckv = _cross_kv(p["xattn"], enc_out, cfg)
        x = x + _cross_attend(p["xattn"], h, ckv, cfg)
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + mlp(p["mlp"], h, cfg.act)
        if mode in ("prefill", "decode"):
            new_caches.append({"self": kv, "cross": ckv})
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = unembed(params["embed"], x)
    return logits, (new_caches if new_caches else None)


def forward(params, cfg: ArchConfig, batch):
    """batch: frames (B,T,d), tokens (B,S) → decoder logits (B,S,V)."""
    enc_out = encode(params, cfg, batch["frames"])
    logits, _ = _decoder(params, cfg, batch["tokens"], enc_out, "forward")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch, aux_weight: float = 0.0):
    logits, _ = forward(params, cfg, batch)
    ce = softmax_cross_entropy(logits, batch["labels"],
                               batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def prefill(params, cfg: ArchConfig, batch, capacity: int):
    enc_out = encode(params, cfg, batch["frames"])
    logits, caches = _decoder(params, cfg, batch["tokens"], enc_out,
                              "prefill", capacity=capacity)
    return logits[:, -1:], caches


def decode_step(params, cfg: ArchConfig, caches, token):
    logits, caches = _decoder(params, cfg, token, None, "decode",
                              caches=caches)
    return logits, caches


def cache_spec(cfg: ArchConfig, batch: int, capacity: int):
    dtype = _dtype(cfg.dtype)
    out = []
    for _ in range(cfg.n_layers):
        out.append({
            "self": attn_lib.gqa_cache_spec(batch, capacity, cfg.n_kv_heads,
                                            cfg.head_dim_, dtype),
            "cross": CrossKV(
                k=jax.ShapeDtypeStruct(
                    (batch, cfg.encoder_seq_len, cfg.n_kv_heads,
                     cfg.head_dim_), dtype),
                v=jax.ShapeDtypeStruct(
                    (batch, cfg.encoder_seq_len, cfg.n_kv_heads,
                     cfg.head_dim_), dtype)),
        })
    return out
