"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM + sLSTM (xLSTM).

Forms provided per cell:
  * RG-LRU  — parallel prefix (``associative_scan``) for train/prefill,
              O(1)-state step for decode.
  * mLSTM   — chunkwise-parallel stabilized form for train/prefill
              (matrix memory; carries (C, n, m) across chunks), plus a
              sequential oracle (``mlstm_sequential``) used by tests,
              and an O(1) decode step.
  * sLSTM   — inherently sequential: ``lax.scan`` over time with
              exponential-gate stabilization, O(1) decode step.

All recurrences run in float32 regardless of parameter dtype.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import xavier


# ---------------------------------------------------------------------------
# Block-diagonal (per-head) linear — used by RG-LRU gates and sLSTM recurrence
# ---------------------------------------------------------------------------
def blockdiag_init(rng, width: int, n_blocks: int, dtype=jnp.float32):
    bs = width // n_blocks
    lim = math.sqrt(6.0 / (2 * bs))
    w = jax.random.uniform(rng, (n_blocks, bs, bs), dtype, -lim, lim)
    return {"w": w}


def blockdiag_apply(params, x):
    """x: (..., width) -> (..., width) via per-block matmul."""
    nb, bs, _ = params["w"].shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    ys = jnp.einsum("...nb,nbc->...nc", xs, params["w"])
    return ys.reshape(*x.shape[:-1], nb * bs)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width cw), with ring state for decode
# ---------------------------------------------------------------------------
def conv1d_init(rng, width: int, cw: int, dtype=jnp.float32):
    lim = math.sqrt(1.0 / cw)
    return {"w": jax.random.uniform(rng, (cw, width), dtype, -lim, lim)}


def conv1d_apply(params, u):
    """u: (B, S, w) causal depthwise conv."""
    cw = params["w"].shape[0]
    out = u * params["w"][cw - 1]
    for j in range(1, cw):
        shifted = jnp.pad(u, ((0, 0), (j, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * params["w"][cw - 1 - j]
    return out


def conv1d_step(params, conv_state, u_t):
    """conv_state: (B, cw-1, w) last inputs; u_t: (B, w). Returns (y, state)."""
    cw = params["w"].shape[0]
    hist = jnp.concatenate([conv_state, u_t[:, None, :]], axis=1)  # (B,cw,w)
    y = jnp.einsum("bcw,cw->bw", hist, params["w"])
    return y, hist[:, 1:]


# ===========================================================================
# RG-LRU (Griffin real-gated linear recurrent unit)
# ===========================================================================
class RGLRUState(NamedTuple):
    h: jax.Array           # (B, w) f32
    conv: jax.Array        # (B, cw-1, w)


_RGLRU_C = 8.0


def rglru_init(rng, d_model: int, width: int, n_heads: int, cw: int,
               dtype=jnp.float32):
    ks = jax.random.split(rng, 7)
    # Λ init so that a = exp(-c·softplus(Λ)) lies in (0.9, 0.999) at r=1:
    # softplus(Λ) = -log(a)/c  =>  Λ = log(expm1(-log(a)/c))
    lam_min, lam_max = 0.9, 0.999
    u = jax.random.uniform(ks[0], (width,))
    a = lam_min + u * (lam_max - lam_min)
    lam = jnp.log(jnp.expm1(-jnp.log(a) / _RGLRU_C))
    return {
        "w_in": xavier(ks[1], (d_model, width), dtype),
        "w_gate": xavier(ks[2], (d_model, width), dtype),
        "w_out": xavier(ks[3], (width, d_model), dtype),
        "conv": conv1d_init(ks[4], width, cw, dtype),
        "rg": blockdiag_init(ks[5], width, n_heads, dtype),   # recurrence gate
        "ig": blockdiag_init(ks[6], width, n_heads, dtype),   # input gate
        "lam": lam.astype(jnp.float32),
    }


def _rglru_gates(params, u):
    """u: (..., w) f32 -> (log_a, gated_input) both f32."""
    r = jax.nn.sigmoid(blockdiag_apply(params["rg"], u))
    i = jax.nn.sigmoid(blockdiag_apply(params["ig"], u))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * u)
    return log_a, b


def rglru_forward(params, x, act: str = "gelu"):
    """x: (B,S,d) -> (B,S,d) via conv + RG-LRU + gated output."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_in"]
    u = conv1d_apply(params["conv"], u).astype(jnp.float32)
    log_a, b = _rglru_gates(params, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    y = (h.astype(x.dtype) * gate) @ params["w_out"]
    return y


def rglru_init_state(params, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = params["w_in"].shape[1]
    cw = params["conv"]["w"].shape[0]
    return RGLRUState(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, cw - 1, w), dtype))


def rglru_state_spec(batch: int, width: int, cw: int, dtype):
    return RGLRUState(
        h=jax.ShapeDtypeStruct((batch, width), jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, cw - 1, width), dtype))


def rglru_step(params, state: RGLRUState, x_t):
    """x_t: (B, 1, d) one token. Returns (y_t, new_state)."""
    xt = x_t[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate"])
    u = xt @ params["w_in"]
    u, conv_state = conv1d_step(params["conv"], state.conv, u)
    u = u.astype(jnp.float32)
    log_a, b = _rglru_gates(params, u)
    h = jnp.exp(log_a) * state.h + b
    y = (h.astype(xt.dtype) * gate) @ params["w_out"]
    return y[:, None, :], RGLRUState(h=h, conv=conv_state)


def rglru_make_cache(params, x):
    """Prefill: forward over x and return final recurrent state."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u_raw = x @ params["w_in"]
    u = conv1d_apply(params["conv"], u_raw).astype(jnp.float32)
    log_a, b = _rglru_gates(params, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    y = (h.astype(x.dtype) * gate) @ params["w_out"]
    cw = params["conv"]["w"].shape[0]
    conv_state = u_raw[:, -(cw - 1):, :]
    # left-pad if S < cw-1 (smoke shapes)
    pad = (cw - 1) - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    return y, RGLRUState(h=h[:, -1].astype(jnp.float32), conv=conv_state)


# ===========================================================================
# mLSTM (xLSTM matrix-memory cell) — chunkwise-parallel stabilized
# ===========================================================================
class MLSTMState(NamedTuple):
    C: jax.Array          # (B, H, dk, dv) f32
    n: jax.Array          # (B, H, dk) f32
    m: jax.Array          # (B, H) f32 stabilizer


def mlstm_cell_init(rng, width: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    return {
        "wq": blockdiag_init(ks[0], width, n_heads, dtype),
        "wk": blockdiag_init(ks[1], width, n_heads, dtype),
        "wv": blockdiag_init(ks[2], width, n_heads, dtype),
        "wi": xavier(ks[3], (width, n_heads), dtype),
        "wf": xavier(ks[4], (width, n_heads), dtype),
        "bi": jnp.zeros((n_heads,), jnp.float32),
        "bf": jnp.full((n_heads,), 3.0, jnp.float32),  # open forget gates
    }


def _mlstm_qkvif(params, u, n_heads):
    B, S, w = u.shape
    hd = w // n_heads
    q = blockdiag_apply(params["wq"], u).reshape(B, S, n_heads, hd)
    k = blockdiag_apply(params["wk"], u).reshape(B, S, n_heads, hd)
    v = blockdiag_apply(params["wv"], u).reshape(B, S, n_heads, hd)
    li = (u @ params["wi"]).astype(jnp.float32) + params["bi"]   # (B,S,H)
    lf = jax.nn.log_sigmoid(
        (u @ params["wf"]).astype(jnp.float32) + params["bf"])
    k = k / math.sqrt(hd)
    return (q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), li, lf)


def mlstm_sequential(params, u, n_heads, state: MLSTMState = None):
    """Oracle: step-by-step mLSTM. u: (B,S,w) -> h: (B,S,w)."""
    B, S, w = u.shape
    hd = w // n_heads
    q, k, v, li, lf = _mlstm_qkvif(params, u, n_heads)
    if state is None:
        state = mlstm_init_state(B, n_heads, hd)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs      # (B,H,hd) ×3, (B,H) ×2
        m_new = jnp.maximum(lft + m, lit)
        fp = jnp.exp(lft + m - m_new)[..., None]
        ip = jnp.exp(lit - m_new)[..., None]
        C = fp[..., None] * C + ip[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = fp * n + ip * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))[..., None]
        h = num / den
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, li, lf))
    (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, w)
    return h, MLSTMState(C, n, m)


def mlstm_chunkwise(params, u, n_heads, chunk: int = 128,
                    state: MLSTMState = None):
    """Chunkwise-parallel stabilized mLSTM (exact; tested vs sequential)."""
    B, S, w = u.shape
    hd = w // n_heads
    if S % chunk != 0:
        return mlstm_sequential(params, u, n_heads, state)
    L, nc = chunk, S // chunk
    q, k, v, li, lf = _mlstm_qkvif(params, u, n_heads)
    if state is None:
        state = mlstm_init_state(B, n_heads, hd)

    def rs(t):  # (B,S,...) -> (nc,B,L,...)
        return jnp.moveaxis(t.reshape(B, nc, L, *t.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = rs(q), rs(k), rs(v), rs(li), rs(lf)
    # per-chunk: move head axis forward: (B,L,H,..) -> (B,H,L,..)
    def hfirst(t):
        return jnp.moveaxis(t, 2, 1) if t.ndim >= 4 else jnp.moveaxis(t, -1, 1)

    def chunk_step(carry, xs):
        C0, n0, m0 = carry                      # (B,H,dk,dv),(B,H,dk),(B,H)
        qt, kt, vt, lit, lft = xs               # (B,L,H,hd)... gates (B,L,H)
        qt, kt, vt = hfirst(qt), hfirst(kt), hfirst(vt)   # (B,H,L,hd)
        lit, lft = hfirst(lit), hfirst(lft)                # (B,H,L)
        b = jnp.cumsum(lft, axis=-1)            # inclusive decay sums
        G = b[..., -1:]                          # (B,H,1)
        # stabilizers
        m_intra = jax.lax.cummax(lit - b, axis=2) + b      # max_{s<=t}(li_s - b_s)+b_t
        m_inter = b + m0[..., None]
        m_t = jnp.maximum(m_inter, m_intra)                # (B,H,L)
        # inter-chunk contribution
        q_scaled = qt * jnp.exp(m_inter - m_t)[..., None]
        num_inter = jnp.einsum("bhlk,bhkv->bhlv", q_scaled, C0)
        den_inter = jnp.einsum("bhlk,bhk->bhl", q_scaled, n0)
        # intra-chunk: D[t,s] = exp(b_t - b_s + li_s - m_t) for s<=t
        logD = (b[..., :, None] - b[..., None, :] + lit[..., None, :]
                - m_t[..., :, None])
        tri = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tri, jnp.exp(logD), 0.0)
        scores = jnp.einsum("bhlk,bhsk->bhls", qt, kt) * D
        num = num_inter + jnp.einsum("bhls,bhsv->bhlv", scores, vt)
        # q_t·n_t = q_t·(inter part) + Σ_{s<=t} scores[t,s]
        den = den_inter + jnp.sum(scores, axis=-1)            # (B,H,L)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- carry update ----
        m_next = jnp.maximum(m0 + G[..., 0],
                             jnp.max(lit + G - b, axis=-1))
        scale_old = jnp.exp(m0 + G[..., 0] - m_next)[..., None, None]
        w_s = jnp.exp(G - b + lit - m_next[..., None])        # (B,H,L)
        C1 = C0 * scale_old + jnp.einsum("bhlk,bhlv->bhkv", kt * w_s[..., None], vt)
        n1 = n0 * scale_old[..., 0] + jnp.einsum("bhlk->bhk", kt * w_s[..., None])
        h = jnp.moveaxis(h, 1, 2)               # (B,L,H,hd)
        return (C1, n1, m_next), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (state.C, state.n, state.m),
                                 (qc, kc, vc, lic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, w)
    return h, MLSTMState(C, n, m)


def mlstm_init_state(batch: int, n_heads: int, hd: int) -> MLSTMState:
    return MLSTMState(
        C=jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, n_heads, hd), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32))


def mlstm_state_spec(batch: int, n_heads: int, hd: int):
    return MLSTMState(
        C=jax.ShapeDtypeStruct((batch, n_heads, hd, hd), jnp.float32),
        n=jax.ShapeDtypeStruct((batch, n_heads, hd), jnp.float32),
        m=jax.ShapeDtypeStruct((batch, n_heads), jnp.float32))


def mlstm_step(params, state: MLSTMState, u_t, n_heads):
    """One decode step. u_t: (B, 1, w)."""
    h, new_state = mlstm_sequential(params, u_t, n_heads, state)
    return h, new_state


# ===========================================================================
# sLSTM (xLSTM scalar cell, exponential gating, block-diag recurrence)
# ===========================================================================
class SLSTMState(NamedTuple):
    c: jax.Array   # (B, w) f32
    n: jax.Array
    h: jax.Array
    m: jax.Array


def slstm_cell_init(rng, d_model: int, width: int, n_heads: int,
                    dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    p = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = xavier(ks[i], (d_model, width), dtype)
        p[f"r{g}"] = blockdiag_init(ks[4 + i], width, n_heads, dtype)
        p[f"b{g}"] = (jnp.full((width,), 3.0, jnp.float32) if g == "f"
                      else jnp.zeros((width,), jnp.float32))
    return p


def slstm_init_state(batch: int, width: int) -> SLSTMState:
    z = jnp.zeros((batch, width), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, width), -1e30))


def slstm_state_spec(batch: int, width: int):
    z = jax.ShapeDtypeStruct((batch, width), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=z)


def _slstm_step(params, state: SLSTMState, xi, xf, xz, xo):
    """Pre-computed input projections (B,w) f32; returns (h, state)."""
    c, n, h, m = state
    li = xi + blockdiag_apply(params["ri"], h) + params["bi"]
    lf = jax.nn.log_sigmoid(
        xf + blockdiag_apply(params["rf"], h) + params["bf"])
    z = jnp.tanh(xz + blockdiag_apply(params["rz"], h) + params["bz"])
    o = jax.nn.sigmoid(xo + blockdiag_apply(params["ro"], h) + params["bo"])
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(li - m_new)
    c = fp * c + ip * z
    n = jnp.maximum(fp * n + ip, 1e-6)
    h = o * (c / n)
    return h, SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_forward(params, x, state: SLSTMState = None):
    """x: (B,S,d) -> (B,S,w) sequential scan over time."""
    B, S, _ = x.shape
    w = params["wi"].shape[1]
    if state is None:
        state = slstm_init_state(B, w)
    xi = (x @ params["wi"]).astype(jnp.float32)
    xf = (x @ params["wf"]).astype(jnp.float32)
    xz = (x @ params["wz"]).astype(jnp.float32)
    xo = (x @ params["wo"]).astype(jnp.float32)

    def step(st, inputs):
        h, st = _slstm_step(params, st, *inputs)
        return st, h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xi, xf, xz, xo))
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def slstm_step(params, state: SLSTMState, x_t):
    """One decode step; x_t: (B, 1, d)."""
    h, state = slstm_forward(params, x_t, state)
    return h, state
