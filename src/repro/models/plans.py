"""Mask pytree → per-projection ``TilePlan`` walker.

``build_decode_plan`` walks a mask pytree (same structure as the
parameter pytree, ``None`` on non-prunable leaves) and derives, for
every dense projection a transformer step executes, the static 128×128
tile bitmap — the TPU analogue of the paper's power-gated crossbar map
(Fig. 2).  The resulting plan mirrors ``params["segments"]`` so
``models.transformer`` can thread it layer-by-layer; the SAME structure
drives the serving decode step, the serving prefill, and the training
forward (the retrain loop), which is why this lives next to the models
rather than in ``serve`` or ``train``.

Scanned segments share one traced block body, so per-repeat bitmaps are
**unioned over the scan axis**: a tile is skipped only when it is dead
in every layer of the segment.  That is conservative but exact —
pruned weights are exact zeros, so computing a tile that is dead in
*this* layer (but live in a sibling) only adds zeros.

Geometry is fixed at the MXU's 128×128 here regardless of the pruning
config's crossbar shape: the plan describes what the TPU kernel can
skip, while ``core.crossbar`` keeps accounting in the paper's geometry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import MXU_TILE
from repro.kernels.bsmm import GeometryError, TilePlan, make_tile_plan

# projection keys routed through the bsmm kernel
_ATTN_KEYS = ("wq", "wk", "wv", "wo")
_MLP_KEYS = ("up", "gate", "down")
_EXPERT_KEYS = ("up", "gate", "down")   # stacked (E, d, d_ff) MoE tensors


@dataclass
class PlanStats:
    """Aggregate tile accounting across every routed projection."""
    routed: int = 0             # projections with a bsmm plan
    dense_fallback: int = 0     # prunable projections left dense
    live_tiles: int = 0
    total_tiles: int = 0
    by_layer: List[Tuple[str, int, int]] = field(default_factory=list)

    @property
    def skipped_tile_fraction(self) -> float:
        if self.total_tiles == 0:
            return 0.0
        return 1.0 - self.live_tiles / self.total_tiles


def _union_mask(mask) -> Optional[np.ndarray]:
    """Mask leaf → 2-D union bitmap source.

    Leading axes — the scan-repeat axis of a stacked segment, the
    expert axis of an MoE tensor, or both ((reps, E, K, N)) — are
    union-reduced away: a tile is skipped only when it is dead in every
    layer/expert sharing the traced matmul, which is conservative but
    exact because pruned weights are exact zeros.
    """
    if mask is None:
        return None
    m = np.asarray(mask)
    if m.ndim > 2:
        m = (m != 0).any(axis=tuple(range(m.ndim - 2)))
    if m.ndim != 2:
        return None
    return m


def _plan_group(masks: Dict[str, Any], keys, label: str, stats: PlanStats,
                *, tile: int, interpret: bool,
                strict: bool = False) -> Optional[Dict[str, TilePlan]]:
    group: Dict[str, TilePlan] = {}
    for key in keys:
        m2 = _union_mask(masks.get(key))
        if m2 is None:
            continue
        plan = make_tile_plan(m2, tile=tile, interpret=interpret,
                              strict=strict, where=f"{label}.{key}")
        if plan is None:                  # shape does not tile — stay dense
            stats.dense_fallback += 1
            continue
        group[key] = plan
        stats.routed += 1
        stats.live_tiles += plan.live_tiles
        stats.total_tiles += plan.total_tiles
        stats.by_layer.append((f"{label}.{key}", plan.live_tiles,
                               plan.total_tiles))
    return group or None


def build_decode_plan(masks, *, tile: int = MXU_TILE,
                      interpret: bool = True, strict: bool = False
                      ) -> Tuple[Optional[list], PlanStats]:
    """Mask pytree → (plan mirroring params['segments'], PlanStats).

    Returns ``(None, empty stats)`` when the masks carry no routable
    structure (non-transformer params, MLA attention, MoE-only FFNs —
    those run dense).  ``strict=True`` turns per-projection dense
    fallbacks (shapes that don't tile) into a ``GeometryError`` naming
    the projection — for callers that expect full coverage.  An invalid
    ``tile`` raises ``GeometryError`` either way.
    """
    if tile <= 0:
        raise GeometryError(f"tile edge must be positive, got {tile}",
                            tile=tile, where="build_decode_plan")
    stats = PlanStats()
    if not isinstance(masks, dict) or "segments" not in masks:
        return None, stats
    plan: list = []
    any_entry = False
    for s_idx, pos_trees in enumerate(masks["segments"]):
        seg_plan = []
        for pos, ptree in enumerate(pos_trees):
            entry: Dict[str, Any] = {}
            if not isinstance(ptree, dict):
                seg_plan.append(None)
                continue
            attn = ptree.get("attn")
            # MLA (absorbed decode is einsum-shaped, not a K×N matmul)
            # is skipped: its dict carries w_dq/w_uq instead of wq.
            if isinstance(attn, dict) and "wq" in attn:
                g = _plan_group(attn, _ATTN_KEYS, f"seg{s_idx}.{pos}.attn",
                                stats, tile=tile, interpret=interpret,
                                strict=strict)
                if g:
                    entry["attn"] = g
            ffn = ptree.get("mlp")
            if isinstance(ffn, dict):
                g = _plan_group(ffn, _MLP_KEYS, f"seg{s_idx}.{pos}.mlp",
                                stats, tile=tile, interpret=interpret,
                                strict=strict)
                if g:
                    entry["mlp"] = g
            moe = ptree.get("moe")
            if isinstance(moe, dict):
                # stacked (E, d, d_ff) expert tensors union over the
                # expert axis (and the scan axis) into ONE shared plan:
                # the per-expert matmuls vmap over E with that plan
                g = _plan_group(moe, _EXPERT_KEYS, f"seg{s_idx}.{pos}.moe",
                                stats, tile=tile, interpret=interpret,
                                strict=strict)
                moe_entry: Dict[str, Any] = dict(g) if g else {}
                shared = moe.get("shared")
                if isinstance(shared, dict):
                    sg = _plan_group(shared, _MLP_KEYS,
                                     f"seg{s_idx}.{pos}.moe.shared",
                                     stats, tile=tile, interpret=interpret,
                                     strict=strict)
                    if sg:
                        moe_entry["shared"] = sg
                if moe_entry:
                    entry["moe"] = moe_entry
            any_entry = any_entry or bool(entry)
            seg_plan.append(entry or None)
        plan.append(seg_plan)
    if not any_entry:
        return None, stats
    return plan, stats
