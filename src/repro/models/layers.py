"""Shared transformer layers: norms, MLPs, embeddings, rotary embeddings.

All modules are (init_fn, apply_fn) pairs over plain dict pytrees — no
framework dependency.  Norm/softmax math runs in float32 regardless of
the parameter dtype; matmul outputs stay in the compute dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bsmm import plan_matmul


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initialisers (paper: Xavier/Glorot; LMs conventionally use scaled normal)
# ---------------------------------------------------------------------------
def xavier(rng, shape, dtype=jnp.float32, in_axis=0, out_axis=-1):
    fan_in = shape[in_axis]
    fan_out = shape[out_axis]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, dtype, -limit, limit)


def normal_init(rng, shape, dtype=jnp.float32, stddev=0.02):
    return (jax.random.normal(rng, shape) * stddev).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def apply_norm(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU / plain GELU)
# ---------------------------------------------------------------------------
def mlp_init(rng, d_model: int, d_ff: int, gated: bool, bias: bool = False,
             dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    p = {"up": xavier(ks[0], (d_model, d_ff), dtype),
         "down": xavier(ks[1], (d_ff, d_model), dtype)}
    if gated:
        p["gate"] = xavier(ks[2], (d_model, d_ff), dtype)
    if bias:
        p["up_b"] = jnp.zeros((d_ff,), dtype)
        p["down_b"] = jnp.zeros((d_model,), dtype)
    return p


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


def mlp(params, x, act: str = "silu", plan=None):
    """``plan`` optionally routes up/gate/down through the block-sparse
    kernel (serving OR retraining a pruned ticket); dense otherwise.
    The kernel's custom VJP keeps the routed path differentiable.
    Bias adds and the gate/up activation ride the kernel's fused
    epilogue — one pass over each projection's output."""
    plan = plan or {}
    if "gate" in params:
        up = plan_matmul(x, params["up"], plan.get("up"),
                         bias=params.get("up_b"))
        h = plan_matmul(x, params["gate"], plan.get("gate"), act=act) * up
    else:
        h = plan_matmul(x, params["up"], plan.get("up"),
                        bias=params.get("up_b"), act=act)
    return plan_matmul(h, params["down"], plan.get("down"),
                       bias=params.get("down_b"))


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return {"table": normal_init(rng, (vocab, d), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Project hidden states to logits (optionally with a tied table)."""
    return x @ params["table"].T


def sinusoidal_positions(seq_len: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * 2.0 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2).astype(jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """Mean CE over valid positions; logits (..., V) in any dtype."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
