# Submodules are imported directly (repro.models.attention etc.);
# keep this namespace lazy so partial builds and config-only imports work.
