"""Decoder-only LM assembly for all assigned architectures.

A model is a sequence of *segments*; each segment is a repeating pattern
of block signatures (block kind × is-MoE).  Within a segment the
per-layer parameters are stacked on a leading axis and the segment runs
under ``lax.scan`` — one traced block body per segment regardless of
depth, which keeps multi-hundred-layer compiles tractable and is the
idiomatic pjit pattern (param shardings broadcast over the scan axis).

Block kinds: global attention, sliding-window attention, MLA attention,
RG-LRU, mLSTM, sLSTM.  FFN: dense MLP or MoE per layer.  Everything is
pre-norm residual.

Three entry points per architecture:
  ``forward``      — full-sequence logits (training);
  ``prefill``      — full sequence → last-position logits + caches;
  ``decode_step``  — one token with caches (serving).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ArchConfig, LOCAL_ATTN, MLSTM, RGLRU,
                                SLSTM)
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.layers import (_dtype, apply_norm, embed, embed_init, mlp,
                                 mlp_init, norm_init, softmax_cross_entropy,
                                 unembed, xavier)

# Sharding-constraint hook (set by repro.distributed.sharding at launch)
from repro.models.hooks import constrain, set_constrain_fn  # noqa: F401,E402

# Activation rematerialisation for the training path: recompute block
# internals in the backward pass instead of storing them (needed for
# scan-over-layers at production batch×seq; ~+1/3 fwd FLOPs).
# Policy "full" recomputes everything; "dots" saves matmul outputs
# (jax.checkpoint_policies.checkpoint_dots) — compute↓ memory↑.
_REMAT_TRAIN = True
_REMAT_POLICY = "full"


def set_remat(flag: bool, policy: str = "full"):
    global _REMAT_TRAIN, _REMAT_POLICY
    _REMAT_TRAIN = flag
    _REMAT_POLICY = policy


def _checkpoint(fn):
    if _REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    sigs: Tuple[Tuple[str, bool], ...]   # per-position (kind, is_moe)
    reps: int                            # how many times the pattern repeats
    first_layer: int                     # absolute index of first layer


def layer_signature(cfg: ArchConfig, i: int) -> Tuple[str, bool]:
    kind = cfg.blocks[i]
    is_moe = (cfg.moe is not None and cfg.d_ff > 0
              and kind in (ATTN, LOCAL_ATTN, RGLRU)
              and cfg.moe.is_moe_layer(i))
    return (kind, is_moe)


def segments_of(cfg: ArchConfig) -> List[Segment]:
    sigs = [layer_signature(cfg, i) for i in range(cfg.n_layers)]
    segs: List[Segment] = []
    if cfg.block_pattern is not None:
        P = len(cfg.block_pattern)
        if cfg.moe is not None:
            P = _lcm(P, cfg.moe.moe_every)
        reps = cfg.n_layers // P
        if reps >= 1 and all(sigs[i] == sigs[i % P] for i in range(reps * P)):
            segs.append(Segment(tuple(sigs[:P]), reps, 0))
            start = reps * P
        else:
            start = 0
        for i in range(start, cfg.n_layers):
            segs.append(Segment((sigs[i],), 1, i))
        return segs
    # no explicit pattern: group maximal runs of identical signature
    i = 0
    while i < cfg.n_layers:
        j = i
        while j < cfg.n_layers and sigs[j] == sigs[i]:
            j += 1
        segs.append(Segment((sigs[i],), j - i, i))
        i = j
    return segs


def _lcm(a, b):
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Per-layer parameter init
# ---------------------------------------------------------------------------
def _layer_init(rng, cfg: ArchConfig, sig, dtype):
    kind, is_moe = sig
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    p: dict = {"norm1": norm_init(cfg.norm, d, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        if cfg.mla is not None:
            p["attn"] = attn_lib.mla_init(ks[0], d, cfg.n_heads, cfg.mla, dtype)
        else:
            p["attn"] = attn_lib.gqa_init(ks[0], d, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.head_dim_,
                                          cfg.qkv_bias, dtype)
    elif kind == RGLRU:
        p["rnn"] = rec_lib.rglru_init(ks[0], d, cfg.rnn_width or d,
                                      cfg.n_heads, cfg.conv1d_width, dtype)
    elif kind == MLSTM:
        w = cfg.rnn_width or 2 * d
        cell = rec_lib.mlstm_cell_init(ks[0], w, cfg.n_heads, dtype)
        p["rnn"] = {
            "cell": cell,
            "up": xavier(ks[1], (d, w), dtype),
            "gate": xavier(ks[2], (d, w), dtype),
            "down": xavier(ks[3], (w, d), dtype),
        }
    elif kind == SLSTM:
        cell = rec_lib.slstm_cell_init(ks[0], d, d, cfg.n_heads, dtype)
        # post-cell gated MLP: up d→2·ff (split gate/value), down ff→d
        p["rnn"] = {
            "cell": cell,
            "up": xavier(ks[1], (d, 4 * d), dtype),
            "down": xavier(ks[2], (2 * d, d), dtype),
        }
    if cfg.d_ff > 0 and kind in (ATTN, LOCAL_ATTN, RGLRU):
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        if is_moe:
            p["moe"] = moe_lib.moe_init(ks[3], d, cfg.moe, cfg.gated_mlp, dtype)
        else:
            p["mlp"] = mlp_init(ks[3], d, cfg.d_ff, cfg.gated_mlp,
                                cfg.mlp_bias, dtype)
    return p


def init_params(rng, cfg: ArchConfig):
    """Full parameter pytree (embed, stacked segments, final norm, head)."""
    dtype = _dtype(cfg.dtype)
    ks = jax.random.split(rng, cfg.n_layers + 3)
    layers = [_layer_init(ks[i], cfg, layer_signature(cfg, i), dtype)
              for i in range(cfg.n_layers)]
    segs = segments_of(cfg)
    seg_params = []
    for seg in segs:
        P = len(seg.sigs)
        pos_trees = []
        for pos in range(P):
            idx = [seg.first_layer + r * P + pos for r in range(seg.reps)]
            if seg.reps == 1:
                pos_trees.append(layers[idx[0]])
            else:
                pos_trees.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[layers[i] for i in idx]))
        seg_params.append(pos_trees)
    params = {
        "embed": embed_init(ks[-1], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "segments": seg_params,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {
            "table": xavier(ks[-2], (cfg.padded_vocab, cfg.d_model), dtype,
                            in_axis=1, out_axis=0)}
    if cfg.num_patch_tokens:
        # vlm stub: a learned projection applied to precomputed patch embeds
        params["patch_proj"] = xavier(ks[-3], (cfg.d_model, cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_block(cfg: ArchConfig, sig, p, x, mode: str, cache,
                 capacity: Optional[int], valid_len=None, plan=None,
                 paged=None):
    """Returns (x, new_cache, aux_loss).

    ``valid_len`` (B,) marks right-padded prefill batches (masked
    prefill — attention kinds only); ``plan`` routes decode projections
    through the block-sparse kernel (keys "attn"/"mlp").  ``paged``
    (tables, lens) switches decode onto the paged KV path: ``cache`` is
    then a ``PagedKVCache``/``PagedLatentCache`` pool and attention runs
    the paged Pallas kernel over live blocks only.
    """
    kind, is_moe = sig
    window = cfg.local_window if kind == LOCAL_ATTN else None
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, p["norm1"], x)
    new_cache = cache
    # plans apply on every mode — training forward, prefill, decode —
    # so each projection a pruned ticket executes can skip dead tiles
    plan = plan or {}
    if valid_len is not None and (kind not in (ATTN,) or mode != "prefill"):
        raise ValueError(
            f"valid_len is only supported for full-attention prefill, "
            f"got kind={kind!r} mode={mode!r}; use exact-length prefill "
            "for windowed/recurrent blocks")
    if kind in (ATTN, LOCAL_ATTN):
        if cfg.mla is not None:
            kw = dict(n_heads=cfg.n_heads, mla=cfg.mla,
                      rope_theta=cfg.rope_theta)
            if mode == "forward":
                out = attn_lib.mla_forward(p["attn"], h, **kw)
            elif mode == "prefill":
                out, new_cache = attn_lib.mla_make_cache(
                    p["attn"], h, capacity=capacity, valid_len=valid_len,
                    **kw)
            elif paged is not None:
                out, new_cache = attn_lib.mla_paged_decode(
                    p["attn"], cache, h, tables=paged[0], lens=paged[1],
                    **kw)
            else:
                out, new_cache = attn_lib.mla_decode(p["attn"], cache, h, **kw)
        else:
            kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta)
            if mode == "forward":
                out = attn_lib.gqa_forward(p["attn"], h, window=window,
                                           plan=plan.get("attn"), **kw)
            elif mode == "prefill":
                out, new_cache = attn_lib.gqa_make_cache(
                    p["attn"], h, capacity=capacity, window=window,
                    valid_len=valid_len, plan=plan.get("attn"), **kw)
            elif paged is not None:
                out, new_cache = attn_lib.gqa_paged_decode(
                    p["attn"], cache, h, tables=paged[0], lens=paged[1],
                    plan=plan.get("attn"), **kw)
            else:
                out, new_cache = attn_lib.gqa_decode(
                    p["attn"], cache, h, window=window,
                    plan=plan.get("attn"), **kw)
    elif kind == RGLRU:
        if mode == "forward":
            out = rec_lib.rglru_forward(p["rnn"], h)
        elif mode == "prefill":
            out, new_cache = rec_lib.rglru_make_cache(p["rnn"], h)
        else:
            out, new_cache = rec_lib.rglru_step(p["rnn"], cache, h)
    elif kind == MLSTM:
        rp = p["rnn"]
        u = h @ rp["up"]
        g = h @ rp["gate"]
        if mode == "forward":
            hc, _ = rec_lib.mlstm_chunkwise(rp["cell"], u, cfg.n_heads)
        elif mode == "prefill":
            hc, new_cache = rec_lib.mlstm_chunkwise(rp["cell"], u, cfg.n_heads)
        else:
            hc, new_cache = rec_lib.mlstm_step(rp["cell"], cache, u,
                                               cfg.n_heads)
        out = (hc.astype(x.dtype) * jax.nn.silu(g)) @ rp["down"]
    elif kind == SLSTM:
        rp = p["rnn"]
        if mode in ("forward", "prefill"):
            hc, st = rec_lib.slstm_forward(rp["cell"], h)
            new_cache = st if mode == "prefill" else cache
        else:
            hc, new_cache = rec_lib.slstm_step(rp["cell"], cache, h)
        y = hc.astype(x.dtype) @ rp["up"]
        out = jax.nn.gelu(y[..., : y.shape[-1] // 2]) * y[..., y.shape[-1] // 2:]
        out = out @ rp["down"]
    else:  # pragma: no cover
        raise ValueError(kind)
    x = x + out
    x = constrain(x, ("dp", None, None))
    if cfg.d_ff > 0 and kind in (ATTN, LOCAL_ATTN, RGLRU):
        h2 = apply_norm(cfg.norm, p["norm2"], x)
        if is_moe:
            mo = moe_lib.moe_forward(p["moe"], h2, cfg.moe, cfg.act,
                                     cfg.gated_mlp, plan=plan.get("moe"))
            x = x + mo.y
            aux = mo.aux_loss
        else:
            x = x + mlp(p["mlp"], h2, cfg.act, plan=plan.get("mlp"))
        x = constrain(x, ("dp", None, None))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Segment runners (scan when reps > 1)
# ---------------------------------------------------------------------------
def _run_segments(cfg, params, x, mode, caches, capacity, valid_len=None,
                  plan=None, paged=None):
    """caches: None or same structure as params['segments'] holding states.

    ``plan``: None or a nested list mirroring params['segments'] — one
    (static) per-position dict of tile plans, shared across a segment's
    scanned repeats (the bitmaps are unioned over the scan axis, so a
    tile is skipped only when it is dead in *every* layer of the
    segment — skipping is sound because pruned weights are exact zeros).
    """
    new_caches = []
    total_aux = jnp.zeros((), jnp.float32)
    remat = _REMAT_TRAIN and mode == "forward"
    for s_idx, (seg, pos_trees) in enumerate(zip(segments_of(cfg),
                                                 params["segments"])):
        seg_caches = caches[s_idx] if caches is not None else None
        seg_plan = plan[s_idx] if plan is not None else None

        def super_block(xc, aux_acc, ptrees, cs, seg=seg, seg_plan=seg_plan):
            c_outs = []
            for pos in range(len(seg.sigs)):
                c = cs[pos] if cs is not None else None
                pe = seg_plan[pos] if seg_plan is not None else None
                xc, c_new, aux = _apply_block(cfg, seg.sigs[pos],
                                              ptrees[pos], xc, mode, c,
                                              capacity, valid_len=valid_len,
                                              plan=pe, paged=paged)
                aux_acc = aux_acc + aux
                c_outs.append(c_new)
            return xc, aux_acc, c_outs

        if remat:
            super_block = _checkpoint(super_block)

        if seg.reps == 1:
            cs = seg_caches if seg_caches is not None else None
            x, total_aux, out_caches = super_block(x, total_aux, pos_trees,
                                                   cs)
            new_caches.append(out_caches)
        else:
            def body(carry, xs, super_block=super_block):
                xc, aux_acc = carry
                ptrees, cs = xs
                xc, aux_acc, c_outs = super_block(xc, aux_acc, ptrees, cs)
                ys = c_outs if cs is not None else None
                return (xc, aux_acc), ys

            xs = (pos_trees, seg_caches)
            (x, total_aux), ys = jax.lax.scan(body, (x, total_aux), xs)
            new_caches.append(ys)
    return x, (new_caches if caches is not None else None), total_aux


# ---------------------------------------------------------------------------
# Embedding front end (handles the vlm patch stub)
# ---------------------------------------------------------------------------
def _embed_inputs(cfg, params, batch):
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    if cfg.num_patch_tokens and "patches" in batch:
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(params, cfg: ArchConfig, batch, plan=None):
    """Training forward: full-sequence logits. batch['tokens']: (B, S).

    ``plan`` (from ``repro.train.plans.lm_train_plan``) routes the
    attention/MLP projections through the block-sparse Pallas kernel —
    forward and backward — so the Algorithm-1 retrain loop's cost
    scales with the pruned ticket's live tiles.
    """
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, ("dp", None, None))
    x, _, aux = _run_segments(cfg, params, x, "forward", None, None,
                              plan=plan)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params.get("unembed", params["embed"])
    logits = unembed(head, x)
    logits = constrain(logits, ("dp", None, "model"))
    return logits, aux


def loss_fn(params, cfg: ArchConfig, batch, aux_weight: float = 0.01,
            plan=None):
    logits, aux = forward(params, cfg, batch, plan=plan)
    labels = batch["labels"]
    if cfg.num_patch_tokens and "patches" in batch:
        # loss only over text positions (the tail of the sequence)
        logits = logits[:, -labels.shape[1]:]
    mask = batch.get("loss_mask")
    ce = softmax_cross_entropy(logits, labels, mask)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Cache construction / serving steps
# ---------------------------------------------------------------------------
def _block_cache_spec(cfg: ArchConfig, sig, batch: int, capacity: int, dtype):
    kind, _ = sig
    if kind in (ATTN, LOCAL_ATTN):
        cap = capacity if kind == ATTN else min(cfg.local_window, capacity)
        if cfg.mla is not None:
            return attn_lib.mla_cache_spec(batch, cap, cfg.mla, dtype)
        return attn_lib.gqa_cache_spec(batch, cap, cfg.n_kv_heads,
                                       cfg.head_dim_, dtype)
    if kind == RGLRU:
        return rec_lib.rglru_state_spec(batch, cfg.rnn_width or cfg.d_model,
                                        cfg.conv1d_width, dtype)
    if kind == MLSTM:
        w = cfg.rnn_width or 2 * cfg.d_model
        return rec_lib.mlstm_state_spec(batch, cfg.n_heads, w // cfg.n_heads)
    if kind == SLSTM:
        return rec_lib.slstm_state_spec(batch, cfg.d_model)
    raise ValueError(kind)


def cache_spec(cfg: ArchConfig, batch: int, capacity: int):
    """ShapeDtypeStruct pytree mirroring params['segments'] structure."""
    dtype = _dtype(cfg.dtype)

    def stack_spec(spec, reps):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps, *s.shape), s.dtype), spec)

    out = []
    for seg in segments_of(cfg):
        pos_specs = []
        for sig in seg.sigs:
            s = _block_cache_spec(cfg, sig, batch, capacity, dtype)
            pos_specs.append(s if seg.reps == 1 else stack_spec(s, seg.reps))
        out.append(pos_specs)
    return out


def supports_masked_prefill(cfg: ArchConfig) -> bool:
    """True when ``prefill`` accepts a per-row ``valid_len`` for this
    architecture: every block is full (global) attention, dense FFN, and
    no patch-token prefix.  Windowed/recurrent blocks carry state
    through the padded tail, and MoE routing computes expert capacity
    over *all* positions (pad tokens shift which real tokens are
    dropped), so those need exact-length prefill instead.
    Encoder-decoder configs prefill through ``models.encdec`` (no
    ``valid_len`` lane), so they are exact-length too."""
    try:
        kinds = set(cfg.blocks)
    except Exception:
        return False
    return (kinds == {ATTN} and not cfg.num_patch_tokens
            and cfg.moe is None and not cfg.is_encoder_decoder)


def cache_batch_axes(cfg: ArchConfig, caches):
    """Pytree of ints matching ``caches``: the batch axis of each leaf.

    Scan-stacked segments carry the layer (repeat) axis first, so their
    cache leaves are (reps, B, ...) — batch axis 1; single-layer
    segments are (B, ...) — axis 0.  Scalar cache indices have *no*
    batch axis yet (leaf.ndim == axis); consumers append one.
    ``serve.ServeEngine`` uses this to splice one request's prefill
    caches into the right slot lane of the decode batch.
    """
    out = []
    segs = segments_of(cfg)
    if len(segs) != len(caches):
        raise ValueError(f"cache structure has {len(caches)} segments, "
                         f"config implies {len(segs)}")
    for seg, seg_c in zip(segs, caches):
        a = 1 if seg.reps > 1 else 0
        out.append(jax.tree.map(lambda leaf, a=a: a, seg_c))
    return out


def prefill(params, cfg: ArchConfig, batch, capacity: int, valid_len=None,
            plan=None):
    """Full-sequence prefill → (last-position logits, caches).

    With ``valid_len`` (B,), batch['tokens'] is right-padded and the
    logits are taken at each row's last *valid* position; cache indices
    start at ``valid_len`` so per-request decode is batch-invariant
    (no request ever attends to a batch-mate's padding).

    ``plan`` (from ``repro.models.plans.build_decode_plan`` — the same
    structure decode uses) routes the attention/MLP projections through
    the block-sparse Pallas kernel, so a pruned ticket's prefill cost
    scales with its live tiles exactly like its decode cost.
    """
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, ("dp", None, None))
    x, caches, _ = _run_segments(cfg, params, x, "prefill",
                                 _none_caches(cfg), capacity,
                                 valid_len=valid_len, plan=plan)
    if valid_len is None:
        x_last = x[:, -1:]
    else:
        last = (jnp.asarray(valid_len, jnp.int32) - 1)[:, None, None]
        last = jnp.broadcast_to(last, (x.shape[0], 1, x.shape[2]))
        x_last = jnp.take_along_axis(x, last, axis=1)
    x_last = apply_norm(cfg.norm, params["final_norm"], x_last)
    head = params.get("unembed", params["embed"])
    logits = unembed(head, x_last)
    return logits, caches


def _none_caches(cfg):
    return [[None for _ in seg.sigs] for seg in segments_of(cfg)]


def decode_step(params, cfg: ArchConfig, caches, token, plan=None):
    """token: (B, 1) int32 → (logits (B,1,V), new caches).

    ``plan`` (from ``repro.models.plans.build_decode_plan``) routes the
    dense attention/MLP projections through the block-sparse Pallas
    kernel so decode cost scales with the pruned ticket's live tiles.
    """
    x = embed(params["embed"], token)
    x = constrain(x, ("dp", None, None))
    x, caches, _ = _run_segments(cfg, params, x, "decode", caches, None,
                                 plan=plan)
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params.get("unembed", params["embed"])
    logits = unembed(head, x)
    logits = constrain(logits, ("dp", None, "model"))
    return logits, caches


# ---------------------------------------------------------------------------
# Paged decode: shared block pools instead of per-slot dense caches
# ---------------------------------------------------------------------------
def supports_paged_decode(cfg: ArchConfig) -> bool:
    """True when ``decode_step_paged`` covers this architecture: every
    block is full (global) attention — GQA or MLA — so all per-layer
    decode state is a KV (or latent) pool.  Windowed attention has ring
    semantics and recurrent blocks carry non-KV state, neither of which
    pages; encoder-decoder archs decode through ``models.encdec``."""
    try:
        kinds = set(cfg.blocks)
    except Exception:
        return False
    return kinds == {ATTN} and not cfg.is_encoder_decoder


def paged_cache_spec(cfg: ArchConfig, num_blocks: int):
    """ShapeDtypeStruct pytree mirroring params['segments']: one block
    pool per attention layer (scan-stacked segments get a leading reps
    axis, same convention as ``cache_spec``)."""
    if not supports_paged_decode(cfg):
        raise ValueError(f"paged decode unsupported for blocks={cfg.blocks}")
    dtype = _dtype(cfg.dtype)

    def block_spec():
        if cfg.mla is not None:
            return attn_lib.mla_paged_spec(num_blocks, cfg.mla, dtype)
        return attn_lib.gqa_paged_spec(num_blocks, cfg.n_kv_heads,
                                       cfg.head_dim_, dtype)

    def stack_spec(spec, reps):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps, *s.shape), s.dtype), spec)

    out = []
    for seg in segments_of(cfg):
        pos_specs = []
        for _sig in seg.sigs:
            s = block_spec()
            pos_specs.append(s if seg.reps == 1 else stack_spec(s, seg.reps))
        out.append(pos_specs)
    return out


def make_paged_caches(cfg: ArchConfig, num_blocks: int):
    """Zero-initialised block pools (see ``paged_cache_spec``)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_cache_spec(cfg, num_blocks))


def adopt_prefill(cfg: ArchConfig, paged_caches, dense_caches, blocks):
    """Scatter one request's dense prefill caches into pool blocks.

    ``dense_caches``: output of ``prefill`` for a single request (B=1,
    capacity == padded prefill length S).  ``blocks``: (⌈S/BLOCK⌉,)
    int32 physical block ids (logical order; ids past the real length
    may be the scratch block).  Returns updated ``paged_caches``.
    Layer-for-layer the adopt runs per-segment, vmapped over scanned
    repeats, mirroring the structure conventions of ``cache_spec``.
    """
    blocks = jnp.asarray(blocks, jnp.int32)
    if cfg.mla is not None:
        adopt = attn_lib.mla_paged_adopt
    else:
        adopt = attn_lib.gqa_paged_adopt
    out = []
    segs = segments_of(cfg)
    if len(segs) != len(paged_caches) or len(segs) != len(dense_caches):
        raise ValueError("cache structure does not match config segments")
    for seg, seg_p, seg_d in zip(segs, paged_caches, dense_caches):
        pos_out = []
        for pc, dc in zip(seg_p, seg_d):
            if seg.reps == 1:
                pos_out.append(adopt(pc, dc, blocks))
            else:
                # drop the scalar cache index from vmap (no reps axis
                # semantics needed for adopt — only k/v rows matter)
                if cfg.mla is None:
                    def one(kp, vp, k, v):
                        return adopt(attn_lib.PagedKVCache(kp, vp),
                                     attn_lib.KVCache(k, v, None), blocks)
                    new = jax.vmap(one)(pc.k_pool, pc.v_pool, dc.k, dc.v)
                else:
                    def one(pool, c_kv, k_rope):
                        return adopt(attn_lib.PagedLatentCache(pool),
                                     attn_lib.MLACache(c_kv, k_rope, None),
                                     blocks)
                    new = jax.vmap(one)(pc.pool, dc.c_kv, dc.k_rope)
                pos_out.append(new)
        out.append(pos_out)
    return out


def decode_step_paged(params, cfg: ArchConfig, caches, token, tables, lens,
                      plan=None):
    """Paged decode step: token (B,1) int32, block ``tables`` (B, NB)
    int32, per-slot ``lens`` (B,) int32 → (logits (B,1,V), new pools).

    Every attention layer appends the new token's KV into its pool at
    ``tables[b, lens[b]//BLOCK]`` and attends over ``lens[b]+1`` tokens
    through the paged Pallas kernel — bytes read scale with live
    context, not allocated capacity.
    """
    tables = jnp.asarray(tables, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    x = embed(params["embed"], token)
    x = constrain(x, ("dp", None, None))
    x, caches, _ = _run_segments(cfg, params, x, "decode", caches, None,
                                 plan=plan, paged=(tables, lens))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    head = params.get("unembed", params["embed"])
    logits = unembed(head, x)
    logits = constrain(logits, ("dp", None, "model"))
    return logits, caches
