"""Attention: GQA (full + sliding-window) and DeepSeek-style MLA.

Memory-efficient by construction: training/prefill attention scans over
query blocks (only one block's score matrix is live at a time — flash
semantics, exact math), sliding-window attention uses the two-chunk
trick (exact for window == chunk).  Decode operates on a KV cache; for
MLA the compressed-latent "absorption" form is used so the cache stores
(kv_lora + rope) floats per token instead of H*(dn+dv).

Softmax/scores in float32; inputs/outputs in the compute dtype.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import bsmm
from repro.kernels.paged_attention import BLOCK_TOKENS, paged_attention
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init, xavier


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def gqa_init(rng, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
             qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": xavier(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": xavier(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": xavier(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": xavier(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def mla_init(rng, d_model: int, n_heads: int, mla, dtype=jnp.float32):
    ks = jax.random.split(rng, 7)
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    return {
        "w_dq": xavier(ks[0], (d_model, mla.q_lora_rank), dtype),
        "q_norm": rmsnorm_init(mla.q_lora_rank, dtype),
        "w_uq": xavier(ks[1], (mla.q_lora_rank, n_heads * (dn + dr)), dtype),
        "w_dkv": xavier(ks[2], (d_model, mla.kv_lora_rank + dr), dtype),
        "kv_norm": rmsnorm_init(mla.kv_lora_rank, dtype),
        "w_uk": xavier(ks[3], (mla.kv_lora_rank, n_heads * dn), dtype),
        "w_uv": xavier(ks[4], (mla.kv_lora_rank, n_heads * dv), dtype),
        "wo": xavier(ks[5], (n_heads * dv, d_model), dtype),
    }


# ---------------------------------------------------------------------------
# Core block-scanned attention (exact, flash memory profile)
# ---------------------------------------------------------------------------
def _grouped_scores(q, k):
    """q: (B,Sq,Hkv,G,hd)  k: (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk) float32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(w, v):
    """w: (B,Hkv,G,Sq,Sk)  v: (B,Sk,Hkv,hd) -> (B,Sq,Hkv,G,hd)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))


def attend(q, k, v, *, causal: bool, q_offset, scale: Optional[float] = None,
           kv_valid_len=None):
    """Exact attention for one query block against full keys.

    q: (B,Sq,Hq,hd)  k,v: (B,Sk,Hkv,hd).  q_offset: global position of
    q[0] (int or traced scalar).  kv_valid_len: mask keys >= this length
    — a scalar, or a (B,) vector for per-row (per-slot) valid lengths.
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = _grouped_scores(qg, k) * scale            # (B,Hkv,G,Sq,Sk) f32
    kpos = jnp.arange(Sk)
    qpos = q_offset + jnp.arange(Sq)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if kv_valid_len is not None:
        kvl = jnp.asarray(kv_valid_len)
        if kvl.ndim == 0:
            mask &= (kpos < kvl)[None, :]
        else:                                  # per-row: (B,) → (B,Sq,Sk)
            mask = mask[None] & (kpos[None, :] < kvl[:, None])[:, None, :]
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(w, v)                           # (B,Sq,Hkv,G,dv)
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def causal_attention(q, k, v, *, block_q: int = 512, q_offset: int = 0):
    """Causal self-attention, scanning query blocks (exact, low-memory)."""
    B, S, Hq, hd = q.shape
    if S <= block_q:
        return attend(q, k, v, causal=True, q_offset=q_offset)
    nb = S // block_q
    assert S % block_q == 0, (S, block_q)
    qb = q.reshape(B, nb, block_q, Hq, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, qblk = args
        out = attend(qblk, k, v, causal=True, q_offset=q_offset + i * block_q)
        return carry, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nb), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, v.shape[-1])


def sliding_window_attention(q, k, v, *, window: int, q_offset: int = 0):
    """Exact sliding-window causal attention via the two-chunk trick.

    Each query chunk (size == window) attends to its own and the
    previous key chunk with a relative-position mask; token i sees keys
    in (i-window, i].  Requires S % window == 0 (or S <= window).
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    if S <= window:
        return causal_attention(q, k, v, q_offset=q_offset)
    assert S % window == 0, (S, window)
    nb = S // window
    W = window
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, nb, W, Hq, hd)
    kb = k.reshape(B, nb, W, Hkv, hd)
    vb = v.reshape(B, nb, W, Hkv, hd)
    # previous chunk (chunk -1 is zeros and fully masked)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    qpos = jnp.arange(W)
    kpos_prev = jnp.arange(-W, 0)
    kpos_self = jnp.arange(W)
    # (q, k) allowed iff 0 <= q - k < W  (within-window causal)
    def mk_mask(kpos):
        d = qpos[:, None] - kpos[None, :]
        return (d >= 0) & (d < W)
    mask = jnp.concatenate([mk_mask(kpos_prev), mk_mask(kpos_self)], axis=1)
    first_mask = jnp.concatenate(
        [jnp.zeros((W, W), bool), mk_mask(kpos_self)], axis=1)

    def chunk(args):
        qc, kp, kc, vp, vc, m = args
        kcat = jnp.concatenate([kp, kc], axis=1)       # (B,2W,Hkv,hd)
        vcat = jnp.concatenate([vp, vc], axis=1)
        qg = qc.reshape(B, W, Hkv, G, hd)
        s = _grouped_scores(qg, kcat) * scale
        s = jnp.where(m[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = _grouped_out(w, vcat)
        return o.reshape(B, W, Hq, hd).astype(q.dtype)

    def body(carry, args):
        i, qc, kp, kc, vp, vc = args
        m = jnp.where(i == 0, first_mask, mask)
        return carry, chunk((qc, kp, kc, vp, vc, m))

    xs = (jnp.arange(nb), qb.transpose(1, 0, 2, 3, 4),
          k_prev.transpose(1, 0, 2, 3, 4), kb.transpose(1, 0, 2, 3, 4),
          v_prev.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4))
    _, outs = jax.lax.scan(body, None, xs)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, hd)


# ---------------------------------------------------------------------------
# GQA block: forward (train/prefill) and decode
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array          # (B, C, Hkv, hd)
    v: jax.Array          # (B, C, Hkv, hd)
    index: jax.Array      # () int32 — number of tokens already written


def gqa_cache_spec(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
                   dtype):
    zeros = jax.ShapeDtypeStruct((batch, capacity, n_kv_heads, head_dim), dtype)
    return KVCache(k=zeros, v=zeros, index=jax.ShapeDtypeStruct((), jnp.int32))


def gqa_qkv(params, x, *, n_heads, n_kv_heads, head_dim, positions,
            rope_theta, plan=None):
    B, S, _ = x.shape
    plan = plan or {}
    q = bsmm.plan_matmul(x, params["wq"], plan.get("wq"))
    k = bsmm.plan_matmul(x, params["wk"], plan.get("wk"))
    v = bsmm.plan_matmul(x, params["wv"], plan.get("wv"))
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_forward(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                window: Optional[int] = None, block_q: int = 512,
                plan=None):
    """Training / prefill self-attention over a full sequence.

    ``plan`` routes the q/k/v/o projections through the block-sparse
    kernel during *retraining* of a pruned ticket (keys "wq"/"wk"/"wv"/
    "wo" → ``TilePlan``); the custom VJP keeps gradients block-sparse
    too, so every retrain epoch gets cheaper as tiles die.
    """
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = gqa_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                      head_dim=head_dim, positions=positions,
                      rope_theta=rope_theta, plan=plan)
    if window is not None:
        out = sliding_window_attention(q, k, v, window=window)
    else:
        out = causal_attention(q, k, v, block_q=block_q)
    return bsmm.plan_matmul(out.reshape(B, S, n_heads * head_dim),
                            params["wo"], (plan or {}).get("wo"))


def gqa_make_cache(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                   capacity: int, window: Optional[int] = None,
                   block_q: int = 512, valid_len=None, plan=None):
    """Prefill: returns (attn_out_projected, KVCache).

    ``valid_len`` (B,) marks right-padded batches: tokens at positions
    ≥ valid_len[b] are padding.  Causality already keeps real queries
    from seeing the padded tail, so only the cache bookkeeping changes —
    the per-row index starts at ``valid_len`` instead of S, and decode
    masks (then overwrites) the pad keys above it.  Requires S ≤
    capacity and full (non-windowed) attention.

    ``plan`` routes the q/k/v/o projections through the block-sparse
    kernel (keys "wq"/"wk"/"wv"/"wo" → ``TilePlan``) — the same plan
    decode uses, so pruned tickets skip dead tiles in prefill too.
    """
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = gqa_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                      head_dim=head_dim, positions=positions,
                      rope_theta=rope_theta, plan=plan)
    if valid_len is not None and (window is not None or S > capacity):
        raise ValueError("valid_len prefill needs full attention with "
                         f"S <= capacity, got S={S}, capacity={capacity}, "
                         f"window={window}")
    if window is not None:
        out = sliding_window_attention(q, k, v, window=window)
        keep = min(window, capacity, S)
    else:
        out = causal_attention(q, k, v, block_q=block_q)
        keep = min(S, capacity)
    kc = jnp.zeros((B, capacity, *k.shape[2:]), k.dtype)
    vc = jnp.zeros_like(kc)
    kc = jax.lax.dynamic_update_slice(kc, k[:, S - keep:], (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v[:, S - keep:], (0, 0, 0, 0))
    if valid_len is None:
        index = jnp.asarray(S, jnp.int32)
    else:
        index = jnp.asarray(valid_len, jnp.int32).reshape(B)
    cache = KVCache(kc, vc, index)
    proj = bsmm.plan_matmul(out.reshape(B, S, n_heads * head_dim),
                            params["wo"], (plan or {}).get("wo"))
    return proj, cache


def gqa_decode(params, cache: KVCache, x, *, n_heads, n_kv_heads, head_dim,
               rope_theta, window: Optional[int] = None, plan=None):
    """One decode step. x: (B, 1, d).  Ring-buffer writes for windows.

    ``cache.index`` may be a scalar (whole batch in lockstep) or a (B,)
    vector (continuous batching: every slot at its own position).
    ``plan`` optionally routes the q/k/v/o projections through the
    block-sparse kernel (keys "wq"/"wk"/"wv"/"wo" → ``TilePlan``).
    """
    B, S, _ = x.shape
    assert S == 1
    capacity = cache.k.shape[1]
    pos = cache.index        # () or (B,): absolute position of the new token
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.broadcast_to(pos[None],
                                                               (B, 1))
    q, k, v = gqa_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                      head_dim=head_dim, positions=positions,
                      rope_theta=rope_theta, plan=plan)
    if window is None:
        slot = jnp.minimum(pos, capacity - 1)
    else:
        slot = pos % capacity
    if per_slot:
        kc = cache.k.at[jnp.arange(B), slot].set(k[:, 0])
        vc = cache.v.at[jnp.arange(B), slot].set(v[:, 0])
    else:
        kc = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    # valid cache entries: all slots < min(pos+1, capacity)
    valid = jnp.minimum(pos + 1, capacity)
    out = attend(q, kc, vc, causal=False, q_offset=0, kv_valid_len=valid)
    proj = bsmm.plan_matmul(out.reshape(B, 1, n_heads * head_dim),
                            params["wo"], (plan or {}).get("wo"))
    return proj, KVCache(kc, vc, pos + 1)


# ---------------------------------------------------------------------------
# Paged KV cache (GQA): shared block pool + per-sequence block tables
# ---------------------------------------------------------------------------
class PagedKVCache(NamedTuple):
    """Pool-resident KV state for one attention layer.

    Unlike ``KVCache`` there is no per-sequence capacity axis: all
    sequences share one pool of ``BLOCK_TOKENS``-token blocks, and the
    *engine* owns the indirection (block tables + per-slot lengths,
    passed into every call).  Decode bandwidth therefore scales with
    live context, not allocated capacity — the KV analogue of the
    bsmm live-tile story.
    """
    k_pool: jax.Array     # (P, BLOCK_TOKENS, Hkv, hd)
    v_pool: jax.Array     # (P, BLOCK_TOKENS, Hkv, hd)


def gqa_paged_spec(num_blocks: int, n_kv_heads: int, head_dim: int, dtype,
                   block: int = BLOCK_TOKENS):
    zeros = jax.ShapeDtypeStruct((num_blocks, block, n_kv_heads, head_dim),
                                 dtype)
    return PagedKVCache(k_pool=zeros, v_pool=zeros)


def gqa_paged_adopt(paged: PagedKVCache, cache: KVCache, blocks):
    """Scatter one request's dense prefill cache into pool blocks.

    ``cache`` is a single-request prefill cache (B=1, capacity == the
    padded prefill length S); ``blocks`` (⌈S/BLOCK⌉,) int32 physical
    ids, logical order.  Entries past the request's real length may
    point at the engine's scratch block — padded keys land there (and
    in the tail of the last real block), where per-length masking keeps
    them invisible, exactly like ``valid_len`` masking on the dense
    path.
    """
    kp, vp = paged.k_pool, paged.v_pool
    S = cache.k.shape[1]
    T = kp.shape[1]
    nb = blocks.shape[0]
    if nb != -(-S // T):
        raise ValueError(f"adopt needs ceil({S}/{T}) block ids, got {nb}")
    for i in range(nb):
        w = min(T, S - i * T)
        kp = kp.at[blocks[i], :w].set(cache.k[0, i * T:i * T + w])
        vp = vp.at[blocks[i], :w].set(cache.v[0, i * T:i * T + w])
    return PagedKVCache(kp, vp)


def gqa_paged_decode(params, cache: PagedKVCache, x, *, n_heads, n_kv_heads,
                     head_dim, rope_theta, tables, lens, plan=None,
                     interpret=None):
    """One paged decode step.  x: (B, 1, d).

    ``tables`` (B, NB) int32 block tables, ``lens`` (B,) int32 tokens
    already written per sequence — the new token is appended at logical
    position ``lens[b]`` (block ``lens[b] // BLOCK`` must already be
    allocated; idle rows point at the scratch block) and attention runs
    over ``lens + 1`` tokens via the paged Pallas kernel.  ``plan``
    routes the q/k/v/o projections through the block-sparse kernel as
    on the dense path.
    """
    B, S, _ = x.shape
    assert S == 1
    pos = jnp.asarray(lens, jnp.int32)             # (B,)
    q, k, v = gqa_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                      head_dim=head_dim, positions=pos[:, None],
                      rope_theta=rope_theta, plan=plan)
    T = cache.k_pool.shape[1]
    blk = tables[jnp.arange(B), pos // T]          # physical block per row
    off = pos % T
    kp = cache.k_pool.at[blk, off].set(k[:, 0])
    vp = cache.v_pool.at[blk, off].set(v[:, 0])
    out = paged_attention(q[:, 0], kp, vp, tables, pos + 1,
                          scale=1.0 / math.sqrt(head_dim),
                          interpret=interpret)
    proj = bsmm.plan_matmul(out.reshape(B, 1, n_heads * head_dim),
                            params["wo"], (plan or {}).get("wo"))
    return proj, PagedKVCache(kp, vp)


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, C, kv_lora_rank)
    k_rope: jax.Array     # (B, C, qk_rope_head_dim)
    index: jax.Array


def mla_cache_spec(batch: int, capacity: int, mla, dtype):
    return MLACache(
        c_kv=jax.ShapeDtypeStruct((batch, capacity, mla.kv_lora_rank), dtype),
        k_rope=jax.ShapeDtypeStruct((batch, capacity, mla.qk_rope_head_dim), dtype),
        index=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _mla_qkv_latent(params, x, mla, n_heads, rope_theta, positions):
    """Shared front end: per-head q (nope+rope), latent c_kv, shared k_rope."""
    B, S, _ = x.shape
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    cq = rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (cq @ params["w_uq"]).reshape(B, S, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    dkv = x @ params["w_dkv"]                       # (B,S,r_kv+dr)
    c_kv = rmsnorm(params["kv_norm"], dkv[..., :mla.kv_lora_rank])
    k_rope = apply_rope(dkv[..., mla.kv_lora_rank:][:, :, None, :],
                        positions, rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params, x, *, n_heads, mla, rope_theta, block_q: int = 512):
    """Training/prefill MLA: expand latents to per-head K/V, attend."""
    B, S, _ = x.shape
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_latent(
        params, x, mla, n_heads, rope_theta, positions)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, n_heads, dn)
    v = (c_kv @ params["w_uv"]).reshape(B, S, n_heads, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, n_heads, dr))],
        axis=-1)
    # causal_attention scales by 1/sqrt(dn+dr) internally — the MLA scale.
    out = causal_attention(q, k, v, block_q=block_q)
    return out.reshape(B, S, n_heads * dv) @ params["wo"]


def mla_make_cache(params, x, *, n_heads, mla, rope_theta, capacity: int,
                   block_q: int = 512, valid_len=None):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    _, _, c_kv, k_rope = _mla_qkv_latent(params, x, mla, n_heads, rope_theta,
                                         positions)
    out = mla_forward(params, x, n_heads=n_heads, mla=mla,
                      rope_theta=rope_theta, block_q=block_q)
    if valid_len is not None and S > capacity:
        raise ValueError(f"valid_len prefill needs S <= capacity, "
                         f"got S={S}, capacity={capacity}")
    keep = min(S, capacity)
    cc = jnp.zeros((B, capacity, mla.kv_lora_rank), x.dtype)
    kr = jnp.zeros((B, capacity, mla.qk_rope_head_dim), x.dtype)
    cc = jax.lax.dynamic_update_slice(cc, c_kv[:, S - keep:], (0, 0, 0))
    kr = jax.lax.dynamic_update_slice(kr, k_rope[:, S - keep:], (0, 0, 0))
    if valid_len is None:
        index = jnp.asarray(S, jnp.int32)
    else:
        index = jnp.asarray(valid_len, jnp.int32).reshape(B)
    return out, MLACache(cc, kr, index)


def mla_decode(params, cache: MLACache, x, *, n_heads, mla, rope_theta):
    """Absorbed-form MLA decode: scores/values in the latent space.

    ``cache.index`` may be scalar or (B,) — see ``gqa_decode``.
    """
    B, S, _ = x.shape
    assert S == 1
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    capacity = cache.c_kv.shape[1]
    pos = cache.index
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.broadcast_to(pos[None],
                                                               (B, 1))
    q_nope, q_rope, c_new, kr_new = _mla_qkv_latent(
        params, x, mla, n_heads, rope_theta, positions)
    slot = jnp.minimum(pos, capacity - 1)
    if per_slot:
        cc = cache.c_kv.at[jnp.arange(B), slot].set(c_new[:, 0])
        kr = cache.k_rope.at[jnp.arange(B), slot].set(kr_new[:, 0])
    else:
        cc = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (0, slot, 0))
        kr = jax.lax.dynamic_update_slice(cache.k_rope, kr_new, (0, slot, 0))
    # absorb W_uk into q:  q_lat[b,h,r] = sum_dn q_nope · W_uk[r, h*dn+dn']
    w_uk = params["w_uk"].reshape(r, n_heads, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, cc.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        kr.astype(jnp.float32))
    scores = (s_lat + s_rope) / math.sqrt(dn + dr)
    n_valid = jnp.minimum(pos + 1, capacity)       # () or (B,)
    if per_slot:
        n_valid = n_valid[:, None, None]
    valid = jnp.arange(capacity)[None, None, :] < n_valid
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", w, cc.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(r, n_heads, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, n_heads * dv).astype(x.dtype)
    return out @ params["wo"], MLACache(cc, kr, pos + 1)


# ---------------------------------------------------------------------------
# Paged MLA: latent rows (c_kv ‖ k_rope) in a shared block pool
# ---------------------------------------------------------------------------
class PagedLatentCache(NamedTuple):
    """Paged absorbed-MLA state: one pool of latent rows per layer.

    Each token stores ``concat(c_kv, k_rope)`` — width ``r + dr`` — as a
    single "kv head".  The same paged kernel serves it via ``v_dim=r``:
    values are the first ``r`` lanes of each key row, so scores and
    context both happen in the latent space, exactly like ``mla_decode``.
    """
    pool: jax.Array       # (P, BLOCK_TOKENS, 1, kv_lora_rank + rope_dim)


def mla_paged_spec(num_blocks: int, mla, dtype, block: int = BLOCK_TOKENS):
    width = mla.kv_lora_rank + mla.qk_rope_head_dim
    return PagedLatentCache(
        pool=jax.ShapeDtypeStruct((num_blocks, block, 1, width), dtype))


def mla_paged_adopt(paged: PagedLatentCache, cache: MLACache, blocks):
    """Scatter one request's dense MLA prefill cache into pool blocks."""
    pool = paged.pool
    S = cache.c_kv.shape[1]
    T = pool.shape[1]
    nb = blocks.shape[0]
    if nb != -(-S // T):
        raise ValueError(f"adopt needs ceil({S}/{T}) block ids, got {nb}")
    rows = jnp.concatenate([cache.c_kv[0], cache.k_rope[0]], axis=-1)
    for i in range(nb):
        w = min(T, S - i * T)
        pool = pool.at[blocks[i], :w, 0].set(rows[i * T:i * T + w])
    return PagedLatentCache(pool)


def mla_paged_decode(params, cache: PagedLatentCache, x, *, n_heads, mla,
                     rope_theta, tables, lens, interpret=None):
    """One paged absorbed-MLA decode step.  See ``gqa_paged_decode``."""
    B, S, _ = x.shape
    assert S == 1
    dn, dr, dv = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    pos = jnp.asarray(lens, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkv_latent(
        params, x, mla, n_heads, rope_theta, pos[:, None])
    T = cache.pool.shape[1]
    blk = tables[jnp.arange(B), pos // T]
    off = pos % T
    row = jnp.concatenate([c_new[:, 0], kr_new[:, 0]], axis=-1)
    pool = cache.pool.at[blk, off, 0].set(row)
    w_uk = params["w_uk"].reshape(r, n_heads, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                       preferred_element_type=jnp.float32)
    q_eff = jnp.concatenate(
        [q_lat, q_rope[:, 0].astype(jnp.float32)], axis=-1)  # (B,H,r+dr)
    ctx_lat = paged_attention(q_eff.astype(pool.dtype), pool, None, tables,
                              pos + 1, scale=1.0 / math.sqrt(dn + dr),
                              v_dim=r, interpret=interpret)   # (B,H,r)
    w_uv = params["w_uv"].reshape(r, n_heads, dv)
    out = jnp.einsum("bhr,rhd->bhd", ctx_lat.astype(jnp.float32),
                     w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, n_heads * dv).astype(x.dtype)
    return out @ params["wo"], PagedLatentCache(pool)
