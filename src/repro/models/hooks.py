"""Global activation-sharding-constraint hook.

``repro.distributed.sharding.install`` points this at
``lax.with_sharding_constraint`` with the active mesh rules; outside a
mesh it is the identity.  Tags per dimension: "dp" (batch axes),
"model" (tensor/expert axis), None (replicated).
"""
from __future__ import annotations

_CONSTRAIN = lambda x, tags: x  # noqa: E731
_MOE_GROUPS = 1


def set_constrain_fn(fn):
    global _CONSTRAIN
    _CONSTRAIN = fn


def constrain(x, tags):
    return _CONSTRAIN(x, tags)


def set_moe_groups(g: int):
    """Dispatch groups for MoE (= data-parallel shard count).

    Grouped dispatch keeps the sort/scatter/gather of the capacity
    buffer local to each data shard (GShard/Switch 'groups'), removing
    the (T,d)-sized all-gather + all-reduce per MoE layer.
    """
    global _MOE_GROUPS
    _MOE_GROUPS = max(1, int(g))


def moe_groups() -> int:
    return _MOE_GROUPS
