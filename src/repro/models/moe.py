"""Mixture-of-Experts FFN: top-k routing, grouped capacity dispatch,
batched expert compute, optional shared experts.

Dispatch is the sort-based capacity scheme computed per *group*
(GShard/Switch "local groups"): tokens are split into G groups aligned
with the data-parallel shards; each group sorts its (token, expert)
pairs, keeps the first C_g per expert, and scatters into its slice of
the (G, E, C_g, d) buffer.  With the buffer sharded (data, expert) and
expert weights sharded on E, the expert einsum runs with ZERO
collectives; the only cross-device traffic is the (T_local, d) combine
reduction over the expert axis — the measured fix for the deepseek-v3
prefill cell (EXPERIMENTS.md §Perf):  per-layer all-gather(T·d) +
all-reduce(T·d) → all-reduce(T_local·d).

Pure XLA (no data-dependent shapes) so it shards under pjit on any
mesh; G defaults to the launch-installed data-shard count and divides
down automatically for small token counts (decode).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.bsmm import plan_matmul
from repro.models import hooks
from repro.models.hooks import constrain
from repro.models.layers import _act, mlp, mlp_init, xavier


class MoEOutput(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array
    # fraction of routed pairs dropped by the capacity limit (diagnostic)
    drop_fraction: jax.Array


def moe_init(rng, d_model: int, moe, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(rng, 6)
    E, ff = moe.num_experts, moe.d_ff_expert
    p = {
        "router": xavier(ks[0], (d_model, E), dtype),
        "up": xavier(ks[1], (E, d_model, ff), dtype, in_axis=1, out_axis=2),
        "down": xavier(ks[2], (E, ff, d_model), dtype, in_axis=1, out_axis=2),
    }
    if gated:
        p["gate"] = xavier(ks[3], (E, d_model, ff), dtype, in_axis=1,
                           out_axis=2)
    if moe.num_shared_experts > 0:
        ff_s = (moe.d_ff_shared or ff) * moe.num_shared_experts
        p["shared"] = mlp_init(ks[4], d_model, ff_s, gated, dtype=dtype)
    return p


def expert_capacity(tokens_per_group: int, moe) -> int:
    c = int(math.ceil(tokens_per_group * moe.top_k * moe.capacity_factor
                      / moe.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _num_groups(T: int, requested: Optional[int]) -> int:
    g = requested if requested is not None else hooks.moe_groups()
    g = max(1, min(g, T))
    while T % g:
        g -= 1
    return g


def _expert_matmul(a, w, plan, spec: str):
    """Per-expert matmul, optionally block-sparse.

    ``a``: (G, E, C, din); ``w``: (E, din, dout); ``plan``: one shared
    ``TilePlan`` built from the mask unioned over the expert axis
    (``models.plans``) — a tile is skipped only when it is dead in
    EVERY expert, which is exact because pruned weights are exact
    zeros.  The vmap over experts batches the Pallas call; dense
    einsum when no plan.
    """
    if plan is None:
        return jnp.einsum(spec, a, w)
    return jax.vmap(lambda ae, we: plan_matmul(ae, we, plan),
                    in_axes=(1, 0), out_axes=1)(a, w)


def moe_forward(params, x, moe, act: str, gated: bool,
                capacity: Optional[int] = None,
                num_groups: Optional[int] = None,
                plan=None) -> MoEOutput:
    """x: (B, S, d) -> MoEOutput with y: (B, S, d).

    ``plan`` (from ``models.plans.build_decode_plan``): per-projection
    tile plans — keys ``up``/``gate``/``down`` for the stacked expert
    tensors and ``shared`` for the shared-expert MLP — routing the
    expert compute through the block-sparse kernel so MoE retrains and
    decode scale with the ticket's live tiles like every other family.
    """
    B, S, d = x.shape
    T = B * S
    k, E = moe.top_k, moe.num_experts
    G = _num_groups(T, num_groups)
    Tg = T // G
    C = capacity if capacity is not None else expert_capacity(Tg, moe)

    xt = constrain(x.reshape(G, Tg, d), ("dp", None, None))
    logits = (xt @ params["router"]).astype(jnp.float32)     # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                   # (G, Tg, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch/GShard form, global) ----
    density = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / T
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * E / k

    # ---- per-group sort-based capacity dispatch ----
    e_flat = top_e.reshape(G, Tg * k)
    w_flat = top_w.reshape(G, Tg * k)
    tok_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)[None], (G, Tg * k))
    order = jnp.argsort(e_flat, axis=-1)                      # (G, Tg·k)
    e_s = jnp.take_along_axis(e_flat, order, -1)
    tok_s = jnp.take_along_axis(tok_flat, order, -1)
    w_s = jnp.take_along_axis(w_flat, order, -1)
    # per-group expert counts from the sorted ids (no T×E one-hot)
    bounds = jnp.arange(E + 1, dtype=e_s.dtype)
    cum = jax.vmap(lambda es: jnp.searchsorted(es, bounds))(e_s)  # (G, E+1)
    counts = (cum[:, 1:] - cum[:, :-1]).astype(jnp.int32)
    starts = cum[:, :-1].astype(jnp.int32)
    pos_in_e = (jnp.arange(Tg * k, dtype=jnp.int32)[None]
                - jnp.take_along_axis(starts, e_s, -1))
    keep = pos_in_e < C
    dest = jnp.where(keep, e_s * C + pos_in_e, 0)
    drop_fraction = 1.0 - jnp.mean(keep.astype(jnp.float32))

    rows = jnp.take_along_axis(xt, tok_s[..., None], axis=1)   # (G,Tg·k,d)
    rows = rows * keep[..., None].astype(x.dtype)
    rows = constrain(rows, ("dp", None, None))
    buf = jax.vmap(lambda b, idx, r: b.at[idx].add(r))(
        jnp.zeros((G, E * C, d), x.dtype), dest, rows)
    buf = constrain(buf.reshape(G, E, C, d), ("dp", "model", None, None))

    # ---- batched expert compute (E sharded = expert parallelism) ----
    plan = plan or {}
    up = _expert_matmul(buf, params["up"], plan.get("up"), "gecd,edf->gecf")
    if gated:
        h = _act(act, _expert_matmul(buf, params["gate"], plan.get("gate"),
                                     "gecd,edf->gecf")) * up
    else:
        h = _act(act, up)
    y_buf = _expert_matmul(h, params["down"], plan.get("down"),
                           "gecf,efd->gecd")
    y_buf = constrain(y_buf, ("dp", "model", None, None))

    # ---- combine: scatter FROM the expert buffer INTO tokens ----
    # slot s = e·C + pos holds sorted pair index starts[e] + pos
    e_of_slot = jnp.arange(E * C, dtype=jnp.int32) // C
    pos_of_slot = jnp.arange(E * C, dtype=jnp.int32) % C
    src = jnp.minimum(starts[:, e_of_slot] + pos_of_slot[None],
                      Tg * k - 1)                              # (G, E·C)
    valid = pos_of_slot[None] < counts[:, e_of_slot]          # (G, E·C)
    slot_tok = jnp.where(valid, jnp.take_along_axis(tok_s, src, -1), Tg)
    slot_w = jnp.where(valid, jnp.take_along_axis(w_s, src, -1), 0.0)
    contrib = (y_buf.reshape(G, E * C, d)
               * slot_w[..., None].astype(y_buf.dtype))
    out = jax.vmap(lambda o, idx, c: o.at[idx].add(c))(
        jnp.zeros((G, Tg + 1, d), x.dtype), slot_tok,
        contrib.astype(x.dtype))[:, :Tg]
    out = constrain(out, ("dp", None, None))

    if "shared" in params:
        out = out + mlp(params["shared"], xt, act, plan=plan.get("shared"))
    return MoEOutput(out.reshape(B, S, d), aux, drop_fraction)
