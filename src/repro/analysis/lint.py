"""The lint driver: one arch → one ``Report``, all three analyzers.

``lint_arch`` is deliberately a *static* pipeline — no training, no
token generation.  It builds the real objects (adapter, masks, tile
plans, a live ``ServeEngine`` for serving families) exactly the way a
run would, then verifies them and traces the jitted hot paths
abstractly:

  1. recipe lint — the family's tuned recipe (or an explicit one)
     against the family's capabilities (R-rules);
  2. invariant verification — a ``structured_prune`` mask set at the
     config's crossbar geometry, its per-leaf ``XbarStats`` accounting,
     the decode/train tile plans vs the masks' tile reduction, and
     cross-generation consistency after a live hot-swap (P-rules);
  3. jaxpr audit — the jitted train step, prefill, and decode closures
     traced with abstract/concrete batches, checked for dense routing
     misses, x64 promotions, host callbacks (J-rules); ``hlo=True``
     adds the compiled-artifact cross-check.

Everything runs on CPU at ``scale="tiny"`` in seconds per arch, so the
CI gate can afford ``lint --all``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from repro.analysis.findings import Report
from repro.analysis.invariants import (_walk_plan_leaves, verify_decode_plan,
                                       verify_engine, verify_mask_accounting,
                                       verify_tile_plan)
from repro.analysis.jaxpr_audit import (audit_closure, audit_compiled,
                                        audit_engine_sharding,
                                        unambiguous_covered)
from repro.analysis.recipe_lint import lint_recipe_for_family

# modest per-granularity fractions: enough pruning to produce dead
# tiles at tiny scale without collapsing any layer to all-zero
_LINT_FRACTION = 0.3
_EXPERT_FRACTION = 0.25


def _lint_schedule(spec) -> Sequence:
    grans = spec.granularities or ("filter", "channel", "index")
    return [(g, _EXPERT_FRACTION if g == "expert" else _LINT_FRACTION)
            for g in grans]


def lint_arch(arch: Any, *, recipe: Any = None, scale: str = "tiny",
              seed: int = 0, hlo: bool = False) -> Report:
    """Run all three analyzers against one registered arch.

    ``recipe`` overrides the family's tuned recipe (name, path, dict,
    or instance); ``hlo=True`` additionally compiles the serving
    prefill and cross-checks the optimized HLO (slower).
    """
    import jax

    from repro.api.registry import make_adapter, resolve_config
    from repro.api.session import structured_prune
    from repro.configs import PruneConfig

    report = Report()
    cfg, spec = resolve_config(arch)
    name = arch if isinstance(arch, str) else getattr(cfg, "name", "arch")
    prefix = f"{name}/"

    # -- 1. recipe lint ----------------------------------------------------
    rec = recipe if recipe is not None else spec.recipe
    if rec is not None:
        report.extend(lint_recipe_for_family(rec, spec,
                                             where_prefix=prefix))

    # -- 2. masks + plans at the config's crossbar geometry ----------------
    adapter = make_adapter(arch, scale=scale)
    params = adapter.init_params(jax.random.PRNGKey(seed))
    pcfg = PruneConfig()
    masks = structured_prune(params, _lint_schedule(spec),
                             prunable=adapter.prunable,
                             conv_pred=adapter.conv_pred, cfg=pcfg)
    report.extend(verify_mask_accounting(
        masks, adapter.conv_pred, rows=pcfg.xbar_rows,
        cols=pcfg.xbar_cols, where=f"{name}/masks"))

    # -- 3. family-shaped plan verification + jaxpr audit ------------------
    if spec.family == "cnn":
        _lint_cnn(report, name, adapter, params, masks)
    else:
        _lint_lm(report, name, adapter, params, masks)

    if spec.serves:
        _lint_serving(report, name, adapter, spec, params, masks, hlo=hlo)
    return report


def _lint_cnn(report: Report, name: str, adapter, params, masks) -> None:
    import jax

    from repro.train.plans import cnn_train_plan

    plans, stats = cnn_train_plan(masks, interpret=True)
    for path, leaf in _walk_plan_leaves(plans):
        report.extend(verify_tile_plan(
            leaf, where=f"{name}/train_plan/{path}"))
    covered = unambiguous_covered(plans, params)
    cfg = adapter.cfg
    cnn = adapter._cnn

    def loss(p, state, batch):
        l, (new_state, _) = cnn.loss_fn(p, state, cfg, batch, train=True,
                                        plans=plans)
        return l, (new_state, {})

    step = jax.jit(jax.value_and_grad(loss, has_aux=True))
    batch = adapter._batch(0, 2)
    report.extend(audit_closure(
        step, [params, adapter._bn0, batch], covered=covered,
        where=f"{name}/train_step"))


def _lint_lm(report: Report, name: str, adapter, params, masks) -> None:
    import jax

    kwargs: Dict[str, Any] = {}
    covered: Dict = {}
    if adapter.family == "audio":
        # enc-dec masks carry no decode-plan structure; the trace is
        # audited for promotions/callbacks only
        mod, cfg = adapter._mod, adapter.cfg
        loss = lambda p, batch: mod.loss_fn(p, cfg, batch)
    else:
        from repro.models.plans import build_decode_plan
        from repro.train.plans import lm_train_plan

        plan, stats = build_decode_plan(masks, interpret=True)
        report.extend(verify_decode_plan(
            masks, plan, stats, where=f"{name}/decode_plan"))
        train_plan, _ = lm_train_plan(masks, interpret=True)
        covered = unambiguous_covered(train_plan, params)
        tfm, cfg = adapter._tfm, adapter.cfg
        loss = lambda p, batch: tfm.loss_fn(p, cfg, batch,
                                            plan=train_plan)

    step = jax.jit(jax.value_and_grad(loss, has_aux=True))
    batch = adapter._batch(0)
    report.extend(audit_closure(
        step, [params, batch], covered=covered,
        where=f"{name}/train_step", **kwargs))


def _lint_serving(report: Report, name: str, adapter, spec, params,
                  masks, *, hlo: bool = False) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.masks import apply_masks
    from repro.serve.engine import ServeEngine

    cfg = adapter.cfg   # the SCALED config the params were built for
    prefill_fn, decode_fn = adapter.serve_fns()
    masked = apply_masks(params, masks)
    eng = ServeEngine(params=masked, cfg=cfg, prefill_fn=prefill_fn,
                      decode_fn=decode_fn, masks=masks, interpret=True,
                      batch_slots=2, capacity=64)
    gen = eng.generations[-1]
    covered = unambiguous_covered(gen.plan, masked)

    toks = jnp.zeros((1, 8), jnp.int32)
    if spec.family == "audio":
        frames = jnp.zeros((1, int(cfg.encoder_seq_len),
                            int(cfg.d_model)), jnp.float32)
        prefill, pargs = gen.prefill_frames, [masked, toks, frames]
    else:
        prefill, pargs = gen.prefill_exact, [masked, toks]
    report.extend(audit_closure(prefill, pargs, covered=covered,
                                where=f"{name}/prefill"))

    # decode runs against SLOT-shaped caches (batch axis = engine
    # slots), derived abstractly: eval_shape the prefill, zero-fill,
    # re-lane through the engine's own cache plumbing
    logits_s, caches_s = jax.eval_shape(prefill, *pargs)
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), caches_s)
    slot_caches = eng._empty_slot_caches(zeros)
    slot_s = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), slot_caches)
    tok = jax.ShapeDtypeStruct((eng.slots, 1), jnp.int32)
    report.extend(audit_closure(
        gen.decode, [masked, slot_s, tok], covered=covered,
        where=f"{name}/decode"))

    if eng.paged:
        # paged decode closure: same J-rule audit as the dense decode,
        # against abstract pool/table/length arguments
        pc_s = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            gen.paged_caches)
        tbl = jax.ShapeDtypeStruct((eng.slots, eng.kv_blocks - 1),
                                   jnp.int32)
        lens = jax.ShapeDtypeStruct((eng.slots,), jnp.int32)
        report.extend(audit_closure(
            gen.decode_paged, [masked, pc_s, tok, tbl, lens],
            covered=covered, where=f"{name}/decode_paged"))
        # adopt a real prefill into the pool and demand the gathered
        # logical order reproduce the dense oracle bit-for-bit (P114)
        from repro.analysis.invariants import verify_paged_reconstruction
        from repro.serve.paging import blocks_needed
        if spec.family != "audio":
            _, dense_c = gen.prefill_exact(masked, toks)
            from repro.kernels.paged_attention import BLOCK_TOKENS
            nb = blocks_needed(int(toks.shape[1]), BLOCK_TOKENS)
            blocks = jnp.arange(1, nb + 1, dtype=jnp.int32)
            adopted = gen.adopt(gen.paged_caches, dense_c, blocks)
            report.extend(verify_paged_reconstruction(
                adopted, dense_c, blocks, int(toks.shape[1]),
                where=f"{name}/paged"))

    # live hot-swap, then cross-generation consistency (P112) — paged
    # engines also get pool/table balance checks here (P113/P115)
    eng.swap(masked, masks)
    report.extend(verify_engine(eng, where=f"{name}/engine"))
    # sharding placement (J208) — a no-op on this 1-device lint engine,
    # load-bearing when the driver lints a mesh-backed engine
    report.extend(audit_engine_sharding(eng, where=f"{name}/engine"))

    if hlo:
        report.extend(audit_compiled(prefill, pargs,
                                     where=f"{name}/prefill.hlo"))


def lint_kernels(*, backend: str = "tpu") -> Report:
    """K300–K306 over every registered Pallas kernel's canonical audit
    case (``analysis.kernel_audit``): BlockSpec/grid coverage, bounds,
    guard/liveness agreement, accumulator dtypes, VMEM budget, and the
    perf-model cross-check.  Pure host numpy — no tracing, no device."""
    from repro.analysis.kernel_audit import audit_kernels

    report = Report()
    report.extend(audit_kernels(backend=backend))
    return report


def lint_all(names: Optional[Sequence[str]] = None, *,
             scale: str = "tiny", seed: int = 0,
             hlo: bool = False) -> Dict[str, Report]:
    """``lint_arch`` over every registered arch (or ``names``)."""
    from repro.api.registry import list_adaptable

    out: Dict[str, Report] = {}
    for name in (names if names is not None else list_adaptable()):
        out[name] = lint_arch(name, scale=scale, seed=seed, hlo=hlo)
    return out
