"""Sparsity lint: static verification of recipes, tile plans, and
jitted hot paths.

Three analyzers, one structured ``Finding`` model with stable rule
codes (``findings.RULES``):

* ``recipe_lint`` — R001–R009, recipe programs vs family capabilities;
* ``invariants``  — P101–P116, tile plans / decode plans / crossbar
  stats / paged-KV pools / fleet accounting re-derived from their
  sources and compared;
* ``jaxpr_audit`` — J201–J208, abstract traces of jitted hot paths
  (dense routing misses, x64 promotions, host callbacks) plus a
  compiled-HLO cross-check;
* ``kernel_audit`` — K300–K306, every registered Pallas kernel's
  declarative ``KernelSpec`` (the object its ``pallas_call`` is built
  from) evaluated exhaustively over small concrete grids: output-tile
  coverage, index-map/block-table bounds, ``pl.when`` liveness vs the
  truth source, f32 accumulators, VMEM budget, perf-model agreement.

``lint.lint_arch`` runs the first three against a registered arch and
``lint.lint_kernels`` the fourth; the CLI surface is ``python -m
repro.api lint [--arch NAME | --all] [--kernels]`` with ``--explain
CODE`` documenting any rule from the central registry.
"""
from repro.analysis.findings import (RULES, SEVERITIES, Finding, Report,
                                     error, explain, info, rules_markdown,
                                     warning)
from repro.analysis.kernel_audit import (AuditCase, audit_case,
                                         audit_kernel_spec, audit_kernels,
                                         default_cases)
from repro.analysis.invariants import (verify_block_pool,
                                       verify_block_tables,
                                       verify_decode_plan, verify_engine,
                                       verify_mask_accounting,
                                       verify_paged_engine,
                                       verify_paged_reconstruction,
                                       verify_tile_plan, verify_fleet,
                                       verify_xbar_stats)
from repro.analysis.jaxpr_audit import (audit_closure, audit_compiled,
                                        audit_engine_sharding,
                                        audit_hlo_text, collect_covered,
                                        iter_eqns, unambiguous_covered)
from repro.analysis.lint import lint_all, lint_arch, lint_kernels
from repro.analysis.recipe_lint import lint_recipe, lint_recipe_for_family

__all__ = [
    "RULES", "SEVERITIES", "Finding", "Report", "error", "warning", "info",
    "explain", "rules_markdown",
    "AuditCase", "audit_case", "audit_kernel_spec", "audit_kernels",
    "default_cases",
    "lint_recipe", "lint_recipe_for_family",
    "verify_tile_plan", "verify_decode_plan", "verify_xbar_stats",
    "verify_mask_accounting", "verify_engine", "verify_block_pool",
    "verify_block_tables", "verify_paged_engine",
    "verify_paged_reconstruction", "verify_fleet",
    "audit_closure", "audit_compiled", "audit_hlo_text",
    "audit_engine_sharding",
    "collect_covered", "unambiguous_covered", "iter_eqns",
    "lint_arch", "lint_all", "lint_kernels",
]
