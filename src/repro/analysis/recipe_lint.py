"""Recipe linter: static checks of ``Recipe``/``Stage`` programs.

Runs entirely on the recipe data — no model, no training.  Checks a
program against the target family's capabilities (``FamilySpec``) and
against the session interpreter's actual semantics, which is where the
subtle rules come from:

* ``retrain_steps=0`` does NOT mean "no retraining": the adapters treat
  a falsy budget as "use my default", so a zero budget silently trains
  the full default schedule (R004).
* A stage whose ``target_sparsity`` is already met by an earlier stage
  still runs at least one round before its exit check — the target is
  dead text (R003).
* The per-stage exit ``s_after >= target`` composes multiplicatively:
  each accepted round prunes ``rate`` of the *remaining* weights, so a
  stage capped at ``max_rounds`` can reach at most
  ``1 - (1-s0)·(1-rate)^max_rounds`` (R007).

Rule codes R001–R009; see ``analysis.findings.RULES``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.findings import Finding, error, warning
from repro.api.recipes import Recipe, RecipeLike, resolve_recipe

_REACH_EPS = 1e-9


def lint_recipe(spec: RecipeLike, *,
                allowed_granularities: Optional[Sequence[str]] = None,
                family: str = "",
                where_prefix: str = "") -> List[Finding]:
    """Lint one recipe (instance, registered name, dict, or .json path).

    ``allowed_granularities``: the family's valid prune granularities
    (``api.registry.family_granularities``); None skips the family
    check (R002).  ``family`` only labels the finding messages.
    """
    try:
        recipe = resolve_recipe(spec)
    except (ValueError, TypeError, KeyError, FileNotFoundError) as e:
        label = spec if isinstance(spec, str) else \
            (spec.get("name", "?") if isinstance(spec, dict) else "?")
        return [error("R001", f"{where_prefix}recipe:{label}", str(e))]

    findings: List[Finding] = []

    def loc(i: int, stage) -> str:
        return f"{where_prefix}recipe:{recipe.name}/stage[{i}]:{stage.name}"

    allowed = (None if allowed_granularities is None
               else set(allowed_granularities))
    # best-case sparsity reachable so far (every round accepted), used
    # for both the monotonicity check and the reachability bound
    best_sparsity = 0.0
    last_target: Optional[float] = None
    quantized_at: Optional[int] = None
    seen_prune = False
    seen_names = {}

    for i, s in enumerate(recipe.stages):
        if s.name in seen_names:
            findings.append(warning(
                "R008", loc(i, s),
                f"stage name {s.name!r} duplicates stage"
                f"[{seen_names[s.name]}] — resume and event attribution "
                f"key on stage identity; give stages distinct names"))
        else:
            seen_names[s.name] = i

        if s.retrain_steps is not None and s.retrain_steps <= 0:
            findings.append(error(
                "R004", loc(i, s),
                f"retrain_steps={s.retrain_steps} is not a zero-retrain "
                f"budget: falsy budgets silently fall back to the "
                f"adapter's default schedule; drop the field or set a "
                f"positive budget"))

        if s.kind == "prune":
            seen_prune = True
            if allowed is not None and s.granularity not in allowed:
                fam = f" for family {family!r}" if family else ""
                findings.append(error(
                    "R002", loc(i, s),
                    f"granularity {s.granularity!r} is not usable"
                    f"{fam}; allowed: {sorted(allowed)} (it would run "
                    f"but prune nothing — no leaves expose groups)"))
            if quantized_at is not None:
                findings.append(warning(
                    "R006", loc(i, s),
                    f"prune stage after quantize stage"
                    f"[{quantized_at}] — pruning after QAT invalidates "
                    f"the calibrated quantized accuracy the quantize "
                    f"gate accepted; order prune stages first"))
            if s.target_sparsity is not None:
                if last_target is not None and \
                        s.target_sparsity <= last_target:
                    findings.append(error(
                        "R003", loc(i, s),
                        f"target_sparsity={s.target_sparsity} does not "
                        f"exceed the previous target {last_target} — "
                        f"the target is already met when the stage "
                        f"starts, so it bounds nothing (the stage still "
                        f"runs one unbudgeted round)"))
                last_target = s.target_sparsity
                if s.max_rounds is not None:
                    reach = 1.0 - (1.0 - best_sparsity) * \
                        (1.0 - s.rate) ** s.max_rounds
                    if reach + _REACH_EPS < s.target_sparsity:
                        findings.append(warning(
                            "R007", loc(i, s),
                            f"target_sparsity={s.target_sparsity} is "
                            f"unreachable: {s.max_rounds} rounds at "
                            f"rate={s.rate} reach at most {reach:.3f} "
                            f"even if every round is accepted"))
            # advance the best-case sparsity bound
            if s.max_rounds is not None:
                best = 1.0 - (1.0 - best_sparsity) * \
                    (1.0 - s.rate) ** s.max_rounds
            else:
                best = 1.0  # unbounded rounds can approach 1.0
            if s.target_sparsity is not None:
                best = min(best, max(s.target_sparsity, best_sparsity))
            best_sparsity = max(best_sparsity, best)
        elif s.kind == "quantize":
            if not seen_prune:
                findings.append(warning(
                    "R005", loc(i, s),
                    "quantize stage before any prune stage: QAT "
                    "calibrates a dense model, so the quantized "
                    "accuracy gate measures nothing about the ticket "
                    "this recipe is supposed to produce"))
            quantized_at = i

    if not seen_prune:
        findings.append(warning(
            "R009", f"{where_prefix}recipe:{recipe.name}",
            "recipe has no prune stage — it commits no masks "
            "(measurement-only programs like the ablation sweep are "
            "fine; anything meant to produce a ticket is not)"))
    return findings


def lint_recipe_for_family(spec: RecipeLike, family_spec,
                           where_prefix: str = "") -> List[Finding]:
    """Lint a recipe against a ``FamilySpec`` (granularity capability)."""
    from repro.api.registry import family_granularities
    return lint_recipe(
        spec,
        allowed_granularities=family_granularities(family_spec),
        family=family_spec.family,
        where_prefix=where_prefix)
