"""Static Pallas kernel verifier (rules K300–K306).

Every kernel in ``repro.kernels`` describes its launch as a declarative
``KernelSpec`` — grid, dimension semantics, the *actual* BlockSpec
index-map callables, scalar-prefetch operands, scratch, and a host
mirror of its ``pl.when`` work gate.  Because the kernels construct
their ``pallas_call`` *from* those specs, auditing the spec audits the
executed launch geometry, with no source re-parsing and no second copy
of the index maps to drift.

``audit_kernel_spec`` evaluates the spec exhaustively over its concrete
grid (audit cases are a handful of grid cells; the checks are O(grid ×
operands) host numpy):

  K300  spec malformed — grid/dims/blocks/shapes inconsistent, or an
        index map that does not evaluate.
  K301  output coverage exact — the output index map is constant along
        'arbitrary' axes (revolving accumulator) and a bijection from
        the parallel axes onto the output tile grid: every tile written
        exactly once, none skipped on a ragged edge.
  K302  all index maps in bounds over ALL grid cells — including
        guarded ones, whose DMA still happens (this is why dead block-
        table entries must point at the scratch block, not past the
        pool).
  K303  guard/liveness agreement — per parallel class, the multiset of
        blocks the *unguarded* cells gather equals the live set derived
        independently from the truth source (tile bitmap, block table +
        lengths, causal structure).
  K304  accumulator/softmax scratch is f32 and the accumulator shape
        matches the output block it flushes into.
  K305  VMEM footprint (double-buffered blocks + scratch) within the
        per-backend budget declared in ``configs.base``.
  K306  passes/FLOPs/bytes enumerated from the spec equal
        ``core.perf_model``'s analytic ``KernelCost`` prediction from
        plan metadata (the no-elision, guarded-skip traffic model) —
        the perf model and the kernels cannot silently diverge.

``default_cases()`` is the canonical registry of small concrete cases
covering every registered kernel (bsmm fwd plain + fused epilogue, dx,
dw, paged attention GQA + fused-V MLA, flash attention, masked matmul,
tile stats); ``audit_kernels()`` runs them all and is what ``lint
--kernels`` invokes — the first gate of the TPU bring-up runbook.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding, error
from repro.configs.base import MXU_TILE, vmem_budget
from repro.kernels.spec import ACCUMULATOR_ROLES, BlockMap, KernelSpec

Coord = Tuple[int, ...]
#: truth for K303: input name -> parallel class -> live block coords
ExpectedGathers = Dict[str, Dict[Coord, List[Coord]]]

_DIM_SEMANTICS = ("parallel", "arbitrary")
_MAX_EXAMPLES = 3       # coords quoted per finding before eliding


@dataclass(frozen=True)
class AuditCase:
    """One concrete kernel launch plus its independent liveness truth
    and (optionally) the perf model's cost prediction to cross-check."""
    name: str
    spec: KernelSpec
    expected_gathers: Optional[ExpectedGathers] = None
    cost: Optional[object] = None           # core.perf_model.KernelCost


def _eval_map(bm: BlockMap, ids: Coord, scalars) -> Coord:
    out = bm.index_map(*ids, *scalars)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(c) for c in out)


def _squeeze(shape: Sequence[int]) -> Tuple[int, ...]:
    return tuple(int(d) for d in shape if int(d) != 1)


def _check_structure(spec: KernelSpec, where: str) -> List[Finding]:
    bad: List[Finding] = []
    if len(spec.grid) != len(spec.dims):
        bad.append(error("K300", where,
                         f"grid rank {len(spec.grid)} != "
                         f"dimension_semantics rank {len(spec.dims)}"))
    for d in spec.dims:
        if d not in _DIM_SEMANTICS:
            bad.append(error("K300", where,
                             f"unknown dimension semantic {d!r}"))
    if any(g <= 0 for g in spec.grid):
        bad.append(error("K300", where,
                         f"non-positive grid extent {spec.grid}"))
    for bm in (*spec.inputs, *spec.outputs):
        if len(bm.block) != len(bm.shape):
            bad.append(error(
                "K300", where,
                f"{bm.name}: block rank {len(bm.block)} != operand "
                f"rank {len(bm.shape)}"))
            continue
        if any(b <= 0 for b in bm.block) or \
                any(s % b for s, b in zip(bm.shape, bm.block)):
            bad.append(error(
                "K300", where,
                f"{bm.name}: block {bm.block} does not tile shape "
                f"{bm.shape} evenly"))
    if bad:
        return bad
    origin = tuple(0 for _ in spec.grid)
    for bm in (*spec.inputs, *spec.outputs):
        try:
            coord = _eval_map(bm, origin, spec.scalars)
        except Exception as e:   # noqa: BLE001 — any failure is the finding
            bad.append(error("K300", where,
                             f"{bm.name}: index map failed at grid "
                             f"origin: {type(e).__name__}: {e}"))
            continue
        if len(coord) != len(bm.block):
            bad.append(error(
                "K300", where,
                f"{bm.name}: index map returns {len(coord)} coords for "
                f"a rank-{len(bm.block)} block"))
    if spec.guard is not None:
        try:
            spec.guard(*origin, *spec.scalars)
        except Exception as e:   # noqa: BLE001
            bad.append(error("K300", where,
                             f"guard failed at grid origin: "
                             f"{type(e).__name__}: {e}"))
    return bad


def _fmt_cells(cells: List) -> str:
    shown = ", ".join(map(str, cells[:_MAX_EXAMPLES]))
    more = len(cells) - _MAX_EXAMPLES
    return shown + (f", … +{more} more" if more > 0 else "")


def audit_kernel_spec(spec: KernelSpec, *, backend: str = "tpu",
                      expected_gathers: Optional[ExpectedGathers] = None,
                      cost=None, where: str = "") -> List[Finding]:
    """Run K300–K306 against one concrete ``KernelSpec``.

    ``expected_gathers`` supplies the independent liveness truth for
    K303; ``cost`` a ``core.perf_model.KernelCost`` for K306.  Either
    may be None to skip that rule (e.g. data-dependent guards).
    """
    where = where or f"kernels/{spec.name}"
    findings = _check_structure(spec, where)
    if findings:
        return findings      # geometry unusable; later rules would lie

    par = spec.parallel_axes()
    cells = list(np.ndindex(*spec.grid))
    unguarded = [c for c in cells
                 if spec.guard is None or spec.guard(*c, *spec.scalars)]

    # one evaluation sweep shared by K301/K302/K303/K306
    coords: Dict[str, Dict[Coord, Coord]] = {}     # map name -> cell -> coord
    for bm in (*spec.inputs, *spec.outputs):
        coords[bm.name] = {c: _eval_map(bm, c, spec.scalars)
                           for c in cells}

    # -- K302: every cell's DMA target in bounds (guarded cells too) ----
    for bm in (*spec.inputs, *spec.outputs):
        tgrid = bm.tile_grid()
        bad = [(c, coords[bm.name][c]) for c in cells
               if any(not 0 <= x < t
                      for x, t in zip(coords[bm.name][c], tgrid))]
        if bad:
            findings.append(error(
                "K302", where,
                f"{bm.name}: index map leaves the {tgrid} tile grid at "
                f"{len(bad)} of {len(cells)} grid cells "
                f"(cell -> block): {_fmt_cells(bad)}"))

    # -- K301: output coverage exact --------------------------------------
    for bm in spec.outputs:
        per_class: Dict[Coord, Coord] = {}
        moved = []
        for c in cells:
            cls = tuple(c[d] for d in par)
            coord = coords[bm.name][c]
            prev = per_class.setdefault(cls, coord)
            if prev != coord:
                moved.append((cls, prev, coord))
        if moved:
            findings.append(error(
                "K301", where,
                f"{bm.name}: output block moves along an 'arbitrary' "
                f"grid axis — the revolving accumulator would flush to "
                f"different tiles (class, first, later): "
                f"{_fmt_cells(moved)}"))
            continue
        written = list(per_class.values())
        wset = set(written)
        expected = set(np.ndindex(*bm.tile_grid()))
        missing = sorted(expected - wset)
        dup = sorted({w for w in wset if written.count(w) > 1})
        if missing or dup:
            parts = []
            if missing:
                parts.append(f"{len(missing)} of {len(expected)} output "
                             f"tiles never written: {_fmt_cells(missing)}")
            if dup:
                parts.append(f"tiles written by multiple parallel "
                             f"classes: {_fmt_cells(dup)}")
            findings.append(error(
                "K301", where, f"{bm.name}: " + "; ".join(parts)))

    # -- K303: unguarded gathers == independent liveness truth ----------
    if expected_gathers:
        by_name = {bm.name: bm for bm in spec.inputs}
        for name, truth in expected_gathers.items():
            if name not in by_name:
                findings.append(error(
                    "K303", where,
                    f"liveness truth names unknown input {name!r}"))
                continue
            got: Dict[Coord, List[Coord]] = {}
            for c in unguarded:
                cls = tuple(c[d] for d in par)
                got.setdefault(cls, []).append(coords[name][c])
            classes = set(truth) | set(got)
            bad_cls = []
            for cls in sorted(classes):
                want = sorted(tuple(map(int, w)) for w in
                              truth.get(cls, []))
                have = sorted(got.get(cls, []))
                if want != have:
                    bad_cls.append((cls, want, have))
            if bad_cls:
                cls, want, have = bad_cls[0]
                findings.append(error(
                    "K303", where,
                    f"{name}: unguarded gathers disagree with the live "
                    f"set for {len(bad_cls)} parallel class(es); e.g. "
                    f"class {cls}: live={want} gathered={have} — a "
                    f"loose guard streams dead/scratch blocks, a tight "
                    f"one drops live work"))

    # -- K304: accumulator dtype/shape ----------------------------------
    for i, s in enumerate(spec.scratch):
        if s.role in ACCUMULATOR_ROLES and \
                np.dtype(s.dtype) != np.dtype(np.float32):
            findings.append(error(
                "K304", where,
                f"scratch[{i}] ({s.role}) is {np.dtype(s.dtype).name}, "
                f"must be float32 — low-precision accumulation breaks "
                f"the kernels' exactness contract"))
    accs = [s for s in spec.scratch if s.role == "accumulator"]
    if accs and spec.outputs:
        acc, out = accs[0], spec.outputs[0]
        if _squeeze(acc.shape) != _squeeze(out.block):
            findings.append(error(
                "K304", where,
                f"accumulator shape {tuple(acc.shape)} does not match "
                f"the output block {tuple(out.block)} it flushes into"))

    # -- K305: VMEM footprint vs backend budget -------------------------
    bd = spec.vmem_breakdown()
    budget = vmem_budget(backend)
    if bd["total"] > budget:
        findings.append(error(
            "K305", where,
            f"estimated VMEM {bd['total']} B (2×in {bd['inputs']} + "
            f"2×out {bd['outputs']} + scratch {bd['scratch']}) exceeds "
            f"the {budget} B {backend} budget "
            f"(configs.base.VMEM_BUDGET_BYTES)"))

    # -- K306: spec-enumerated cost == perf-model prediction ------------
    if cost is not None:
        passes = len(unguarded)
        flops = passes * float(spec.cell_flops)
        in_bytes = passes * sum(bm.block_bytes for bm in spec.inputs)
        out_bytes = sum(
            len({coords[bm.name][c] for c in cells}) * bm.block_bytes
            for bm in spec.outputs)
        got = (passes, flops, float(in_bytes + out_bytes))
        want = (int(cost.passes), float(cost.flops),
                float(cost.hbm_bytes))
        if got != want:
            findings.append(error(
                "K306", where,
                f"spec enumeration (passes={got[0]}, flops={got[1]:.0f}, "
                f"bytes={got[2]:.0f}) disagrees with the perf model "
                f"(passes={want[0]}, flops={want[1]:.0f}, "
                f"bytes={want[2]:.0f}) — kernels and core.perf_model "
                f"have diverged"))
    return findings


def audit_case(case: AuditCase, *, backend: str = "tpu",
               where: str = "") -> List[Finding]:
    return audit_kernel_spec(case.spec, backend=backend,
                             expected_gathers=case.expected_gathers,
                             cost=case.cost,
                             where=where or f"kernels/{case.name}")


# ---------------------------------------------------------------------------
# Canonical audit cases: one small concrete launch per registered
# kernel, with liveness truth derived from first principles (the
# bitmap / the block lists the tables were built from / causal math),
# NOT from the plan arrays the index maps read.
# ---------------------------------------------------------------------------

#: (Kt, Nt) tile bitmap with dead tiles in both directions
_BITMAP = np.array([[1, 0],
                    [0, 1],
                    [1, 1]], np.int32)


def _bsmm_cases(tile: int) -> List[AuditCase]:
    from repro.core.perf_model import (bsmm_dw_cost, bsmm_dx_cost,
                                       bsmm_fwd_cost)
    from repro.kernels.bsmm import (bsmm_dw_spec, bsmm_dx_spec,
                                    bsmm_fwd_spec, make_tile_plan)

    Kt, Nt = _BITMAP.shape
    K, N = Kt * tile, Nt * tile
    M, bm = 2 * tile, tile
    Mt = M // bm
    mask = np.repeat(np.repeat(_BITMAP, tile, 0), tile, 1)
    plan = make_tile_plan(mask, tile=tile, strict=True)

    live_k = {j: np.nonzero(_BITMAP[:, j])[0] for j in range(Nt)}
    live_n = {k: np.nonzero(_BITMAP[k, :])[0] for k in range(Kt)}
    fwd_truth = {
        "x": {(i, j): [(i, int(kt)) for kt in live_k[j]]
              for i in range(Mt) for j in range(Nt)},
        "w": {(i, j): [(int(kt), j) for kt in live_k[j]]
              for i in range(Mt) for j in range(Nt)},
    }
    dx_truth = {
        "g": {(i, k): [(i, int(nt)) for nt in live_n[k]]
              for i in range(Mt) for k in range(Kt)},
        "w": {(i, k): [(k, int(nt)) for nt in live_n[k]]
              for i in range(Mt) for k in range(Kt)},
    }
    kk, nn = np.nonzero(_BITMAP)             # row-major, == plan order
    dw_truth = {
        "x": {(l,): [(m, int(kk[l])) for m in range(Mt)]
              for l in range(len(kk))},
        "g": {(l,): [(m, int(nn[l])) for m in range(Mt)]
              for l in range(len(kk))},
    }
    cases = [
        AuditCase(
            "bsmm_fwd",
            bsmm_fwd_spec(plan.idx, plan.counts, plan.kmax, M=M, K=K,
                          N=N, bm=bm, bk=tile, bn=tile),
            fwd_truth, bsmm_fwd_cost(plan, M, bm=bm)),
        AuditCase(
            "bsmm_fwd_epilogue",
            bsmm_fwd_spec(plan.idx, plan.counts, plan.kmax, M=M, K=K,
                          N=N, bm=bm, bk=tile, bn=tile, fused=True),
            fwd_truth, bsmm_fwd_cost(plan, M, bm=bm, fused=True)),
        AuditCase(
            "bsmm_dx",
            bsmm_dx_spec(plan.idx_t, plan.counts_t, plan.nmax, M=M,
                         K=K, N=N, bm=bm, tile=tile),
            dx_truth, bsmm_dx_cost(plan, M, bm=bm)),
        AuditCase(
            "bsmm_dw",
            bsmm_dw_spec(plan.kk, plan.nn, M=M, K=K, N=N, bm=bm,
                         tile=tile),
            dw_truth, bsmm_dw_cost(plan, M, bm=bm)),
    ]
    return cases


def _paged_cases() -> List[AuditCase]:
    from repro.core.perf_model import paged_decode_cost
    from repro.kernels.paged_attention import (BLOCK_TOKENS,
                                               PagedGeometry,
                                               paged_attention_spec)

    T = BLOCK_TOKENS
    B, Hq, Hkv, hd, P, NB = 2, 4, 2, 8, 5, 3
    # the truth source: per-sequence physical block lists + lengths the
    # tables are BUILT from (dead entries -> the pool's scratch block 0)
    blocks = [[1, 2], [3]]
    lengths = [T + 2, 7]                    # seq0 spans 2 blocks, seq1 1
    tables = np.zeros((B, NB), np.int32)
    for b, blks in enumerate(blocks):
        tables[b, :len(blks)] = blks
    lengths_a = np.asarray(lengths, np.int32)

    def truth(dv: int, fused: bool) -> ExpectedGathers:
        t: ExpectedGathers = {
            "k_pool": {(b,): [(blk, 0, 0, 0) for blk in blocks[b]]
                       for b in range(B)}}
        if not fused:
            t["v_pool"] = {(b,): [(blk, 0, 0, 0) for blk in blocks[b]]
                           for b in range(B)}
        return t

    cases = []
    for fused, dv, name in ((False, hd, "paged_attention_gqa"),
                            (True, hd // 2, "paged_attention_mla")):
        geo = PagedGeometry(B=B, Hq=Hq, hd=hd, Hkv=Hkv, T=T, NB=NB,
                            P=P, dv=dv)
        cases.append(AuditCase(
            name,
            paged_attention_spec(geo, tables, lengths_a, fused_v=fused),
            truth(dv, fused),
            paged_decode_cost(lengths, nb=NB, block_tokens=T,
                              n_q_heads=Hq, n_kv_heads=Hkv, head_dim=hd,
                              v_dim=dv, fused_v=fused)))
    return cases


def _flash_case(tile: int) -> AuditCase:
    from repro.core.perf_model import flash_cost
    from repro.kernels.flash_attention import flash_attention_spec

    B, Hq, Hkv, hd = 1, 2, 1, 16
    S, bq, bk = 2 * tile, tile, tile
    G = Hq // Hkv
    spec = flash_attention_spec(B=B, S=S, Hq=Hq, Hkv=Hkv, hd=hd, bq=bq,
                                bk=bk, causal=True)
    # causal truth from first principles: with square blocks, q block i
    # attends k blocks 0..i
    truth: ExpectedGathers = {
        "k": {(b, h, i): [(b, h // G, j, 0) for j in range(i + 1)]
              for b in range(B) for h in range(Hq)
              for i in range(S // bq)}}
    return AuditCase(
        "flash_attention", spec, truth,
        flash_cost(batch=B, n_q_heads=Hq, seq=S, head_dim=hd, bq=bq,
                   bk=bk, causal=True))


def default_cases(tile: int = MXU_TILE) -> List[AuditCase]:
    """The canonical small concrete launches, one per registered
    kernel.  ``masked_matmul``/``tile_stats`` carry no liveness truth
    or cost (their work gates are data-dependent / VPU-only), so K303
    and K306 are skipped for them by construction."""
    from repro.kernels.bsmm import masked_matmul_spec
    from repro.kernels.tile_stats import tile_stats_spec

    cases = _bsmm_cases(tile)
    cases.extend(_paged_cases())
    cases.append(_flash_case(tile))
    cases.append(AuditCase(
        "masked_matmul",
        masked_matmul_spec(M=2 * tile, K=3 * tile, N=2 * tile, bm=tile,
                           bk=tile, bn=tile)))
    cases.append(AuditCase(
        "tile_stats", tile_stats_spec(K=2 * tile, N=2 * tile, bk=tile,
                                      bn=tile)))
    return cases


def audit_kernels(*, backend: str = "tpu",
                  cases: Optional[Sequence[AuditCase]] = None
                  ) -> List[Finding]:
    """K300–K306 over every registered kernel's canonical audit case —
    the ``lint --kernels`` entry point and the first TPU bring-up gate."""
    out: List[Finding] = []
    for case in (cases if cases is not None else default_cases()):
        out.extend(audit_case(case, backend=backend))
    return out
