"""Jaxpr auditor: trace jitted hot paths abstractly, audit the trace.

``jax.make_jaxpr`` runs the closure with abstract values — no FLOPs, no
compile — and hands back the full equation graph, including the bodies
of every nested ``jit``/``scan``/``cond``.  The auditor walks that
graph looking for the failure modes that do not crash but silently
forfeit the sparsity the plan paid for:

* a dense ``dot_general`` whose weight operand has exactly the (K, N)
  shape some ``TilePlan`` covers (J201) — the kernel router fell back
  to dense for a projection it was supposed to skip tiles on;
* no ``pallas_call`` anywhere in a trace whose plan routes at least one
  projection (J205) — the whole path lost its routing (e.g. a stale
  ``use_bsmm=False`` default);
* f64 values (J202), host callbacks (J203), and unjitted closures
  (J204) — each a per-step tax invisible in unit tests.

``pallas_call`` bodies are NOT descended into: the block-sparse kernel
legitimately contains a dense per-tile ``dot`` — that is the point.

The compiled-artifact cross-check (``audit_compiled``) reuses
``launch.hlo_analysis`` to confirm at the HLO level what the trace
promised (J206, J207).

Rule codes J201–J208; see ``analysis.findings.RULES``.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

from repro.analysis.findings import Finding, error, info, warning

# primitives whose params hold sub-jaxprs we must NOT descend into:
# the block-sparse kernel body is dense per tile by design
_OPAQUE_PRIMS = ("pallas_call",)


def collect_covered(plan_tree) -> Dict[Tuple[int, int], str]:
    """{(K, N) weight shape: plan path} for every TilePlan in a tree.

    A plan built by ``make_tile_plan`` covers a (K, N) weight where
    K = len(counts_t)·tile and N = len(counts)·tile; any dense
    ``dot_general`` against that exact shape in a hot path is a routing
    miss.  Later duplicates keep the first label (the shape is the key —
    shared-shape projections are indistinguishable in the trace anyway).
    """
    from repro.analysis.invariants import _walk_plan_leaves
    covered: Dict[Tuple[int, int], str] = {}
    for path, plan in _walk_plan_leaves(plan_tree):
        if plan.counts_t is None:
            continue
        K = int(plan.counts_t.shape[0]) * plan.tile
        N = int(plan.counts.shape[0]) * plan.tile
        covered.setdefault((K, N), path)
    return covered


def unambiguous_covered(plan_tree, params) -> Dict[Tuple[int, int], str]:
    """``collect_covered`` minus shapes that non-routed weights share.

    A dense ``dot_general`` is identified by its weight operand's
    (K, N) alone — the trace has no param paths — so a shape is a
    reliable routing-miss signature only when EVERY weight of that
    shape is plan-covered.  Tiny-scale configs collide constantly
    (every square projection is (128, 128), including RG-LRU gates and
    patch projections that legitimately run dense), so the lint driver
    filters through the param tree: if more ≥2-D param leaves carry a
    covered (…, K, N) shape than the plan routes, that shape is
    ambiguous and is not audited.  Stacked leaves (scan segments, MoE
    experts) count once — they share one traced matmul, exactly like
    their union-reduced plan.
    """
    import jax

    from repro.analysis.invariants import _walk_plan_leaves
    covered: Dict[Tuple[int, int], str] = {}
    plan_counts: Dict[Tuple[int, int], int] = {}
    for path, plan in _walk_plan_leaves(plan_tree):
        if plan.counts_t is None:
            continue
        s = (int(plan.counts_t.shape[0]) * plan.tile,
             int(plan.counts.shape[0]) * plan.tile)
        covered.setdefault(s, path)
        plan_counts[s] = plan_counts.get(s, 0) + 1
    leaf_counts: Dict[Tuple[int, int], int] = {}
    for leaf in jax.tree.leaves(params):
        if getattr(leaf, "ndim", 0) >= 2:
            s = tuple(int(d) for d in leaf.shape[-2:])
            leaf_counts[s] = leaf_counts.get(s, 0) + 1
    return {s: label for s, label in covered.items()
            if leaf_counts.get(s, 0) <= plan_counts[s]}


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Yield every equation of a (Closed)Jaxpr, recursing through
    call/control-flow sub-jaxprs but treating ``_OPAQUE_PRIMS`` bodies
    as leaves."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr → Jaxpr
    for eqn in jx.eqns:
        yield eqn
        if eqn.primitive.name in _OPAQUE_PRIMS:
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    for v in params.values():
        for item in (v if isinstance(v, (list, tuple)) else (v,)):
            if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                yield item


def _is_jitted(fn) -> bool:
    import jax
    return isinstance(fn, (jax.stages.Wrapped,)) or \
        type(fn).__name__ in ("PjitFunction", "CompiledFunction")


def audit_closure(fn, args: Iterable[Any], *,
                  covered: Optional[Dict[Tuple[int, int], str]] = None,
                  where: str = "closure",
                  expect_jitted: bool = True,
                  kwargs: Optional[dict] = None) -> List[Finding]:
    """Trace ``fn(*args)`` abstractly and audit the jaxpr.

    ``args`` may be ``ShapeDtypeStruct``s or concrete arrays — nothing
    executes.  ``covered`` maps plan-covered weight shapes to labels
    (``collect_covered``); None skips the routing rules (J201/J205).
    """
    import jax
    import numpy as np

    findings: List[Finding] = []
    if expect_jitted and not _is_jitted(fn):
        findings.append(warning(
            "J204", where,
            f"closure is {type(fn).__name__}, not a jitted function — "
            f"every call retraces and dispatches op-by-op"))
    try:
        jaxpr = jax.make_jaxpr(fn)(*args, **(kwargs or {}))
    except Exception as e:  # trace failure is itself a finding
        findings.append(error(
            "J204", where,
            f"could not trace the closure abstractly: "
            f"{type(e).__name__}: {e}"))
        return findings

    n_pallas = 0
    f64_seen: set = set()
    cb_seen: set = set()
    dense_hits: Dict[Tuple[int, int], int] = {}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _OPAQUE_PRIMS:
            n_pallas += 1
            continue
        if "callback" in name and name not in cb_seen:
            cb_seen.add(name)
            findings.append(warning(
                "J203", where,
                f"host callback primitive {name!r} in the trace — every "
                f"step round-trips to Python (debug print/jax.debug "
                f"left in a hot path?)"))
        if covered and name == "dot_general":
            # weight operand is the rhs; covered shapes are (K, N)
            rhs = eqn.invars[-1].aval
            shape = tuple(int(d) for d in getattr(rhs, "shape", ()))
            if len(shape) >= 2 and shape[-2:] in covered:
                dense_hits[shape[-2:]] = dense_hits.get(shape[-2:], 0) + 1
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and np.dtype(dt) == np.float64 and \
                    "f64" not in f64_seen:
                f64_seen.add("f64")
                findings.append(warning(
                    "J202", where,
                    f"float64 value produced by {name!r} — accidental "
                    f"x64 promotion doubles bytes moved on the hot "
                    f"path (check jax_enable_x64 / python-float "
                    f"constants)"))
    for shape, n in sorted(dense_hits.items()):
        findings.append(error(
            "J201", where,
            f"dense dot_general on weight shape {shape} ({n}x) — a "
            f"TilePlan covers this projection "
            f"({covered[shape]}); the block-sparse route was bypassed"))
    if covered and n_pallas == 0:
        findings.append(error(
            "J205", where,
            f"plan covers {len(covered)} projection shape(s) but the "
            f"trace contains no pallas_call — block-sparse routing is "
            f"disabled for this whole path"))
    return findings


def audit_engine_sharding(engine, *, where: str = "engine") -> List[Finding]:
    """J208: a ``ServeEngine`` on a >1-device mesh whose hot-path
    params never got a ``NamedSharding`` placement.

    The jitted prefill/decode closures pick their GSPMD partitioning up
    from their operands — params that were never ``device_put`` with
    the rules' NamedShardings leave every device running the full dense
    computation (correct outputs, none of the mesh's speedup, N× the
    memory).  No NamedSharding at all is an error; NamedShardings that
    are all fully replicated (no mesh axis appears in any spec) is a
    warning — legal for degenerate configs, almost certainly a
    divisibility bug at real scale.
    """
    import jax

    from jax.sharding import NamedSharding

    findings: List[Finding] = []
    mesh = getattr(engine, "mesh", None)
    if mesh is None or mesh.size <= 1:
        return findings
    for g in engine.generations:
        gwhere = f"{where}/gen{g.gid}"
        leaves = [l for l in jax.tree.leaves(g.params)
                  if hasattr(l, "sharding")]
        named = [l for l in leaves
                 if isinstance(l.sharding, NamedSharding)]
        if not named:
            findings.append(error(
                "J208", gwhere,
                f"engine mesh has {mesh.size} devices but none of the "
                f"{len(leaves)} param leaves carries a NamedSharding — "
                f"the jitted hot paths run fully replicated"))
            continue
        partitioned = [l for l in named
                       if any(s is not None for s in l.sharding.spec)]
        if not partitioned:
            findings.append(warning(
                "J208", gwhere,
                f"all {len(named)} NamedSharding'd param leaves are "
                f"fully replicated on a {mesh.size}-device mesh — no "
                f"dimension divided (shape/mesh mismatch?)"))
    return findings


def audit_compiled(fn, args: Iterable[Any], *,
                   where: str = "compiled",
                   kwargs: Optional[dict] = None) -> List[Finding]:
    """Lower+compile ``fn`` and cross-check the optimized HLO text.

    Slower than the abstract trace (XLA actually compiles), so the lint
    driver only runs it when asked (``--hlo``).  Reuses
    ``launch.hlo_analysis`` parsing: an f64 tensor surviving into the
    optimized module is J206; collective traffic is surfaced as J207
    info (single-host lint traces should have none).
    """
    import jax

    findings: List[Finding] = []
    try:
        jitted = fn if _is_jitted(fn) else jax.jit(fn)
        text = jitted.lower(*args, **(kwargs or {})).compile().as_text()
    except Exception as e:
        findings.append(error(
            "J204", where,
            f"could not compile the closure: {type(e).__name__}: {e}"))
        return findings
    findings.extend(audit_hlo_text(text, where=where))
    return findings


def audit_hlo_text(text: str, *, where: str = "hlo") -> List[Finding]:
    """The J206/J207 checks on an optimized HLO module text."""
    from repro.launch.hlo_analysis import collective_bytes, hlo_dtype_census

    findings: List[Finding] = []
    census = hlo_dtype_census(text)
    if census.get("f64"):
        findings.append(warning(
            "J206", where,
            f"optimized HLO contains {census['f64']} f64 shape(s) — an "
            f"x64 promotion survived compilation"))
    coll = collective_bytes(text)
    if coll.total_bytes:
        findings.append(info(
            "J207", where,
            f"compiled module moves {coll.total_bytes} collective "
            f"bytes: " +
            ", ".join(f"{k}×{coll.count_by_kind[k]}"
                      for k in sorted(coll.bytes_by_kind))))
    return findings
