"""Invariant verifier: the sparsity data structures, re-derived.

Every check recomputes its invariant from an independent definition —
the mask's tile bitmap, the crossbar cell accounting identities — and
compares against the structure under test, so drift in ANY of the
builders (``make_tile_plan``, ``build_decode_plan``, ``xbar_stats``,
the engine's generation bookkeeping) surfaces as a structured finding
rather than as silently-wrong serving math.

Rule codes P101–P116; see ``analysis.findings.RULES``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.findings import Finding, error
from repro.kernels.bsmm import GeometryError, TilePlan, tile_bitmap
from repro.models.plans import (_ATTN_KEYS, _EXPERT_KEYS, _MLP_KEYS,
                                PlanStats, _union_mask, build_decode_plan)


def _check_half(idx: np.ndarray, counts: np.ndarray, cap: int,
                other_t: int, bitmap: np.ndarray, transposed: bool,
                where: str, findings: List[Finding]) -> None:
    """One direction of a plan (forward or transposed) vs the bitmap.

    ``bitmap`` is oriented (Kt, Nt) for the forward half and (Nt, Kt)
    for the transposed half, so in both cases ``idx[j]`` lists live row
    indices of bitmap column j.
    """
    side = "idx_t/counts_t" if transposed else "idx/counts"
    codes = {"bounds": "P101", "counts": "P102", "set": "P103",
             "cap": "P104"}
    if transposed:
        # transpose disagreements all report under the transpose rule
        codes = {k: "P105" for k in codes}
    n_cols, n_rows = bitmap.shape[1], bitmap.shape[0]
    if idx.shape[0] != n_cols or counts.shape[0] != n_cols:
        findings.append(error(
            codes["bounds"], where,
            f"{side}: lengths {idx.shape[0]}/{counts.shape[0]} != "
            f"{n_cols} tile columns"))
        return
    if idx.shape[1] != cap:
        findings.append(error(
            codes["bounds"], where,
            f"{side}: idx width {idx.shape[1]} != declared max {cap}"))
        return
    if idx.size and (idx.min() < 0 or idx.max() >= n_rows):
        findings.append(error(
            codes["bounds"], where,
            f"{side}: tile index out of bounds [0, {n_rows}): "
            f"min={int(idx.min())} max={int(idx.max())}"))
        return
    want_counts = (bitmap != 0).sum(axis=0).astype(np.int64)
    if int(want_counts.max(initial=0)) > cap:
        findings.append(error(
            codes["cap"], where,
            f"{side}: declared max {cap} < densest column "
            f"{int(want_counts.max())} — live tiles would be dropped"))
        return
    if not np.array_equal(counts.astype(np.int64), want_counts):
        bad = int(np.flatnonzero(counts.astype(np.int64)
                                 != want_counts)[0])
        findings.append(error(
            codes["counts"], where,
            f"{side}: counts disagree with the mask bitmap (first at "
            f"tile column {bad}: plan={int(counts[bad])} "
            f"mask={int(want_counts[bad])})"))
        return
    for j in range(n_cols):
        got = set(int(v) for v in idx[j, :int(counts[j])])
        want = set(int(v) for v in np.flatnonzero(bitmap[:, j] != 0))
        if got != want:
            findings.append(error(
                codes["set"], where,
                f"{side}: live set of tile column {j} disagrees with "
                f"the mask (plan-only={sorted(got - want)}, "
                f"mask-only={sorted(want - got)})"))
            return


def verify_tile_plan(plan: TilePlan, mask=None, *,
                     where: str = "plan") -> List[Finding]:
    """One ``TilePlan`` vs its source elementwise mask.

    Without a mask only the internal structure is checked (bounds,
    widths, accounting); with the mask every component — forward half,
    transposed half, flat coords, live/total counts — is compared to a
    freshly reduced tile bitmap.
    """
    findings: List[Finding] = []
    idx = np.asarray(plan.idx)
    counts = np.asarray(plan.counts)
    Nt = counts.shape[0]
    if plan.counts_t is None or plan.idx_t is None or plan.kk is None:
        findings.append(error(
            "P101", where,
            "plan lacks backward metadata (idx_t/counts_t/kk/nn) — "
            "built by something other than make_tile_plan?"))
        return findings
    Kt = np.asarray(plan.counts_t).shape[0]

    if mask is not None:
        m2 = _union_mask(mask)
        if m2 is None:
            findings.append(error(
                "P108", where,
                f"mask is not 2-D-reducible (ndim={np.ndim(mask)}) but "
                f"a plan exists for it"))
            return findings
        K, N = m2.shape
        if K % plan.tile or N % plan.tile or \
                K // plan.tile != Kt or N // plan.tile != Nt:
            findings.append(error(
                "P108", where,
                f"mask {m2.shape} does not match the plan's geometry "
                f"({Kt}x{Nt} tiles of {plan.tile})"))
            return findings
        bitmap = tile_bitmap(m2, plan.tile, plan.tile)
    else:
        bitmap = None

    if bitmap is None:
        # structure-only: derive a bitmap from the forward half so the
        # transposed half and flat coords can still be cross-checked
        bitmap = np.zeros((Kt, Nt), np.int32)
        ok = idx.ndim == 2 and idx.shape[0] == Nt and \
            counts.shape[0] == Nt and \
            (not idx.size or (idx.min() >= 0 and idx.max() < Kt))
        if not ok:
            findings.append(error(
                "P101", where,
                f"idx/counts malformed: idx{idx.shape} counts"
                f"{counts.shape} for {Kt}x{Nt} tiles"))
            return findings
        for j in range(Nt):
            c = int(counts[j])
            if c > idx.shape[1]:
                findings.append(error(
                    "P104", where,
                    f"counts[{j}]={c} exceeds idx width "
                    f"{idx.shape[1]} (kmax={plan.kmax})"))
                return findings
            bitmap[idx[j, :c], j] = 1

    _check_half(idx, counts, plan.kmax, Kt, bitmap, False, where,
                findings)
    _check_half(np.asarray(plan.idx_t), np.asarray(plan.counts_t),
                plan.nmax, Nt, bitmap.T, True, where, findings)

    kk = np.asarray(plan.kk)
    nn = np.asarray(plan.nn)
    want_kk, want_nn = np.nonzero(bitmap)
    if not (np.array_equal(np.sort(kk * Nt + nn),
                           np.sort(want_kk * Nt + want_nn))):
        findings.append(error(
            "P106", where,
            f"flat live-tile coords (kk/nn) disagree with the bitmap: "
            f"{kk.shape[0]} listed vs {want_kk.shape[0]} live tiles"))
    live = int((bitmap != 0).sum())
    if plan.live_tiles != live or plan.total_tiles != bitmap.size:
        findings.append(error(
            "P107", where,
            f"tile accounting: plan says {plan.live_tiles}/"
            f"{plan.total_tiles}, bitmap says {live}/{bitmap.size}"))
    return findings


def _plans_equal(a: TilePlan, b: TilePlan) -> bool:
    if a.tile != b.tile or a.kmax != b.kmax or a.nmax != b.nmax or \
            a.live_tiles != b.live_tiles or a.total_tiles != b.total_tiles:
        return False
    pairs = ((a.idx, b.idx), (a.counts, b.counts), (a.idx_t, b.idx_t),
             (a.counts_t, b.counts_t), (a.kk, b.kk), (a.nn, b.nn))
    return all((x is None) == (y is None) and
               (x is None or np.array_equal(x, y)) for x, y in pairs)


def _walk_plan_leaves(plan, prefix: str = ""):
    """Yield (path, TilePlan) over the nested decode-plan structure."""
    if plan is None:
        return
    if isinstance(plan, TilePlan):
        yield prefix, plan
        return
    if isinstance(plan, dict):
        for k, v in plan.items():
            yield from _walk_plan_leaves(v, f"{prefix}.{k}" if prefix
                                         else str(k))
        return
    if isinstance(plan, (list, tuple)):
        for i, v in enumerate(plan):
            yield from _walk_plan_leaves(v, f"{prefix}[{i}]" if prefix
                                         else f"[{i}]")


def verify_decode_plan(masks, plan, stats: Optional[PlanStats] = None, *,
                       tile: Optional[int] = None,
                       where: str = "decode_plan") -> List[Finding]:
    """A built decode plan vs the masks' tile reduction.

    Rebuilds the plan from the masks with the same walker and demands
    structural identity: every entry present in both, every ``TilePlan``
    bit-identical (P109), and the recorded ``PlanStats`` totals in
    agreement (P110).  Each present leaf is additionally verified
    against its union mask with ``verify_tile_plan`` — defence in depth
    against a walker bug that corrupts both sides identically in
    structure but not against the mask itself.
    """
    findings: List[Finding] = []
    kw = {} if tile is None else {"tile": tile}
    try:
        want_plan, want_stats = build_decode_plan(masks, **kw)
    except GeometryError as e:
        return [error("P108", where, str(e))]

    got = dict(_walk_plan_leaves(plan))
    want = dict(_walk_plan_leaves(want_plan))
    for path in sorted(set(want) - set(got)):
        findings.append(error(
            "P109", f"{where}/{path}",
            "mask has a routable projection here but the plan has no "
            "entry — the matmul will silently run dense"))
    for path in sorted(set(got) - set(want)):
        findings.append(error(
            "P109", f"{where}/{path}",
            "plan has an entry the masks do not motivate — stale plan "
            "from different masks?"))
    for path in sorted(set(got) & set(want)):
        if not _plans_equal(got[path], want[path]):
            findings.append(error(
                "P109", f"{where}/{path}",
                "plan entry differs from the masks' tile reduction "
                "(stale or corrupted plan)"))

    # leaf-level verification against the union masks themselves
    for path, leaf_mask in _iter_mask_projections(masks):
        if path in got:
            findings.extend(verify_tile_plan(
                got[path], leaf_mask, where=f"{where}/{path}"))

    if stats is not None:
        agg_live = sum(p.live_tiles for p in got.values())
        agg_total = sum(p.total_tiles for p in got.values())
        if (stats.live_tiles, stats.total_tiles,
                stats.routed) != (agg_live, agg_total, len(got)):
            findings.append(error(
                "P110", where,
                f"PlanStats says routed={stats.routed} live="
                f"{stats.live_tiles}/{stats.total_tiles}; the plan's "
                f"leaves sum to routed={len(got)} live={agg_live}/"
                f"{agg_total}"))
    return findings


def _iter_mask_projections(masks):
    """Yield (plan-path, mask-leaf) for every routable projection, in
    the same path syntax ``_walk_plan_leaves`` produces."""
    if not isinstance(masks, dict) or "segments" not in masks:
        return
    for s_idx, pos_trees in enumerate(masks["segments"]):
        for pos, ptree in enumerate(pos_trees):
            if not isinstance(ptree, dict):
                continue
            attn = ptree.get("attn")
            if isinstance(attn, dict) and "wq" in attn:
                for k in _ATTN_KEYS:
                    if attn.get(k) is not None:
                        yield f"[{s_idx}][{pos}].attn.{k}", attn[k]
            mlp = ptree.get("mlp")
            if isinstance(mlp, dict):
                for k in _MLP_KEYS:
                    if mlp.get(k) is not None:
                        yield f"[{s_idx}][{pos}].mlp.{k}", mlp[k]
            moe = ptree.get("moe")
            if isinstance(moe, dict):
                for k in _EXPERT_KEYS:
                    if moe.get(k) is not None:
                        yield f"[{s_idx}][{pos}].moe.{k}", moe[k]
                shared = moe.get("shared")
                if isinstance(shared, dict):
                    for k in _MLP_KEYS:
                        if shared.get(k) is not None:
                            yield (f"[{s_idx}][{pos}].moe.shared.{k}",
                                   shared[k])


def verify_xbar_stats(st, mask_matrix: np.ndarray, *,
                      where: str = "xbar") -> List[Finding]:
    """``XbarStats`` cell-accounting identities vs the mask matrix.

    The identities hold by construction when ``xbar_stats`` is healthy;
    the point is to catch drift between the two independent accounting
    routes (per-block saved/live cells vs whole-matrix nonzeros)."""
    findings: List[Finding] = []
    m = np.asarray(mask_matrix) != 0
    R, C = m.shape
    xr, xc = st.xbar_rows, st.xbar_cols
    n_r = -(-R // xr)
    n_c = -(-C // xc)
    checks = [
        ("n_xbars", st.n_xbars, n_r * n_c),
        ("total_cells", st.total_cells, R * C),
        ("nonzero_cells", st.nonzero_cells, int(m.sum())),
        ("saved+live", st.saved_cells + st.live_area, R * C),
        ("strict+free",
         st.xbars_needed_strict + st.xbars_fully_free, st.n_xbars),
        ("packed", st.xbars_needed_packed, -(-st.live_area // (xr * xc))),
    ]
    for name, got, want in checks:
        if int(got) != int(want):
            findings.append(error(
                "P111", where,
                f"XbarStats {name}: {int(got)} != expected "
                f"{int(want)} for mask {m.shape} at {xr}x{xc}"))
    if not (0 <= st.xbars_needed_packed <= st.xbars_needed_strict
            <= st.n_xbars):
        findings.append(error(
            "P111", where,
            f"XbarStats ordering violated: "
            f"packed={st.xbars_needed_packed} "
            f"strict={st.xbars_needed_strict} total={st.n_xbars}"))
    # every kept weight sits in a live row AND a live column, so the
    # live area can never undercount the nonzeros
    if st.nonzero_cells > st.live_area:
        findings.append(error(
            "P111", where,
            f"XbarStats live_area={st.live_area} < nonzero_cells="
            f"{st.nonzero_cells} — live rows/cols dropped kept weights"))
    return findings


def verify_mask_accounting(masks, conv_pred=None, *, rows: int,
                           cols: int, where: str = "masks",
                           max_leaves: Optional[int] = None
                           ) -> List[Finding]:
    """Recompute ``xbar_stats`` for every prunable mask leaf and check
    the accounting identities (P111).

    Walks the mask pytree the way ``core.hardware.analyze_masks`` does:
    each non-None leaf is unrolled with ``leaf_matrices`` (conv leaves
    per ``conv_pred``) and every matrix of the batch gets its own stats
    pass.  ``max_leaves`` caps work on big trees (lint runs at tiny
    scale, so usually unbounded)."""
    import jax

    from repro.core.crossbar import leaf_matrices, xbar_stats
    from repro.core.masks import path_str
    findings: List[Finding] = []
    budget = [max_leaves]

    def visit(path, leaf):
        if leaf is None:
            return leaf
        if budget[0] is not None:
            if budget[0] <= 0:
                return leaf
            budget[0] -= 1
        p = path_str(path)
        raw = np.asarray(leaf)
        conv = bool(conv_pred(p)) if conv_pred is not None else False
        try:
            mats, _ = leaf_matrices(raw, conv)
        except (ValueError, AssertionError):
            return leaf  # non-matrix leaf (bias, scalar gate) — no cells
        for b in range(mats.shape[0]):
            m2 = mats[b] != 0
            lw = f"{where}/{p}" if mats.shape[0] == 1 \
                else f"{where}/{p}[{b}]"
            findings.extend(verify_xbar_stats(
                xbar_stats(m2, rows, cols), m2, where=lw))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return findings


def verify_engine(engine, *, where: str = "engine") -> List[Finding]:
    """Cross-generation consistency inside a (possibly swapped)
    ``ServeEngine``: distinct gids, every generation's plan identical
    to the tile reduction of its own masks, and the engine report's
    skipped-tile fraction agreeing with the newest generation (P112).
    Paged engines additionally get the block-pool/table checks
    (P113/P115) via ``verify_paged_engine``.
    """
    findings: List[Finding] = []
    gens = engine.generations
    gids = [g.gid for g in gens]
    if len(set(gids)) != len(gids):
        findings.append(error(
            "P112", where,
            f"duplicate generation ids: {gids}"))
    for g in gens:
        gwhere = f"{where}/gen{g.gid}"
        if g.masks is None:
            if g.plan is not None:
                findings.append(error(
                    "P112", gwhere,
                    "generation has a tile plan but no masks"))
            continue
        if g.plan is None:
            # legal: use_bsmm=False or masks without routable structure
            continue
        sub = verify_decode_plan(g.masks, g.plan, g.plan_stats,
                                 where=gwhere)
        findings.extend(
            error("P112", f.where, f"[{f.code}] {f.msg}") for f in sub)
    if gens and gens[-1].plan is not None:
        rep = engine.report
        want = gens[-1].plan_stats.skipped_tile_fraction
        if abs(rep.skipped_tile_fraction - want) > 1e-9:
            findings.append(error(
                "P112", where,
                f"report.skipped_tile_fraction="
                f"{rep.skipped_tile_fraction:.6f} disagrees with the "
                f"newest generation's {want:.6f}"))
    if getattr(engine, "paged", False):
        findings.extend(verify_paged_engine(engine, where=where))
    return findings


# ---------------------------------------------------------------------------
# Paged KV cache: block pools, block tables, logical reconstruction
# ---------------------------------------------------------------------------
def verify_block_pool(pool, *, where: str = "pool") -> List[Finding]:
    """``BlockPool`` accounting (P115), re-derived from its raw state.

    Runs the pool's own ``check()`` (double-tracking, leaks) and then
    independently recomputes the balance identity
    ``free + live + scratch == capacity`` and the reservation bound, so
    drift in either the allocator or its self-check surfaces here.
    """
    from repro.serve.paging import PoolError
    findings: List[Finding] = []
    try:
        pool.check()
    except PoolError as e:
        findings.append(error("P115", where, str(e)))
        return findings
    free = len(pool._free)
    total = free + pool.live + len(pool.reserved_ids)
    if total != pool.num_blocks:
        findings.append(error(
            "P115", where,
            f"free({free}) + live({pool.live}) + "
            f"scratch({len(pool.reserved_ids)}) = {total} != capacity "
            f"{pool.num_blocks}"))
    if pool.outstanding > free:
        findings.append(error(
            "P115", where,
            f"outstanding reservations ({pool.outstanding}) exceed the "
            f"free list ({free}) — a guaranteed alloc would fail"))
    if pool.available != free - pool.outstanding:
        findings.append(error(
            "P115", where,
            f"available={pool.available} != free({free}) - "
            f"outstanding({pool.outstanding})"))
    return findings


def verify_block_tables(pool, tables, lens, slot_nblocks, uids, *,
                        block_tokens: int,
                        where: str = "tables") -> List[Finding]:
    """Block tables vs pool ownership (P113).

    For every active slot: the row's live prefix must list exactly the
    blocks the pool says that request owns, in logical order, with no
    block referenced by two slots, no scratch/out-of-range id used as a
    live block, the block count matching ``ceil(len / BLOCK)``, and the
    dead tail parked on the scratch block.  Inactive slots must be
    fully reset.
    """
    findings: List[Finding] = []
    tables = np.asarray(tables)
    lens = np.asarray(lens)
    nbs = np.asarray(slot_nblocks)
    scratch = set(pool.reserved_ids)
    seen: Dict[int, int] = {}
    for s, uid in enumerate(uids):
        sw = f"{where}/slot{s}"
        row = tables[s]
        if uid is None:
            if int(nbs[s]) or int(lens[s]) or \
                    any(int(v) not in scratch for v in row):
                findings.append(error(
                    "P113", sw,
                    "inactive slot still holds table state "
                    f"(nblocks={int(nbs[s])} len={int(lens[s])})"))
            continue
        n, nb = int(lens[s]), int(nbs[s])
        want_nb = -(-n // block_tokens)
        if nb != want_nb:
            findings.append(error(
                "P113", sw,
                f"uid {uid}: {nb} blocks held for {n} tokens "
                f"(want ceil({n}/{block_tokens}) = {want_nb})"))
            continue
        live = [int(v) for v in row[:nb]]
        bad = [v for v in live
               if v in scratch or not 0 <= v < pool.num_blocks]
        if bad:
            findings.append(error(
                "P113", sw,
                f"uid {uid}: live entries reference scratch/out-of-"
                f"range blocks {bad}"))
            continue
        if list(pool.owned(uid)) != live:
            findings.append(error(
                "P113", sw,
                f"uid {uid}: table row {live} disagrees with pool "
                f"ownership {list(pool.owned(uid))}"))
            continue
        for v in live:
            if v in seen:
                findings.append(error(
                    "P113", sw,
                    f"block {v} referenced by slot {seen[v]} and "
                    f"slot {s}"))
            seen[v] = s
        if any(int(v) not in scratch for v in row[nb:]):
            findings.append(error(
                "P113", sw,
                f"uid {uid}: dead table entries past block {nb} are "
                f"not parked on the scratch block"))
    return findings


def verify_paged_reconstruction(paged_caches, dense_caches, blocks,
                                length: int, *,
                                where: str = "paged") -> List[Finding]:
    """Logical-order reconstruction vs the dense oracle (P114).

    ``dense_caches`` is a single request's exact ``prefill`` output
    (B=1); ``blocks`` its adopted physical block ids in logical order.
    Gathering every layer's pool rows through ``blocks`` and trimming to
    ``length`` must reproduce the dense cache bit-for-bit — adopt and
    append are pure copies, so any tolerance would hide an indexing bug.
    """
    findings: List[Finding] = []
    blocks = np.asarray(blocks)

    def gather(pool):
        rows = np.asarray(pool)[blocks]          # (nb, T, H, d)
        return rows.reshape(-1, *rows.shape[2:])[:length]

    def check(pool, want, path):
        pool = np.asarray(pool)
        want = np.asarray(want)
        stacked = pool.ndim == 5                 # leading scan-reps axis
        pools = pool if stacked else pool[None]
        wants = want if stacked else want[None]
        for r in range(pools.shape[0]):
            got = gather(pools[r])
            oracle = wants[r][0, :length].astype(got.dtype)
            if got.shape != oracle.shape or \
                    not np.array_equal(got, oracle):
                diff = float(np.abs(got.astype(np.float32)
                                    - oracle.astype(np.float32)).max()) \
                    if got.shape == oracle.shape else float("nan")
                rp = f"{path}[rep{r}]" if stacked else path
                findings.append(error(
                    "P114", f"{where}/{rp}",
                    f"gathered pool rows != dense oracle over "
                    f"{length} tokens (max |diff| = {diff})"))
                return

    for si, (seg_p, seg_d) in enumerate(zip(paged_caches, dense_caches)):
        for pi, (pc, dc) in enumerate(zip(seg_p, seg_d)):
            path = f"seg{si}.{pi}"
            if hasattr(pc, "k_pool"):            # GQA
                check(pc.k_pool, dc.k, f"{path}.k")
                check(pc.v_pool, dc.v, f"{path}.v")
            else:                                # absorbed MLA
                # the pool stores concat(c_kv, k_rope) as one "kv head"
                want = np.concatenate(
                    [np.asarray(dc.c_kv), np.asarray(dc.k_rope)],
                    axis=-1)[..., None, :]       # (..., B, S, 1, r+dr)
                check(pc.pool, want, path)
    return findings


def verify_paged_engine(engine, *, where: str = "engine") -> List[Finding]:
    """Pool + table consistency across every generation of a paged
    ``ServeEngine`` (P113/P115) — including generations parked by a
    hot-swap, whose draining requests still own blocks.
    """
    from repro.kernels.paged_attention import BLOCK_TOKENS
    findings: List[Finding] = []
    for g in engine.generations:
        if getattr(g, "pool", None) is None:
            continue
        gwhere = f"{where}/gen{g.gid}"
        findings.extend(verify_block_pool(g.pool, where=f"{gwhere}/pool"))
        uids = [None if r is None else r.uid for r in g.slot_reqs]
        findings.extend(verify_block_tables(
            g.pool, g.tables, g.lens, g.slot_nblocks, uids,
            block_tokens=BLOCK_TOKENS, where=f"{gwhere}/tables"))
    return findings


# ---------------------------------------------------------------------------
# Fleet accounting: each uid finishes once, merged totals balance
# ---------------------------------------------------------------------------
def verify_fleet(router, *, where: str = "fleet") -> List[Finding]:
    """``FleetRouter`` accounting identities (P116), re-derived from the
    logical records and per-engine reports.

    A failover moves a request between engines: the invariants below
    say the move is loss- and duplication-free — every submitted uid
    reaches a terminal state in exactly one engine (once the router is
    idle), and the merged report's totals equal the per-engine sums
    (every token was generated by exactly one engine; every finish was
    booked by exactly one engine).  Live engines additionally get the
    cross-generation checks (P112/P113/P115) via ``verify_engine``.
    """
    findings: List[Finding] = []
    rep = router.report

    # each uid finishes at most once (exactly once when drained)
    seen: Dict[Any, int] = {}
    for rec in router.finished:
        seen[rec.uid] = seen.get(rec.uid, 0) + 1
    for uid, n in seen.items():
        if n > 1:
            findings.append(error(
                "P116", f"{where}/uid{uid}",
                f"request finished {n} times across engines"))
    for rec in router.finished:
        if not rec.done:
            findings.append(error(
                "P116", f"{where}/uid{rec.uid}",
                f"finished list holds a non-terminal record "
                f"(status={rec.status!r})"))
    if router.idle:
        rejected = {rec.uid for rec in router.rejected}
        lost = [uid for uid, rec in router.records.items()
                if not rec.done and uid not in rejected
                and uid not in seen]
        if lost:
            findings.append(error(
                "P116", where,
                f"router is idle but {len(lost)} submitted uid(s) never "
                f"finished (lost in dispatch/failover): {lost[:8]}"))

    # merged totals == per-engine sums
    per = rep.per_engine
    eng_tokens = sum(p.tokens_generated for p in per)
    if eng_tokens != rep.tokens_generated:
        findings.append(error(
            "P116", where,
            f"merged tokens_generated={rep.tokens_generated} but the "
            f"engines generated {eng_tokens} (a token was double-booked "
            f"or dropped)"))
    eng_requests = sum(p.requests for p in per)
    if eng_requests != len(router.finished):
        findings.append(error(
            "P116", where,
            f"engines finished {eng_requests} requests but the router "
            f"booked {len(router.finished)} logical finishes (a request "
            f"finished in zero or multiple engines)"))
    if rep.requests != len(router.finished):
        findings.append(error(
            "P116", where,
            f"report.requests={rep.requests} disagrees with the "
            f"finished list ({len(router.finished)})"))

    for i, fe in enumerate(router.frontends):
        if i in router.live:
            findings.extend(
                verify_engine(fe.engine, where=f"{where}/engine{i}"))
    return findings
