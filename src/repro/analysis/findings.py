"""Shared finding model + the central rule registry for the sparsity
lint.

Every analyzer — the recipe linter, the invariant verifier, the jaxpr
auditor, the kernel auditor — reports through one structured
``Finding(severity, code, where, msg)`` so the CLI, CI gate, and tests
consume a single surface.

Rule codes are STABLE identifiers: a code never changes meaning, new
rules get new codes.  ``RULES`` maps every code to a ``Rule`` (code →
one-line title → docstring); it is the single source of truth — the
README's rule table is *generated* from it (``rules_markdown``, a test
asserts they agree), ``lint --explain CODE`` prints ``explain(code)``,
and emitting an unregistered code is itself a bug
(``Finding.__post_init__`` raises).

Severities:
  error   — the sparsity contract is broken: a silently-dense hot path,
            a plan inconsistent with its mask, a recipe that cannot
            run, a kernel launch that reads out of bounds.  The CLI
            exits nonzero on any error finding.
  warning — legal but almost certainly unintended (QAT before pruning,
            unreachable sparsity targets, f64 in a hot trace).
  info    — measurements worth surfacing (HLO collective traffic).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Rule:
    """One registered rule: stable ``code``, one-line ``title`` (the
    README table row), and a ``doc`` paragraph (``lint --explain``)."""
    code: str
    title: str
    doc: str

    @property
    def family(self) -> str:
        return {"R": "recipe linter", "P": "invariant verifier",
                "J": "jaxpr auditor", "K": "kernel auditor"}.get(
                    self.code[:1], "unknown")


_ALL_RULES: Tuple[Rule, ...] = (
    # recipe linter -------------------------------------------------------
    Rule("R001", "recipe/stage does not validate (construction failed)",
         "The recipe or one of its stages failed to construct at all — "
         "bad stage kind, malformed field, or a validation error raised "
         "by Recipe/Stage.  Nothing downstream can run until it builds."),
    Rule("R002", "prune granularity unknown to the target family",
         "A prune stage names a granularity the target family's "
         "strategy registry does not provide (e.g. 'expert' on a dense "
         "model).  The session would fail at stage entry."),
    Rule("R003", "non-monotonic target_sparsity: stage target already "
         "met by an earlier stage (dead stage)",
         "Stage targets must increase: a stage whose target_sparsity "
         "was already reached by an earlier stage commits no masks and "
         "silently does nothing."),
    Rule("R004", "non-positive retrain budget (0 silently falls back "
         "to the adapter default — it does NOT mean 'no retraining')",
         "retrain_steps <= 0 does not disable retraining; the adapter "
         "substitutes its own default budget.  Say what you mean with "
         "an explicit positive budget."),
    Rule("R005", "quantize stage before any prune stage (QAT "
         "calibrates a dense model)",
         "Quantization-aware calibration on the dense network is "
         "invalidated by the pruning that follows — the gate accepted "
         "ranges the pruned weights no longer have."),
    Rule("R006", "prune stage after a quantize stage (invalidates the "
         "QAT calibration the quantize gate accepted)",
         "Pruning after an accepted quantize stage changes the weight "
         "distribution the quantize gate validated; re-order or "
         "re-quantize."),
    Rule("R007", "target_sparsity unreachable within max_rounds at the "
         "stage rate",
         "Pruning fraction p per round reaches at most 1-(1-p)^rounds; "
         "a target beyond that leaves the stage spinning its full "
         "round budget and still failing its own exit condition."),
    Rule("R008", "duplicate stage names (resume + event attribution "
         "are keyed by stage identity)",
         "Mid-stage resume and PruneEvent attribution key on the stage "
         "name; duplicates make resume ambiguous."),
    Rule("R009", "recipe commits no masks (no prune stage)",
         "A recipe without any prune stage produces a dense ticket — "
         "legal, but the entire pipeline exists to prune; almost "
         "certainly a mistake."),
    # invariant verifier --------------------------------------------------
    Rule("P101", "TilePlan indices/counts malformed or out of bounds",
         "idx/counts array shapes must match the tile grid and every "
         "index must be a valid tile row — re-derived from the mask's "
         "tile bitmap."),
    Rule("P102", "TilePlan counts disagree with the mask's tile bitmap",
         "counts[j] must equal the number of live K tiles in column j "
         "of the independently recomputed bitmap."),
    Rule("P103", "TilePlan live-index set disagrees with the mask's "
         "tile bitmap",
         "The set of live indices idx[j, :counts[j]] must be exactly "
         "the bitmap's live rows for column j — no missing, no extra, "
         "no stale entries."),
    Rule("P104", "TilePlan kmax/nmax below the max live count",
         "The grid's last dimension is kmax/nmax; a cap below the "
         "true max live count silently drops tiles from the "
         "accumulation."),
    Rule("P105", "transposed plan (idx_t/counts_t) is not the exact "
         "transpose of the forward plan",
         "The dx backward runs off idx_t/counts_t; they must describe "
         "the same bitmap transposed, or forward and backward see "
         "different sparsity."),
    Rule("P106", "flat live-tile coords (kk/nn) disagree with the "
         "bitmap",
         "The dw kernel materialises exactly the tiles listed in "
         "kk/nn; they must be the bitmap's nonzero coordinates in "
         "row-major order."),
    Rule("P107", "live/total tile accounting disagrees with the bitmap",
         "live_tiles/total_tiles feed the perf model and reports; they "
         "must equal the bitmap's popcount and size."),
    Rule("P108", "geometry mismatch: mask shape vs tile/crossbar "
         "geometry",
         "A mask whose shape does not tile evenly at the configured "
         "crossbar geometry cannot be planned; the builder must have "
         "refused or fallen back explicitly."),
    Rule("P109", "decode plan disagrees with the mask's tile reduction "
         "(missing, extra, or stale plan entry)",
         "Per-projection decode plans are re-derived from the masks "
         "and compared entry-by-entry."),
    Rule("P110", "PlanStats totals disagree with the per-projection "
         "plans",
         "Aggregated live/total tile counts must equal the sum over "
         "the plan leaves they claim to summarise."),
    Rule("P111", "packing/XbarStats accounting disagrees with the mask",
         "Crossbar packing statistics (cells, xbars needed, savings) "
         "are recomputed from the raw mask and compared."),
    Rule("P112", "cross-generation inconsistency inside a ServeEngine",
         "After a hot-swap every generation must keep self-consistent "
         "params/masks/plans/caches; stale cross-links between "
         "generations corrupt in-flight decodes."),
    Rule("P113", "paged block table disagrees with the pool's "
         "ownership (unallocated, double-referenced, out-of-bounds, "
         "or off-scratch dead entry)",
         "Every live table entry must point at a block the pool "
         "assigned to that slot, and dead entries must point at the "
         "scratch block so the kernel's masked DMA stays in bounds."),
    Rule("P114", "paged cache gathered in logical block order does not "
         "reconstruct the dense oracle cache",
         "Adopting a dense prefill into the pool and gathering it back "
         "through the table must be bit-exact."),
    Rule("P115", "BlockPool accounting does not balance (free + live + "
         "scratch vs capacity, or reservations exceed free)",
         "The pool's free list, per-slot ownership, scratch block, and "
         "reservation counters must partition capacity exactly."),
    Rule("P116", "fleet accounting broken (a submitted uid finished "
         "zero or multiple times across engines, or merged report "
         "totals disagree with the per-engine sums)",
         "Failover must neither lose nor duplicate requests, and the "
         "merged fleet report must equal the sum of its engines."),
    # jaxpr auditor -------------------------------------------------------
    Rule("J201", "dense dot_general on a weight shape a TilePlan "
         "covers (missed block-sparse routing)",
         "The traced hot path multiplies by a weight whose shape a "
         "plan covers, but through a dense dot_general — the "
         "block-sparse routing was silently skipped."),
    Rule("J202", "float64 value in a hot-path trace (accidental x64 "
         "promotion)",
         "A f64 intermediate in a jitted hot path usually means a "
         "Python float or numpy default dtype leaked into the trace; "
         "on TPU it doubles bandwidth or fails to lower."),
    Rule("J203", "host callback inside a hot-path trace",
         "io_callback/pure_callback/debug print in a decode or train "
         "step synchronises with the host every call."),
    Rule("J204", "hot-path closure is not jitted (per-call "
         "retrace/dispatch)",
         "The closure could not be traced as a jitted computation; "
         "every invocation would pay Python dispatch."),
    Rule("J205", "plan covers projections but the traced closure "
         "issues no pallas_call at all (whole-path routing miss)",
         "A plan exists for this path yet the trace contains zero "
         "Pallas kernels — the entire path fell back to dense."),
    Rule("J206", "compiled artifact contains f64 tensors (HLO "
         "cross-check)",
         "The optimized HLO still carries f64 after compilation — the "
         "promotion survived XLA simplification."),
    Rule("J207", "collective traffic in a hot-path artifact (HLO "
         "cross-check)",
         "all-reduce/all-gather/permute ops in the compiled hot path; "
         "surfaced as info so sharded configs can budget interconnect "
         "traffic deliberately."),
    Rule("J208", "sharded engine's jitted hot path traced on a "
         ">1-device mesh with replicated-only params (missing "
         "NamedSharding placement — GSPMD runs every device dense)",
         "A mesh-backed engine whose params carry no NamedSharding "
         "gives GSPMD nothing to partition: every device computes the "
         "full dense model."),
    # kernel auditor ------------------------------------------------------
    Rule("K300", "kernel spec malformed (grid/blocks inconsistent with "
         "declared shapes)",
         "The KernelSpec itself is unusable: grid rank disagrees with "
         "dimension_semantics, a block shape does not match its "
         "operand's rank or does not tile it evenly, or an index map "
         "does not evaluate over the grid.  Remaining K-rules are "
         "skipped for that kernel."),
    Rule("K301", "output-tile coverage not exact (skipped or "
         "multiply-written output tiles)",
         "Enumerating the grid, the output index map must write every "
         "output tile exactly once: constant along 'arbitrary' grid "
         "axes (the revolving accumulator), and a bijection from the "
         "parallel axes onto the full output tile grid — no tile "
         "skipped on a ragged edge, none double-written."),
    Rule("K302", "input index map or block-table gather out of bounds",
         "Every grid cell's index map — including pl.when-guarded "
         "cells, whose DMA still happens — must land inside the "
         "declared operand shape.  Catches a block-table entry past "
         "the pool and an index map shifted off the edge."),
    Rule("K303", "pl.when guard disagrees with the plan's liveness "
         "(dead blocks read, or live blocks masked off)",
         "For gather kernels, the multiset of blocks the *unguarded* "
         "cells read must equal the live set derived independently "
         "from the truth source (tile bitmap, block table + lengths). "
         "A guard that is too loose streams dead/scratch blocks into "
         "the accumulation; too tight drops live work."),
    Rule("K304", "accumulator/softmax scratch not float32, or scratch "
         "shape mismatched",
         "Streaming accumulators and softmax running state must be "
         "f32 VMEM (bf16 accumulation loses the exactness the oracle "
         "tests assert), and an accumulator's shape must match the "
         "output block it flushes into."),
    Rule("K305", "VMEM footprint estimate exceeds the backend budget",
         "Double-buffered input/output blocks plus scratch at the "
         "planned tile shape must fit the per-backend budget declared "
         "in configs.base.VMEM_BUDGET_BYTES — a launch that audits "
         "red here would OOM VMEM on real hardware."),
    Rule("K306", "kernel spec cost disagrees with the perf model's "
         "passes/FLOPs/bytes prediction",
         "The auditor derives passes/flops/bytes by enumerating the "
         "spec's grid and guard under the no-elision traffic model and "
         "compares against core.perf_model's analytic KernelCost "
         "prediction from plan metadata — so the perf model and the "
         "kernels cannot silently diverge."),
)

# The rule-code registry.  README's "Static analysis" table is generated
# from this dict (``rules_markdown``); tests assert every emitted code
# is registered and every registered code has a seeded-defect test.
RULES: Dict[str, Rule] = {r.code: r for r in _ALL_RULES}


def rules_markdown() -> str:
    """The README rules table, generated from the registry."""
    lines = ["| Code | Checks |", "|------|--------|"]
    for r in _ALL_RULES:
        lines.append(f"| {r.code} | {r.title} |")
    return "\n".join(lines)


def explain(code: str) -> str:
    """Human-readable account of one rule (``lint --explain CODE``)."""
    rule = RULES.get(code.upper())
    if rule is None:
        known = ", ".join(sorted(RULES))
        raise KeyError(f"unknown rule code {code!r}; known: {known}")
    return f"{rule.code} [{rule.family}]\n  {rule.title}\n\n{rule.doc}"


@dataclass(frozen=True)
class Finding:
    """One lint result: ``severity`` ∈ {error, warning, info}, ``code``
    a stable rule id from ``RULES``, ``where`` a location path (e.g.
    ``vgg11/recipe:cnn-full/stage[2]:prune:index`` or
    ``llama3.2-3b/decode/seg0.0.mlp.up``), ``msg`` the human account."""
    severity: str
    code: str
    where: str
    msg: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"known: {SEVERITIES}")
        if self.code not in RULES:
            raise ValueError(f"unregistered rule code {self.code!r} — "
                             f"add it to analysis.findings.RULES")

    def to_dict(self) -> dict:
        return {"severity": self.severity, "code": self.code,
                "where": self.where, "msg": self.msg}

    def __str__(self) -> str:
        return f"[{self.severity.upper():7s}] {self.code} {self.where}: " \
               f"{self.msg}"


def error(code: str, where: str, msg: str) -> Finding:
    return Finding("error", code, where, msg)


def warning(code: str, where: str, msg: str) -> Finding:
    return Finding("warning", code, where, msg)


def info(code: str, where: str, msg: str) -> Finding:
    return Finding("info", code, where, msg)


@dataclass
class Report:
    """An ordered collection of findings with severity accounting."""
    findings: List[Finding] = field(default_factory=list)

    def add(self, f: Finding) -> None:
        self.findings.append(f)

    def extend(self, fs: Iterable[Finding]) -> None:
        self.findings.extend(fs)

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "error")

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "warning")

    @property
    def ok(self) -> bool:
        """True when no error-severity finding was recorded."""
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(f.code for f in self.findings)

    def by_code(self, code: str) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.code == code)

    def summary(self) -> dict:
        counts = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            counts[f.severity] += 1
        return {"findings": len(self.findings), **counts, "ok": self.ok}

    def to_dict(self) -> dict:
        return {"findings": [f.to_dict() for f in self.findings],
                "summary": self.summary()}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)
