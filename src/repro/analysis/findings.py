"""Shared finding model for the sparsity lint.

Every analyzer — the recipe linter, the invariant verifier, the jaxpr
auditor — reports through one structured ``Finding(severity, code,
where, msg)`` so the CLI, CI gate, and tests consume a single surface.

Rule codes are STABLE identifiers (documented in the README's rule
table and asserted by ``tests/test_analysis.py``): a code never changes
meaning, new rules get new codes.  ``RULES`` maps every code to its
one-line contract; emitting an unregistered code is itself a bug
(``Finding.__post_init__`` raises).

Severities:
  error   — the sparsity contract is broken: a silently-dense hot path,
            a plan inconsistent with its mask, a recipe that cannot
            run.  The CLI exits nonzero on any error finding.
  warning — legal but almost certainly unintended (QAT before pruning,
            unreachable sparsity targets, f64 in a hot trace).
  info    — measurements worth surfacing (HLO collective traffic).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Tuple

SEVERITIES = ("error", "warning", "info")

# ---------------------------------------------------------------------------
# The rule-code registry.  README's "Static analysis" table is generated
# from this dict; tests assert every emitted code is registered.
# ---------------------------------------------------------------------------
RULES: Dict[str, str] = {
    # recipe linter -------------------------------------------------------
    "R001": "recipe/stage does not validate (construction failed)",
    "R002": "prune granularity unknown to the target family",
    "R003": "non-monotonic target_sparsity: stage target already met "
            "by an earlier stage (dead stage)",
    "R004": "non-positive retrain budget (0 silently falls back to the "
            "adapter default — it does NOT mean 'no retraining')",
    "R005": "quantize stage before any prune stage (QAT calibrates a "
            "dense model)",
    "R006": "prune stage after a quantize stage (invalidates the QAT "
            "calibration the quantize gate accepted)",
    "R007": "target_sparsity unreachable within max_rounds at the "
            "stage rate",
    "R008": "duplicate stage names (resume + event attribution are "
            "keyed by stage identity)",
    "R009": "recipe commits no masks (no prune stage)",
    # invariant verifier --------------------------------------------------
    "P101": "TilePlan indices/counts malformed or out of bounds",
    "P102": "TilePlan counts disagree with the mask's tile bitmap",
    "P103": "TilePlan live-index set disagrees with the mask's tile "
            "bitmap",
    "P104": "TilePlan kmax/nmax below the max live count",
    "P105": "transposed plan (idx_t/counts_t) is not the exact "
            "transpose of the forward plan",
    "P106": "flat live-tile coords (kk/nn) disagree with the bitmap",
    "P107": "live/total tile accounting disagrees with the bitmap",
    "P108": "geometry mismatch: mask shape vs tile/crossbar geometry",
    "P109": "decode plan disagrees with the mask's tile reduction "
            "(missing, extra, or stale plan entry)",
    "P110": "PlanStats totals disagree with the per-projection plans",
    "P111": "packing/XbarStats accounting disagrees with the mask",
    "P112": "cross-generation inconsistency inside a ServeEngine",
    "P113": "paged block table disagrees with the pool's ownership "
            "(unallocated, double-referenced, out-of-bounds, or "
            "off-scratch dead entry)",
    "P114": "paged cache gathered in logical block order does not "
            "reconstruct the dense oracle cache",
    "P115": "BlockPool accounting does not balance (free + live + "
            "scratch vs capacity, or reservations exceed free)",
    "P116": "fleet accounting broken (a submitted uid finished zero or "
            "multiple times across engines, or merged report totals "
            "disagree with the per-engine sums)",
    # jaxpr auditor -------------------------------------------------------
    "J201": "dense dot_general on a weight shape a TilePlan covers "
            "(missed block-sparse routing)",
    "J202": "float64 value in a hot-path trace (accidental x64 "
            "promotion)",
    "J203": "host callback inside a hot-path trace",
    "J204": "hot-path closure is not jitted (per-call retrace/dispatch)",
    "J205": "plan covers projections but the traced closure issues no "
            "pallas_call at all (whole-path routing miss)",
    "J206": "compiled artifact contains f64 tensors (HLO cross-check)",
    "J207": "collective traffic in a hot-path artifact (HLO "
            "cross-check)",
    "J208": "sharded engine's jitted hot path traced on a >1-device "
            "mesh with replicated-only params (missing NamedSharding "
            "placement — GSPMD runs every device dense)",
}


@dataclass(frozen=True)
class Finding:
    """One lint result: ``severity`` ∈ {error, warning, info}, ``code``
    a stable rule id from ``RULES``, ``where`` a location path (e.g.
    ``vgg11/recipe:cnn-full/stage[2]:prune:index`` or
    ``llama3.2-3b/decode/seg0.0.mlp.up``), ``msg`` the human account."""
    severity: str
    code: str
    where: str
    msg: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"known: {SEVERITIES}")
        if self.code not in RULES:
            raise ValueError(f"unregistered rule code {self.code!r} — "
                             f"add it to analysis.findings.RULES")

    def to_dict(self) -> dict:
        return {"severity": self.severity, "code": self.code,
                "where": self.where, "msg": self.msg}

    def __str__(self) -> str:
        return f"[{self.severity.upper():7s}] {self.code} {self.where}: " \
               f"{self.msg}"


def error(code: str, where: str, msg: str) -> Finding:
    return Finding("error", code, where, msg)


def warning(code: str, where: str, msg: str) -> Finding:
    return Finding("warning", code, where, msg)


def info(code: str, where: str, msg: str) -> Finding:
    return Finding("info", code, where, msg)


@dataclass
class Report:
    """An ordered collection of findings with severity accounting."""
    findings: List[Finding] = field(default_factory=list)

    def add(self, f: Finding) -> None:
        self.findings.append(f)

    def extend(self, fs: Iterable[Finding]) -> None:
        self.findings.extend(fs)

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "error")

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "warning")

    @property
    def ok(self) -> bool:
        """True when no error-severity finding was recorded."""
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(f.code for f in self.findings)

    def by_code(self, code: str) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.code == code)

    def summary(self) -> dict:
        counts = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            counts[f.severity] += 1
        return {"findings": len(self.findings), **counts, "ok": self.ok}

    def to_dict(self) -> dict:
        return {"findings": [f.to_dict() for f in self.findings],
                "summary": self.summary()}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)
