"""Family-keyed adapter registry: ``make_adapter(name)`` for every arch.

Every registered config (``configs.list_archs() + list_cnns()``) maps
through its ``family`` to ONE entry here; the entry is *data* — which
adapter class drives the family, which prunability/conv predicates
apply, which granularity schedule Algorithm 1 should walk, how to
scale the config down for CPU smoke runs — so covering a new model
family means registering an entry, not writing a new adapter subclass.

    adapter = make_adapter("deepseek-v3-671b", scale="tiny")
    result = PruningSession(adapter, PruneConfig(max_iters=1)).run()

Families → adapters:
  dense / moe / hybrid / ssm / vlm → ``LMAdapter`` (one transformer
      forward handles every block kind; MoE additionally gets the
      ``expert`` granularity ahead of the paper's schedule)
  audio                            → ``EncDecAdapter``
  cnn                              → ``CNNAdapter``
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.api.adapters import (CNNAdapter, EncDecAdapter, LMAdapter,
                                ModelAdapter)
from repro.configs import (ArchConfig, CNNConfig, get_arch, get_cnn,
                           list_archs, list_cnns, scaled_down,
                           scaled_down_cnn)
from repro.core.masks import cnn_conv_path, family_prunable

SCALES = ("tiny", "full")


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Registry entry: everything family-specific, as data."""
    family: str
    adapter_factory: Callable[..., ModelAdapter]
    prunable: Callable[[str, Any], bool]
    conv_pred: Optional[Callable[[str], bool]] = None
    # None → PruneConfig.granularities (the paper's schedule)
    granularities: Optional[Tuple[str, ...]] = None
    # cfg → reduced same-family cfg for scale="tiny"
    scale_tiny: Callable[[Any], Any] = lambda cfg: cfg
    # adapter kwargs that make scale="tiny" runs CPU-seconds cheap
    smoke_kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    serves: bool = False


_FAMILIES: Dict[str, FamilySpec] = {}


def register_family(spec: FamilySpec) -> FamilySpec:
    """Later registrations replace earlier ones (project overrides)."""
    _FAMILIES[spec.family] = spec
    return spec


def get_family(family: str) -> FamilySpec:
    if family not in _FAMILIES:
        raise KeyError(f"no adapter family {family!r}; "
                       f"registered: {sorted(_FAMILIES)}")
    return _FAMILIES[family]


def available_families() -> Tuple[str, ...]:
    return tuple(sorted(_FAMILIES))


def _tiny_arch(cfg: ArchConfig) -> ArchConfig:
    return scaled_down(cfg, dtype="float32")


_LM_SMOKE = dict(steps=6, batch_size=2, seq_len=16, eval_batches=1,
                 warmup=2)

for _fam in ("dense", "moe", "hybrid", "ssm", "vlm"):
    register_family(FamilySpec(
        family=_fam,
        adapter_factory=LMAdapter,
        prunable=family_prunable(_fam),
        granularities=(("expert", "filter", "channel", "index")
                       if _fam == "moe" else None),
        scale_tiny=_tiny_arch,
        smoke_kwargs=_LM_SMOKE,
        serves=True,
    ))

register_family(FamilySpec(
    family="audio",
    adapter_factory=EncDecAdapter,
    prunable=family_prunable("audio"),
    scale_tiny=_tiny_arch,
    smoke_kwargs=dict(steps=4, batch_size=2, seq_len=12, eval_batches=1),
    serves=False,
))

register_family(FamilySpec(
    family="cnn",
    adapter_factory=CNNAdapter,
    prunable=family_prunable("cnn"),
    conv_pred=cnn_conv_path,
    scale_tiny=scaled_down_cnn,
    smoke_kwargs=dict(steps=6, batch_size=8, eval_batches=1,
                      eval_batch_size=16),
    serves=False,
))


def list_adaptable() -> Sequence[str]:
    """Every registered arch name ``make_adapter`` accepts."""
    return list(list_archs()) + list(list_cnns())


def resolve_config(arch):
    """Name or config instance → (config, FamilySpec)."""
    if isinstance(arch, (ArchConfig, CNNConfig)):
        return arch, get_family(arch.family)
    try:
        cfg = get_arch(arch)
    except KeyError:
        try:
            cfg = get_cnn(arch)
        except KeyError:
            raise KeyError(f"unknown arch {arch!r}; "
                           f"known: {list_adaptable()}") from None
    return cfg, get_family(cfg.family)


def make_adapter(arch, *, scale: str = "tiny",
                 **adapter_kwargs) -> ModelAdapter:
    """One working ``ModelAdapter`` for ANY registered arch.

    ``arch``: a name from ``list_adaptable()`` or a config instance
    (instances are used as-is — they are already the scale you want).
    ``scale``: "tiny" reduces the config for CPU smoke runs and
    defaults the adapter's training budget to seconds; "full" keeps
    the registered config and the adapter class defaults.  Explicit
    ``adapter_kwargs`` always win over the smoke defaults.

    The family entry's prunability predicate, conv predicate, and
    granularity schedule are attached to the adapter as data;
    ``PruningSession`` picks the granularities up automatically.
    """
    cfg, spec = resolve_config(arch)
    is_instance = isinstance(arch, (ArchConfig, CNNConfig))
    kwargs = dict(adapter_kwargs)
    if not is_instance:
        if scale not in SCALES:
            raise ValueError(f"unknown scale {scale!r}; known: {SCALES}")
        if scale == "tiny":
            cfg = spec.scale_tiny(cfg)
            kwargs = {**spec.smoke_kwargs, **kwargs}
    adapter = spec.adapter_factory(cfg, **kwargs)
    adapter.family = spec.family
    adapter.prunable_pred = spec.prunable
    adapter.conv_path_pred = spec.conv_pred
    adapter.granularities = spec.granularities
    return adapter
