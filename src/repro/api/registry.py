"""Family-keyed adapter registry: ``make_adapter(name)`` for every arch.

Every registered config (``configs.list_archs() + list_cnns()``) maps
through its ``family`` to ONE entry here; the entry is *data* — which
adapter class drives the family, which prunability/conv predicates
apply, which granularity schedule Algorithm 1 should walk, how to
scale the config down for CPU smoke runs — so covering a new model
family means registering an entry, not writing a new adapter subclass.

    adapter = make_adapter("deepseek-v3-671b", scale="tiny")
    result = PruningSession(adapter, PruneConfig(max_iters=1)).run()

Families → adapters:
  dense / moe / hybrid / ssm / vlm → ``LMAdapter`` (one transformer
      forward handles every block kind; MoE additionally gets the
      ``expert`` granularity ahead of the paper's schedule)
  audio                            → ``EncDecAdapter``
  cnn                              → ``CNNAdapter``
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.api.adapters import (CNNAdapter, EncDecAdapter, LMAdapter,
                                ModelAdapter)
from repro.api.recipes import (Recipe, prune_stage, quantize_stage,
                               register_recipe)
from repro.configs import (ArchConfig, CNNConfig, get_arch, get_cnn,
                           list_archs, list_cnns, scaled_down,
                           scaled_down_cnn)
from repro.core.masks import cnn_conv_path, family_prunable

SCALES = ("tiny", "full")


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Registry entry: everything family-specific, as data."""
    family: str
    adapter_factory: Callable[..., ModelAdapter]
    prunable: Callable[[str, Any], bool]
    conv_pred: Optional[Callable[[str], bool]] = None
    # None → PruneConfig.granularities (the paper's schedule)
    granularities: Optional[Tuple[str, ...]] = None
    # granularities that exist in the strategy registry but are inert
    # for this family (e.g. `expert` outside MoE exposes no prunable
    # groups) — the recipe linter flags recipes that schedule them
    excluded_granularities: Tuple[str, ...] = ()
    # tuned full-scale prune program (registered recipe name); applied
    # at scale="full" only — tiny smoke runs keep the cheap flat
    # schedule above
    recipe: Optional[str] = None
    # cfg → reduced same-family cfg for scale="tiny"
    scale_tiny: Callable[[Any], Any] = lambda cfg: cfg
    # adapter kwargs that make scale="tiny" runs CPU-seconds cheap
    smoke_kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    serves: bool = False


_FAMILIES: Dict[str, FamilySpec] = {}


def register_family(spec: FamilySpec) -> FamilySpec:
    """Later registrations replace earlier ones (project overrides)."""
    _FAMILIES[spec.family] = spec
    return spec


def get_family(family: str) -> FamilySpec:
    if family not in _FAMILIES:
        raise KeyError(f"no adapter family {family!r}; "
                       f"registered: {sorted(_FAMILIES)}")
    return _FAMILIES[family]


def available_families() -> Tuple[str, ...]:
    return tuple(sorted(_FAMILIES))


def family_granularities(spec: FamilySpec) -> Tuple[str, ...]:
    """Granularities a recipe may schedule for this family: every
    registered strategy minus the family's exclusions."""
    from repro.core.strategies import available_strategies
    return tuple(g for g in available_strategies()
                 if g not in spec.excluded_granularities)


def _tiny_arch(cfg: ArchConfig) -> ArchConfig:
    return scaled_down(cfg, dtype="float32")


_LM_SMOKE = dict(steps=6, batch_size=2, seq_len=16, eval_batches=1,
                 warmup=2)

# ---------------------------------------------------------------------------
# Tuned full-scale recipes (FamilySpec.recipe points at these by name).
# Rates/budgets follow the paper's calibration: coarse stages prune
# aggressively with long retrains; fine stages mop up with shorter
# ones; every family finishes with the ReRAM-native int8 QAT stage.
# ---------------------------------------------------------------------------
register_recipe(Recipe(
    name="cnn-full",
    description="Tuned full-scale CNN program (VGG/ResNet on CIFAR): "
                "the paper schedule at 25%/round with a mop-up index "
                "pass, then int8 quantization-aware retrain.",
    stages=(
        prune_stage("filter", rate=0.25, retrain_steps=400),
        prune_stage("channel", rate=0.25, retrain_steps=400),
        prune_stage("index", rate=0.20, retrain_steps=300,
                    target_sparsity=0.95),
        quantize_stage(8, retrain_steps=300),
    )))

register_recipe(Recipe(
    name="dense-full",
    description="Tuned full-scale dense-LM program: coarse filter "
                "pass, crossbar-aligned channel/index passes at a "
                "gentler rate (LM loss cliffs are sharper than CNN "
                "accuracy), then int8 QAT.",
    stages=(
        prune_stage("filter", rate=0.20, retrain_steps=300),
        prune_stage("channel", rate=0.20, retrain_steps=300),
        prune_stage("index", rate=0.15, retrain_steps=200,
                    target_sparsity=0.90),
        quantize_stage(8, retrain_steps=200),
    )))

register_recipe(Recipe(
    name="moe-full",
    description="Tuned full-scale MoE program: whole-expert slices "
                "first (bounded rounds — the router needs survivors), "
                "then the dense-LM schedule over what remains, then "
                "int8 QAT.",
    stages=(
        prune_stage("expert", rate=0.25, max_rounds=3, retrain_steps=300),
        prune_stage("filter", rate=0.20, retrain_steps=300),
        prune_stage("channel", rate=0.20, retrain_steps=200),
        prune_stage("index", rate=0.15, retrain_steps=200,
                    target_sparsity=0.90),
        quantize_stage(8, retrain_steps=200),
    )))

for _fam in ("dense", "moe", "hybrid", "ssm", "vlm"):
    register_family(FamilySpec(
        family=_fam,
        adapter_factory=LMAdapter,
        prunable=family_prunable(_fam),
        granularities=(("expert", "filter", "channel", "index")
                       if _fam == "moe" else None),
        excluded_granularities=() if _fam == "moe" else ("expert",),
        recipe="moe-full" if _fam == "moe" else "dense-full",
        scale_tiny=_tiny_arch,
        smoke_kwargs=_LM_SMOKE,
        serves=True,
    ))

register_family(FamilySpec(
    family="audio",
    adapter_factory=EncDecAdapter,
    prunable=family_prunable("audio"),
    excluded_granularities=("expert",),
    recipe="dense-full",
    scale_tiny=_tiny_arch,
    smoke_kwargs=dict(steps=4, batch_size=2, seq_len=12, eval_batches=1),
    serves=True,
))

register_family(FamilySpec(
    family="cnn",
    adapter_factory=CNNAdapter,
    prunable=family_prunable("cnn"),
    conv_pred=cnn_conv_path,
    excluded_granularities=("expert",),
    recipe="cnn-full",
    scale_tiny=scaled_down_cnn,
    smoke_kwargs=dict(steps=6, batch_size=8, eval_batches=1,
                      eval_batch_size=16),
    serves=False,
))


def list_adaptable() -> Sequence[str]:
    """Every registered arch name ``make_adapter`` accepts."""
    return list(list_archs()) + list(list_cnns())


def resolve_config(arch):
    """Name or config instance → (config, FamilySpec)."""
    if isinstance(arch, (ArchConfig, CNNConfig)):
        return arch, get_family(arch.family)
    try:
        cfg = get_arch(arch)
    except KeyError:
        try:
            cfg = get_cnn(arch)
        except KeyError:
            raise KeyError(f"unknown arch {arch!r}; "
                           f"known: {list_adaptable()}") from None
    return cfg, get_family(cfg.family)


def make_adapter(arch, *, scale: str = "tiny",
                 **adapter_kwargs) -> ModelAdapter:
    """One working ``ModelAdapter`` for ANY registered arch.

    ``arch``: a name from ``list_adaptable()`` or a config instance
    (instances are used as-is — they are already the scale you want).
    ``scale``: "tiny" reduces the config for CPU smoke runs and
    defaults the adapter's training budget to seconds; "full" keeps
    the registered config and the adapter class defaults.  Explicit
    ``adapter_kwargs`` always win over the smoke defaults.

    The family entry's prunability predicate, conv predicate, and
    granularity schedule are attached to the adapter as data;
    ``PruningSession`` picks the granularities up automatically.  At
    ``scale="full"`` the family's tuned recipe rides along too
    (``adapter.recipe``), so a full-scale session runs the tuned
    staged program unless the caller overrides it.
    """
    cfg, spec = resolve_config(arch)
    is_instance = isinstance(arch, (ArchConfig, CNNConfig))
    kwargs = dict(adapter_kwargs)
    full_scale = False
    if not is_instance:
        if scale not in SCALES:
            raise ValueError(f"unknown scale {scale!r}; known: {SCALES}")
        if scale == "tiny":
            cfg = spec.scale_tiny(cfg)
            kwargs = {**spec.smoke_kwargs, **kwargs}
        else:
            full_scale = True
    adapter = spec.adapter_factory(cfg, **kwargs)
    adapter.family = spec.family
    adapter.prunable_pred = spec.prunable
    adapter.conv_path_pred = spec.conv_pred
    adapter.granularities = spec.granularities
    adapter.recipe = spec.recipe if full_scale else None
    return adapter
