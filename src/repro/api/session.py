"""PruningSession: Algorithm 1 as a resumable, observable session.

    adapter = CNNAdapter(cfg)
    session = PruningSession(adapter, PruneConfig(prune_fraction=0.25),
                             ckpt_dir="/ckpt/prune")
    result = session.run()          # train → prune → gate → rewind, resumable

The session owns the loop state (iteration, granularity cursor, masks,
baseline accuracy, event history) and checkpoints it through
``CheckpointManager`` after every iteration, so a long prune run killed
by preemption resumes from the last completed iteration and produces
the same ``PruneResult`` as an uninterrupted run (adapters are
deterministic given their seed).  Each iteration emits a streaming
``PruneEvent`` to registered callbacks.

Crossbar geometry comes from ``PruneConfig.xbar_rows/xbar_cols`` and is
threaded into scoring, zeroing, and the hardware report — no hardcoded
128s anywhere on the session path.
"""
from __future__ import annotations

import logging
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import PruneConfig
from repro.core import lottery
from repro.core.algorithm import PruneEvent, PruneResult, prune_step
from repro.core.hardware import HWReport, analyze_masks
from repro.core.masks import apply_masks, make_masks, sparsity_fraction
from repro.core.strategies import TileGeometry

log = logging.getLogger("realprune.session")

_HIST_COLS = 6        # iteration, gran_idx, s_before, s_after, acc, accepted


def structured_prune(params, schedule: Sequence[Tuple[str, float]], *,
                     prunable: Callable, conv_pred: Callable = None,
                     cfg: Optional[PruneConfig] = None, block: int = 32):
    """One-shot crossbar-aware pruning: apply a fixed (granularity,
    fraction) schedule to trained weights without the accuracy gate.

    The config's crossbar geometry drives every step.  Returns masks.
    """
    cfg = cfg or PruneConfig()
    geom = TileGeometry.from_config(cfg)
    conv_pred = conv_pred or (lambda p: False)
    masks = make_masks(params, prunable)
    for gran, frac in schedule:
        masks = prune_step(params, masks, gran, frac, conv_pred,
                           block=block, geometry=geom)
    return masks


class PruningSession:
    """Drive Algorithm 1 over a ``ModelAdapter`` with resume + events."""

    def __init__(self, adapter, cfg: Optional[PruneConfig] = None, *,
                 granularities: Optional[Sequence[str]] = None,
                 baseline_accuracy: Optional[float] = None,
                 seed: int = 0, block: int = 32,
                 ckpt_dir: Optional[str] = None, keep: int = 3,
                 callbacks: Sequence[Callable[[PruneEvent], None]] = ()):
        self.adapter = adapter
        self.cfg = cfg or PruneConfig()
        self.geometry = TileGeometry.from_config(self.cfg)
        # explicit arg > family registry data on the adapter > PruneConfig
        self.grans = list(granularities
                          or getattr(adapter, "granularities", None)
                          or self.cfg.granularities)
        self.baseline_accuracy = baseline_accuracy
        self.seed = seed
        self.block = block
        self.callbacks = list(callbacks)
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep,
                                       async_save=False)
                     if ckpt_dir else None)
        self.result: Optional[PruneResult] = None
        self._w_init = None

    # -- checkpoint plumbing ----------------------------------------------
    def _hist_array(self, history: List[PruneEvent]) -> np.ndarray:
        rows = [[e.iteration, self.grans.index(e.granularity),
                 e.sparsity_before, e.sparsity_after, e.accuracy,
                 float(e.accepted)] for e in history]
        return np.asarray(rows, np.float64).reshape(len(rows), _HIST_COLS)

    def _hist_events(self, arr) -> List[PruneEvent]:
        out = []
        for row in np.asarray(arr).reshape(-1, _HIST_COLS):
            out.append(PruneEvent(int(round(row[0])),
                                  self.grans[int(round(row[1]))],
                                  float(row[2]), float(row[3]),
                                  float(row[4]), bool(row[5] > 0.5)))
        return out

    def _save(self, itr, g_idx, masks, baseline, history):
        if self.ckpt is None:
            return
        self.ckpt.save(itr, {
            "masks": masks,
            "g_idx": np.asarray(g_idx, np.int32),
            "baseline": np.asarray(baseline, np.float64),
            "hist": self._hist_array(history)}, blocking=True)

    def _restore(self, masks_template):
        if self.ckpt is None:
            return None
        # baseline/hist templates are host numpy float64, matching
        # ``_save``: a float32 template would downcast the restored
        # baseline and could flip the ``acc >= baseline - tol`` gate
        # after resume (numpy templates restore without JAX dtype
        # canonicalisation — see checkpoint.manager.load_pytree)
        tmpl = {"masks": masks_template,
                "g_idx": np.zeros((), np.int32),
                "baseline": np.zeros((), np.float64),
                "hist": np.zeros((0, _HIST_COLS), np.float64)}
        step, tree = self.ckpt.restore(tmpl)
        if step is None:
            return None
        history = self._hist_events(tree["hist"])
        log.info("resumed pruning session at iteration %d "
                 "(%d events, sparsity %.3f)", step, len(history),
                 sparsity_fraction(tree["masks"]))
        return (step, int(tree["g_idx"]), tree["masks"],
                float(tree["baseline"]), history)

    # -- the loop ----------------------------------------------------------
    def run(self, rng=None) -> PruneResult:
        """Run (or resume) Algorithm 1 to completion."""
        cfg, adapter = self.cfg, self.adapter
        if rng is None:
            rng = jax.random.PRNGKey(self.seed)
        w_init = adapter.init_params(rng)                   # t=0 snapshot
        self._w_init = w_init
        masks = make_masks(w_init, adapter.prunable)
        itr, g_idx = 0, 0
        history: List[PruneEvent] = []
        baseline = self.baseline_accuracy

        restored = self._restore(masks)
        if restored is not None:
            itr, g_idx, masks, baseline, history = restored
        elif baseline is None:
            trained = adapter.train(w_init, masks)          # dense baseline
            baseline = float(adapter.evaluate(trained, masks))
            log.info("baseline accuracy: %.4f", baseline)
            self._save(0, 0, masks, baseline, history)

        params = apply_masks(w_init, masks)
        while itr < cfg.max_iters and g_idx < len(self.grans):
            itr += 1
            trained = adapter.train(params, masks)              # line 3
            # adapters that retrain through the block-sparse kernel
            # rebuild their plan from the current masks each round, so
            # each deeper prune round retrains with fewer tile passes
            pstats = getattr(adapter, "last_plan_stats", None)
            if pstats is not None and pstats.routed:
                log.info("iter %d retrain: %d matmuls block-sparse, "
                         "%.1f%% tiles skipped", itr, pstats.routed,
                         100.0 * pstats.skipped_tile_fraction)
            cand = prune_step(trained, masks, self.grans[g_idx],  # line 4
                              cfg.prune_fraction, adapter.conv_pred,
                              block=self.block, geometry=self.geometry)
            cand_params = apply_masks(trained, cand)
            acc = float(adapter.evaluate(cand_params, cand))     # line 5
            s_before = sparsity_fraction(masks)
            s_after = sparsity_fraction(cand)
            ok = acc >= baseline - cfg.accuracy_tolerance
            event = PruneEvent(itr, self.grans[g_idx], s_before, s_after,
                               acc, ok)
            history.append(event)
            log.info("iter %d [%s] sparsity %.3f->%.3f acc %.4f (%s)", itr,
                     self.grans[g_idx], s_before, s_after, acc,
                     "keep" if ok else "undo")
            if ok:
                masks = cand
            else:
                g_idx += 1                                   # lines 6-7
            params = apply_masks(w_init, masks)              # line 8
            self._save(itr, g_idx, masks, baseline, history)
            for cb in self.callbacks:
                cb(event)
        final_params = apply_masks(w_init, masks)
        self.result = PruneResult(masks=masks, params=final_params,
                                  history=history)
        return self.result

    # -- handoffs ----------------------------------------------------------
    def _require_result(self) -> PruneResult:
        if self.result is None:
            raise RuntimeError("run() the session first")
        return self.result

    @property
    def init_params(self):
        """The t=0 snapshot the winning ticket rewinds to."""
        if self._w_init is None:
            raise RuntimeError("run() the session first")
        return self._w_init

    def export_ticket(self, path: str) -> None:
        """Serialise the winning ticket (w_init, masks) — paper §V.C."""
        res = self._require_result()
        lottery.export_ticket(path, lottery.snapshot(self._w_init),
                              res.masks)

    def finetune(self, steps: Optional[int] = None, **kwargs):
        """Continue training the ticket through the adapter's Trainer."""
        res = self._require_result()
        return self.adapter.train(res.params, res.masks, steps, **kwargs)

    def serve_engine(self, *, batch_slots: int = 8, capacity: int = 512,
                     greedy: Optional[bool] = None, temperature: float = 0.0,
                     sample_seed: int = 0, use_bsmm: Optional[bool] = None,
                     interpret: Optional[bool] = None):
        """Hand the pruned ticket straight to a ``ServeEngine``.

        The ticket's masks ride along, so the engine derives the
        per-layer 128×128 tile bitmaps and routes decode projections
        through the block-sparse kernel (``use_bsmm=False`` opts out).
        """
        from repro.serve import ServeEngine
        res = self._require_result()
        prefill_fn, decode_fn = self.adapter.serve_fns()
        return ServeEngine(params=res.params, cfg=self.adapter.cfg,
                           prefill_fn=prefill_fn, decode_fn=decode_fn,
                           batch_slots=batch_slots, capacity=capacity,
                           greedy=greedy, temperature=temperature,
                           sample_seed=sample_seed, masks=res.masks,
                           use_bsmm=use_bsmm, interpret=interpret)

    def hardware_report(self, activation_volumes=None) -> HWReport:
        """Crossbar accounting of the final masks at the session's
        (config-driven) geometry."""
        res = self._require_result()
        return analyze_masks(res.masks, self.adapter.conv_pred,
                             activation_volumes=activation_volumes,
                             xbar_rows=self.geometry.rows,
                             xbar_cols=self.geometry.cols)
