"""PruningSession: staged prune programs (recipes) as a resumable,
observable session.

    adapter = make_adapter("vgg11", scale="tiny")
    session = PruningSession(adapter, PruneConfig(), recipe="paper-quant",
                             ckpt_dir="/ckpt/prune")
    result = session.run()          # recipe interpreter, resumable

The session interprets a ``repro.api.recipes.Recipe`` — an ordered
tuple of stages (``prune`` at one granularity, ``quantize`` for a
quantization-aware retrain, ``ablate`` for the schedule-ablation
sweep) — and owns the loop state (stage cursor ``(stage_idx, step)``,
masks, baseline accuracy, event history).  State checkpoints through
``CheckpointManager`` after every round, so a long run killed by
preemption resumes MID-STAGE from the last completed round and produces
the same ``PruneResult`` as an uninterrupted run (adapters are
deterministic given their seed).  Each round emits a streaming
``PruneEvent`` (with stage name/index and kind) to registered
callbacks.

Recipe resolution order (first match wins):

  1. explicit ``recipe=``       — Recipe | registered name | path | dict
  2. explicit ``granularities=``— compiled via ``from_granularities``
  3. ``cfg.recipe``             — named recipe on the PruneConfig (set
                                  only by callers, so it outranks the
                                  family registry's defaults)
  4. ``adapter.recipe``         — family-tuned recipe (registry data)
  5. ``adapter.granularities``  — family schedule, compiled
  6. ``cfg.granularities``      — the paper schedule, compiled

so every legacy ``granularities=`` entry point still works — it just
compiles to a prune-stage-per-granularity recipe with identical
semantics.

Crossbar geometry comes from ``PruneConfig.xbar_rows/xbar_cols`` and is
threaded into scoring, zeroing, and the hardware report — no hardcoded
128s anywhere on the session path.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, pack_json, unpack_json
from repro.configs.base import PruneConfig
from repro.core import lottery
from repro.core.algorithm import PruneEvent, PruneResult, prune_step
from repro.core.hardware import HWReport, analyze_masks
from repro.core.masks import apply_masks, make_masks, sparsity_fraction
from repro.core.quantize import fake_quantize_tree
from repro.core.strategies import TileGeometry

log = logging.getLogger("realprune.session")

_STATE_FIELDS = ("stage_idx", "step", "itr", "prune_rounds")
# checkpoint layout version: bump when the saved keys/encoding change.
# Missing template keys restore as template zeros (checkpoint.manager
# fills by path), so an explicit marker is the ONLY reliable way to
# tell an older-layout checkpoint from a fresh one.
_CKPT_FMT = 2


def structured_prune(params, schedule: Sequence[Tuple[str, float]], *,
                     prunable: Callable, conv_pred: Callable = None,
                     cfg: Optional[PruneConfig] = None, block: int = 32):
    """One-shot crossbar-aware pruning: apply a fixed (granularity,
    fraction) schedule to trained weights without the accuracy gate.

    The config's crossbar geometry drives every step.  Returns masks.
    """
    cfg = cfg or PruneConfig()
    geom = TileGeometry.from_config(cfg)
    conv_pred = conv_pred or (lambda p: False)
    masks = make_masks(params, prunable)
    for gran, frac in schedule:
        masks = prune_step(params, masks, gran, frac, conv_pred,
                           block=block, geometry=geom)
    return masks


def _resolve_session_recipe(recipe, granularities, adapter, cfg):
    from repro.api import recipes as rcp

    if recipe is not None:
        return rcp.resolve_recipe(recipe)
    # flat schedules compile with the config's per-round fraction —
    # the legacy knob keeps steering the legacy surface
    rate = cfg.prune_fraction
    if granularities:
        return rcp.from_granularities(granularities, rate=rate)
    # cfg.recipe defaults to None, so when set it is caller intent and
    # outranks the family registry's default recipe/schedule
    if getattr(cfg, "recipe", None):
        return rcp.resolve_recipe(cfg.recipe)
    a_recipe = getattr(adapter, "recipe", None)
    if a_recipe is not None:
        return rcp.resolve_recipe(a_recipe)
    a_grans = getattr(adapter, "granularities", None)
    if a_grans:
        return rcp.from_granularities(a_grans, rate=rate,
                                      name="family-schedule")
    return rcp.from_granularities(cfg.granularities, rate=rate,
                                  name="config-schedule")


class PruningSession:
    """Interpret a prune recipe over a ``ModelAdapter`` with resume +
    streaming events."""

    def __init__(self, adapter, cfg: Optional[PruneConfig] = None, *,
                 recipe=None,
                 granularities: Optional[Sequence[str]] = None,
                 baseline_accuracy: Optional[float] = None,
                 seed: int = 0, block: int = 32,
                 ckpt_dir: Optional[str] = None, keep: int = 3,
                 callbacks: Sequence[Callable[[PruneEvent], None]] = ()):
        self.adapter = adapter
        self.cfg = cfg or PruneConfig()
        self.geometry = TileGeometry.from_config(self.cfg)
        self.recipe = _resolve_session_recipe(recipe, granularities,
                                              adapter, self.cfg)
        self.baseline_accuracy = baseline_accuracy
        self.seed = seed
        self.block = block
        self.callbacks = list(callbacks)
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep,
                                       async_save=False)
                     if ckpt_dir else None)
        self.result: Optional[PruneResult] = None
        # bits of the last ACCEPTED quantize stage (None until one runs)
        self.quantize_bits: Optional[int] = None
        # live view of the committed masks while run() is in flight
        # (callbacks read this for per-stage accounting)
        self.masks = None
        self._w_init = None

    @property
    def grans(self) -> List[str]:
        """Prune-stage granularities in program order (legacy surface)."""
        return list(self.recipe.prune_granularities)

    # -- checkpoint plumbing ----------------------------------------------
    def _save(self, state: dict, masks, baseline, history):
        if self.ckpt is None:
            return
        self.ckpt.save(state["itr"], {
            "fmt": np.asarray(_CKPT_FMT, np.int64),
            "masks": masks,
            "state": np.asarray([state[f] for f in _STATE_FIELDS],
                                np.int64),
            # float64 on purpose: a float32 baseline would downcast on
            # restore and could flip the ``acc >= baseline - tol`` gate
            "baseline": np.asarray(baseline, np.float64),
            "hist": pack_json([dataclasses.asdict(e) for e in history]),
            "recipe": pack_json(self.recipe.to_dict())}, blocking=True)

    def _restore(self, masks_template):
        if self.ckpt is None:
            return None
        # numpy templates restore host-side without JAX dtype
        # canonicalisation (checkpoint.manager.load_pytree); byte-array
        # templates take their shape from disk, so variable-length JSON
        # payloads (history, recipe) round-trip losslessly
        tmpl = {"fmt": np.zeros((), np.int64),
                "masks": masks_template,
                "state": np.zeros((len(_STATE_FIELDS),), np.int64),
                "baseline": np.zeros((), np.float64),
                "hist": np.zeros((0,), np.uint8),
                "recipe": np.zeros((0,), np.uint8)}
        step, tree = self.ckpt.restore(tmpl)
        if step is None:
            return None
        if int(np.asarray(tree["fmt"])) != _CKPT_FMT:
            raise ValueError(
                f"session checkpoint at {self.ckpt.root} uses an older "
                f"(pre-recipe) or unknown layout — resuming it would "
                f"silently re-prune already-pruned masks; finish it with "
                f"the code that wrote it, or start over with a fresh "
                f"ckpt_dir")
        stored = unpack_json(tree["recipe"], default=None)
        if stored is not None and stored != self.recipe.to_dict():
            same_name = stored.get("name") == self.recipe.name
            raise ValueError(
                f"checkpoint at {self.ckpt.root} was written by recipe "
                f"{stored.get('name')!r}, but this session runs "
                f"{self.recipe.name!r}"
                + (" (same name, different stage parameters — e.g. a "
                   "--steps override rewrites per-stage retrain "
                   "budgets)" if same_name else "")
                + "; resuming a different program would corrupt the "
                "run history — pass the original recipe or a fresh "
                "ckpt_dir")
        history = [PruneEvent(**d)
                   for d in unpack_json(tree["hist"], default=[])]
        state = dict(zip(_STATE_FIELDS,
                         (int(v) for v in np.asarray(tree["state"]))))
        log.info("resumed pruning session at stage %d step %d "
                 "(%d events, sparsity %.3f)", state["stage_idx"],
                 state["step"], len(history),
                 sparsity_fraction(tree["masks"]))
        return state, tree["masks"], float(tree["baseline"]), history

    # -- the interpreter ---------------------------------------------------
    def _gate(self, stage) -> float:
        return (self.cfg.accuracy_tolerance if stage.accuracy_drop is None
                else stage.accuracy_drop)

    def _emit(self, event: PruneEvent, history: List[PruneEvent]):
        history.append(event)
        log.info("iter %d [%s/%s] sparsity %.3f->%.3f acc %.4f (%s)",
                 event.iteration, event.stage, event.granularity,
                 event.sparsity_before, event.sparsity_after,
                 event.accuracy,
                 "keep" if event.accepted else
                 ("scored" if event.kind == "ablate" else "undo"))

    def run(self, rng=None) -> PruneResult:
        """Run (or resume) the recipe to completion."""
        cfg, adapter = self.cfg, self.adapter
        stages = self.recipe.stages
        if rng is None:
            rng = jax.random.PRNGKey(self.seed)
        w_init = adapter.init_params(rng)                   # t=0 snapshot
        self._w_init = w_init
        masks = make_masks(w_init, adapter.prunable)
        state = dict.fromkeys(_STATE_FIELDS, 0)
        history: List[PruneEvent] = []
        baseline = self.baseline_accuracy
        self.quantize_bits = None

        restored = self._restore(masks)
        if restored is not None:
            state, masks, baseline, history = restored
            for e in history:       # re-derive accepted-quantize state
                if e.kind == "quantize" and e.accepted:
                    self.quantize_bits = stages[e.stage_idx].bits
        elif baseline is None:
            trained = adapter.train(w_init, masks)          # dense baseline
            baseline = float(adapter.evaluate(trained, masks))
            log.info("baseline accuracy: %.4f", baseline)
            self._save(state, masks, baseline, history)

        self.masks = masks
        params = apply_masks(w_init, masks)
        while state["stage_idx"] < len(stages):
            stage = stages[state["stage_idx"]]
            fresh = []
            if stage.kind == "prune":
                masks, params, done = self._prune_round(
                    stage, state, w_init, params, masks, baseline,
                    history, fresh)
            elif stage.kind == "quantize":
                done = self._quantize_round(stage, state, params, masks,
                                            baseline, history, fresh)
            else:
                done = self._ablate_round(stage, state, params, masks,
                                          history, fresh)
            if done:
                state["stage_idx"] += 1
                state["step"] = 0
            self.masks = masks
            self._save(state, masks, baseline, history)
            for e in fresh:
                for cb in self.callbacks:
                    cb(e)
        final_params = apply_masks(w_init, masks)
        self.result = PruneResult(masks=masks, params=final_params,
                                  history=history,
                                  recipe=self.recipe.to_dict())
        return self.result

    # -- stage bodies ------------------------------------------------------
    def _prune_round(self, stage, state, w_init, params, masks, baseline,
                     history, fresh):
        """One train→prune→gate round; Algorithm 1 lines 3-8."""
        cfg, adapter = self.cfg, self.adapter
        if state["prune_rounds"] >= cfg.max_iters:
            # global prune budget spent: skip remaining prune stages
            # (quantize/ablate stages still run)
            return masks, params, True
        state["itr"] += 1
        state["prune_rounds"] += 1
        state["step"] += 1
        trained = adapter.train(params, masks,
                                stage.retrain_steps)        # line 3
        # adapters that retrain through the block-sparse kernel rebuild
        # their plan from the current masks each round, so each deeper
        # prune round retrains with fewer tile passes
        pstats = getattr(adapter, "last_plan_stats", None)
        if pstats is not None and pstats.routed:
            log.info("iter %d retrain: %d matmuls block-sparse, "
                     "%.1f%% tiles skipped", state["itr"], pstats.routed,
                     100.0 * pstats.skipped_tile_fraction)
        cand = prune_step(trained, masks, stage.granularity,  # line 4
                          stage.rate, adapter.conv_pred,
                          block=self.block, geometry=self.geometry)
        cand_params = apply_masks(trained, cand)
        acc = float(adapter.evaluate(cand_params, cand))      # line 5
        s_before = sparsity_fraction(masks)
        s_after = sparsity_fraction(cand)
        ok = acc >= baseline - self._gate(stage)
        comm = getattr(adapter, "last_comm_stats", None) or {}
        if comm:
            log.info("iter %d retrain comm: %.1f%% of grads on the wire "
                     "(%.1f KiB/step)", state["itr"],
                     100.0 * comm["sent_fraction"],
                     comm["bytes_per_step"] / 1024.0)
        event = PruneEvent(state["itr"], stage.granularity, s_before,
                           s_after, acc, ok, stage=stage.name,
                           stage_idx=state["stage_idx"], kind="prune",
                           comm_sent_fraction=float(
                               comm.get("sent_fraction", 0.0)),
                           comm_bytes_per_step=int(
                               comm.get("bytes_per_step", 0)))
        self._emit(event, history)
        fresh.append(event)
        if ok:
            masks = cand
        done = (not ok                                       # lines 6-7
                or (stage.max_rounds is not None
                    and state["step"] >= stage.max_rounds)
                or (stage.target_sparsity is not None
                    and s_after >= stage.target_sparsity))
        params = apply_masks(w_init, masks)                  # line 8
        return masks, params, done

    def _quantize_round(self, stage, state, params, masks, baseline,
                        history, fresh):
        """Quantization-aware retrain of the current ticket, gated on
        its accuracy under fake quantization at ``stage.bits``."""
        adapter = self.adapter
        state["itr"] += 1
        state["step"] += 1
        trained = adapter.train(params, masks, stage.retrain_steps,
                                quantize_bits=stage.bits)
        q_params = fake_quantize_tree(trained, adapter.prunable,
                                      stage.bits)
        acc = float(adapter.evaluate(q_params, masks))
        s = sparsity_fraction(masks)
        ok = acc >= baseline - self._gate(stage)
        event = PruneEvent(state["itr"], f"int{stage.bits}", s, s, acc,
                           ok, stage=stage.name,
                           stage_idx=state["stage_idx"], kind="quantize")
        self._emit(event, history)
        fresh.append(event)
        if ok:
            self.quantize_bits = stage.bits
        return True

    def _ablate_round(self, stage, state, params, masks, history, fresh):
        """Schedule-ablation sweep: retrain once, score one prune round
        per granularity, commit NOTHING (masks are unchanged)."""
        adapter = self.adapter
        sweep = stage.granularities
        trained = adapter.train(params, masks, stage.retrain_steps)
        s_before = sparsity_fraction(masks)
        while state["step"] < len(sweep):
            g = sweep[state["step"]]
            state["itr"] += 1
            state["step"] += 1
            cand = prune_step(trained, masks, g, stage.rate,
                              adapter.conv_pred, block=self.block,
                              geometry=self.geometry)
            acc = float(adapter.evaluate(apply_masks(trained, cand),
                                         cand))
            event = PruneEvent(state["itr"], g, s_before,
                               sparsity_fraction(cand), acc, False,
                               stage=stage.name,
                               stage_idx=state["stage_idx"],
                               kind="ablate")
            self._emit(event, history)
            fresh.append(event)
        return True

    # -- handoffs ----------------------------------------------------------
    def _require_result(self) -> PruneResult:
        if self.result is None:
            raise RuntimeError("run() the session first")
        return self.result

    @property
    def init_params(self):
        """The t=0 snapshot the winning ticket rewinds to."""
        if self._w_init is None:
            raise RuntimeError("run() the session first")
        return self._w_init

    def ticket_meta(self) -> dict:
        """Metadata embedded in exported tickets: the resolved recipe
        (the reproducibility payload — rerunning it on the same config
        regenerates the ticket) plus the quantization outcome.

        ``arch`` is the session CONFIG's name for human provenance —
        for tiny-scale runs that is the scaled variant (e.g.
        ``vgg11-smoke``), not a registered arch id, so don't feed it
        back to ``make_adapter``; load tickets with the same
        ``--arch``/``--scale`` pair that pruned them (the CLI's shape
        validation catches mismatches).
        """
        res = self._require_result()
        return {"recipe": self.recipe.to_dict(),
                "quantize_bits": self.quantize_bits,
                "arch": getattr(self.adapter.cfg, "name", None),
                "sparsity": res.sparsity}

    def export_ticket(self, path: str) -> None:
        """Serialise the winning ticket (w_init, masks) — paper §V.C —
        with the resolved recipe embedded in its metadata."""
        res = self._require_result()
        lottery.export_ticket(path, lottery.snapshot(self._w_init),
                              res.masks, meta=self.ticket_meta())

    def finetune(self, steps: Optional[int] = None, **kwargs):
        """Continue training the ticket through the adapter's Trainer.

        After an accepted quantize stage the fine-tune stays
        quantization-aware (pass ``quantize_bits=None`` to opt out).
        """
        res = self._require_result()
        if self.quantize_bits is not None:
            kwargs.setdefault("quantize_bits", self.quantize_bits)
        return self.adapter.train(res.params, res.masks, steps, **kwargs)

    def serve_engine(self, *, batch_slots: int = 8, capacity: int = 512,
                     greedy: Optional[bool] = None, temperature: float = 0.0,
                     sample_seed: int = 0, use_bsmm: Optional[bool] = None,
                     interpret: Optional[bool] = None):
        """Hand the pruned ticket straight to a ``ServeEngine``.

        The ticket's masks ride along, so the engine derives the
        per-layer 128×128 tile bitmaps and routes prefill AND decode
        projections through the block-sparse kernel (``use_bsmm=False``
        opts out).
        """
        from repro.serve import ServeEngine
        res = self._require_result()
        prefill_fn, decode_fn = self.adapter.serve_fns()
        return ServeEngine(params=res.params, cfg=self.adapter.cfg,
                           prefill_fn=prefill_fn, decode_fn=decode_fn,
                           batch_slots=batch_slots, capacity=capacity,
                           greedy=greedy, temperature=temperature,
                           sample_seed=sample_seed, masks=res.masks,
                           use_bsmm=use_bsmm, interpret=interpret)

    def hardware_report(self, activation_volumes=None) -> HWReport:
        """Crossbar accounting of the final masks at the session's
        (config-driven) geometry.  When a quantize stage was accepted,
        the report carries the fixed-point width so its byte accounting
        (``HWReport.weight_bytes``) includes quantized storage."""
        res = self._require_result()
        return analyze_masks(res.masks, self.adapter.conv_pred,
                             activation_volumes=activation_volumes,
                             xbar_rows=self.geometry.rows,
                             xbar_cols=self.geometry.cols,
                             quant_bits=self.quantize_bits,
                             dtype=getattr(self.adapter.cfg, "dtype",
                                           None))
