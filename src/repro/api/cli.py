"""``python -m repro.api`` — prune / finetune / report / serve any arch.

One CLI over the session layer: every name in ``configs.list_archs() +
list_cnns()`` resolves through the family registry to a working
adapter, so the same subcommands drive CNNs, dense/MoE/hybrid/ssm
transformers, vlm and enc-dec configs.

    python -m repro.api archs
    python -m repro.api recipes
    python -m repro.api prune --arch vgg11 --scale tiny --rounds 1
    python -m repro.api prune --arch scaled_down_cnn --recipe paper --json
    python -m repro.api prune --arch llama3.2-3b --recipe paper-quant
    python -m repro.api report   --arch vgg11 --ticket /tmp/t
    python -m repro.api finetune --arch vgg11 --ticket /tmp/t --steps 20
    python -m repro.api serve    --arch yi-6b --requests 4
    python -m repro.api serve-daemon --arch yi-6b --ticket /tmp/t --json
    python -m repro.api swap --arch yi-6b --ticket /tmp/a --candidate /tmp/b

``--recipe`` runs a staged prune program (a registered name from
``recipes`` or a path to a recipe ``.json``); without it the legacy
flat granularity schedule applies.  ``--json`` switches event output
to one JSON object per line (machine-readable: round events carry the
stage name/index and kind, sparsity, accuracy, and the bsmm live-tile
fraction) for scripting and bench harnesses.

Exit codes: 0 success; 2 structured refusal (e.g. ``serve`` on a
family with no serving path — reported, not a traceback).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import numpy as np

EXIT_OK = 0
EXIT_UNSUPPORTED = 2


def _emit(obj: dict, as_json: bool, human: str):
    if as_json:
        print(json.dumps(obj), flush=True)
    else:
        print(human, flush=True)


def _hardware_dict(rep) -> dict:
    return {
        "cell_sparsity": rep.sparsity,
        "cell_savings": rep.cell_savings,
        "xbars_unpruned": rep.xbars_unpruned,
        "xbars_needed": rep.xbars_needed,
        "xbar_savings": rep.xbar_savings,
    }


def __getattr__(name):
    # ``cli.TicketMismatch`` stays importable without paying the jax
    # import at CLI startup; the class itself lives with the rest of
    # the ticket verification logic in ``serve.manager``
    if name == "TicketMismatch":
        from repro.serve.manager import TicketMismatch
        return TicketMismatch
    raise AttributeError(name)


def _load_ticket(adapter, path: str, seed: int):
    """Ticket dir → (rewound params, masks) shaped like the adapter.

    Delegates to ``serve.manager.load_ticket``, which validates the
    stored mask keys/shapes against the adapter's template first
    (``import_ticket`` silently skips mismatched keys, which would
    otherwise surface as a deep traceback much later) and raises
    ``TicketMismatch`` on disagreement.
    """
    import jax

    from repro.serve.manager import load_ticket

    params = adapter.init_params(jax.random.PRNGKey(seed))
    rewound, masks, _meta = load_ticket(
        path, params, adapter.prunable,
        arch_name=getattr(adapter.cfg, "name", "?"))
    return rewound, masks


def _ticket_mismatch(args, e) -> int:
    _emit({"event": "ticket_mismatch", "arch": args.arch,
           "ticket": args.ticket, "reason": str(e)},
          args.json, f"error: {e}")
    return EXIT_UNSUPPORTED


def _add_common(p: argparse.ArgumentParser, ticket_required: bool = False):
    p.add_argument("--arch", required=True,
                   help="any name from `python -m repro.api archs`")
    p.add_argument("--scale", default="tiny", choices=("tiny", "full"),
                   help="tiny: reduced config + seconds-scale training "
                        "budget; full: the registered config")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true",
                   help="one JSON object per event line")
    if ticket_required:
        p.add_argument("--ticket", required=True,
                       help="ticket directory from `prune --ticket`")


def cmd_archs(args) -> int:
    from repro.api.registry import list_adaptable, resolve_config

    rows = []
    for name in list_adaptable():
        cfg, spec = resolve_config(name)
        rows.append({"arch": name, "family": spec.family,
                     "adapter": spec.adapter_factory.__name__,
                     "granularities": list(spec.granularities or ()),
                     "recipe": spec.recipe,
                     "serves": spec.serves})
    if args.json:
        for r in rows:
            print(json.dumps(r))
    else:
        for r in rows:
            grans = ",".join(r["granularities"]) or "(paper schedule)"
            print(f"{r['arch']:28s} {r['family']:7s} {r['adapter']:14s} "
                  f"grans={grans} recipe={r['recipe']} "
                  f"serves={r['serves']}")
    return EXIT_OK


def cmd_prune(args) -> int:
    from repro.api.registry import make_adapter
    from repro.api.session import PruningSession
    from repro.configs import PruneConfig

    adapter = make_adapter(args.arch, scale=args.scale,
                           **({"steps": args.steps} if args.steps else {}))
    cfg = PruneConfig(prune_fraction=args.fraction, max_iters=args.rounds,
                      accuracy_tolerance=args.tolerance)
    grans = args.granularity.split(",") if args.granularity else None

    def on_event(e):
        stats = getattr(adapter, "last_plan_stats", None)
        live = (1.0 - stats.skipped_tile_fraction
                if stats is not None and stats.routed else None)
        verdict = ("keep" if e.accepted else
                   "scored" if e.kind == "ablate" else "undo")
        _emit({"event": "round", "arch": args.arch,
               "iteration": e.iteration, "stage": e.stage,
               "stage_idx": e.stage_idx, "kind": e.kind,
               "granularity": e.granularity,
               "sparsity_before": e.sparsity_before,
               "sparsity_after": e.sparsity_after,
               "accuracy": e.accuracy, "accepted": e.accepted,
               "live_tile_fraction": live,
               "comm_sent_fraction": e.comm_sent_fraction,
               "comm_bytes_per_step": e.comm_bytes_per_step},
              args.json,
              f"round {e.iteration} [{e.stage}] sparsity "
              f"{e.sparsity_before:.3f}->{e.sparsity_after:.3f} "
              f"acc {e.accuracy:.4f} ({verdict})")

    session = PruningSession(adapter, cfg, recipe=args.recipe,
                             granularities=grans,
                             seed=args.seed, ckpt_dir=args.ckpt,
                             callbacks=[on_event])
    if args.steps:
        # an explicit --steps wins over per-stage retrain budgets no
        # matter where the recipe came from (--recipe, the family
        # registry at --scale full, or cfg) — smoke runs stay cheap
        session.recipe = session.recipe.with_retrain_steps(args.steps)
    res = session.run()
    if args.ticket:
        session.export_ticket(args.ticket)
    rep = session.hardware_report()
    _emit({"event": "result", "arch": args.arch,
           "sparsity": res.sparsity, "iterations": len(res.history),
           "recipe": session.recipe.name,
           "stages": [s.name for s in session.recipe.stages],
           "granularities": session.grans,
           "quantize_bits": session.quantize_bits,
           "weight_bytes": rep.weight_bytes(),
           "ticket": args.ticket, **_hardware_dict(rep)},
          args.json,
          f"{args.arch}: sparsity {res.sparsity:.1%} after "
          f"{len(res.history)} rounds of recipe "
          f"'{session.recipe.name}' | crossbars "
          f"{rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}), cell savings {rep.cell_savings:.1%}"
          + (f" | int{session.quantize_bits} QAT accepted"
             if session.quantize_bits else "")
          + (f" | ticket -> {args.ticket}" if args.ticket else ""))
    return EXIT_OK


def cmd_recipes(args) -> int:
    from repro.api.recipes import available_recipes, get_recipe
    from repro.api.registry import available_families, get_family

    tuned_by = {}
    for fam in available_families():
        name = get_family(fam).recipe
        if name:
            tuned_by.setdefault(name, []).append(fam)
    for name in available_recipes():
        r = get_recipe(name)
        row = {"recipe": name,
               "stages": [s.name for s in r.stages],
               "families": tuned_by.get(name, []),
               "description": r.description}
        _emit(row, args.json,
              f"{name:14s} {' -> '.join(row['stages'])}"
              + (f"  [tuned: {','.join(row['families'])}]"
                 if row["families"] else ""))
    return EXIT_OK


def cmd_lint(args) -> int:
    """Static sparsity lint; exits 1 on any error-severity finding."""
    from repro.analysis import lint_arch, lint_kernels
    from repro.api.registry import list_adaptable

    if args.explain is not None:
        from repro.analysis.findings import RULES, explain
        code = args.explain.upper()
        if code not in RULES:
            _emit({"error": "unknown rule", "code": code,
                   "known": sorted(RULES)}, args.json,
                  f"unknown rule {code}; known: "
                  f"{', '.join(sorted(RULES))}")
            return EXIT_UNSUPPORTED
        rule = RULES[code]
        _emit({"code": rule.code, "family": rule.family,
               "title": rule.title, "doc": rule.doc}, args.json,
              explain(code))
        return EXIT_OK

    if not (args.all or args.arch or args.kernels):
        print("lint: one of --arch, --all, --kernels, or --explain "
              "is required")
        return EXIT_UNSUPPORTED

    any_error = False
    # the kernel audit (K3xx) is part of the full gate: on by default
    # for --all, opt-in alongside --arch, standalone via bare --kernels
    if args.kernels or args.all:
        rep = lint_kernels()
        any_error = not rep.ok
        summary = rep.summary()
        _emit({"arch": "kernels", **rep.to_dict()}, args.json,
              f"{'kernels':28s} findings={summary['findings']} "
              f"errors={summary['error']} "
              f"warnings={summary['warning']} "
              f"{'OK' if rep.ok else 'FAIL'}")
        if not args.json:
            for f in rep.findings:
                print(f"  {f}")

    names = (list_adaptable() if args.all
             else [args.arch] if args.arch else [])
    for name in names:
        rep = lint_arch(name, recipe=args.recipe, scale=args.scale,
                        seed=args.seed, hlo=args.hlo)
        any_error = any_error or not rep.ok
        summary = rep.summary()
        _emit({"arch": name, **rep.to_dict()}, args.json,
              f"{name:28s} findings={summary['findings']} "
              f"errors={summary['error']} "
              f"warnings={summary['warning']} "
              f"{'OK' if rep.ok else 'FAIL'}")
        if not args.json:
            for f in rep.findings:
                print(f"  {f}")
    return 1 if any_error else EXIT_OK


def cmd_finetune(args) -> int:
    from repro.api.registry import make_adapter
    from repro.core.lottery import ticket_meta
    from repro.serve.manager import TicketMismatch

    adapter = make_adapter(args.arch, scale=args.scale,
                           **({"steps": args.steps} if args.steps else {}))
    try:
        params, masks = _load_ticket(adapter, args.ticket, args.seed)
    except TicketMismatch as e:
        return _ticket_mismatch(args, e)
    # tickets from a recipe with an accepted quantize stage fine-tune
    # quantization-aware — the embedded metadata carries the bits
    bits = ticket_meta(args.ticket).get("quantize_bits")
    trained = adapter.train(params, masks, args.steps, quantize_bits=bits)
    score = adapter.evaluate(trained, masks)
    metrics = getattr(adapter, "last_metrics", {})
    _emit({"event": "finetune", "arch": args.arch, "ticket": args.ticket,
           "steps": args.steps, "score": score,
           "quantize_bits": bits,
           "loss": metrics.get("loss")},
          args.json,
          f"{args.arch}: ticket fine-tuned {args.steps or 'default'} "
          f"steps, eval score {score:.4f}"
          + (f", loss {metrics['loss']:.4f}" if "loss" in metrics else "")
          + (f" (int{bits} QAT)" if bits else ""))
    return EXIT_OK


def cmd_report(args) -> int:
    from repro.api.registry import make_adapter
    from repro.core.hardware import analyze_masks
    from repro.core.lottery import ticket_meta
    from repro.core.masks import sparsity_fraction
    from repro.serve.manager import TicketMismatch

    adapter = make_adapter(args.arch, scale=args.scale)
    try:
        _, masks = _load_ticket(adapter, args.ticket, args.seed)
    except TicketMismatch as e:
        return _ticket_mismatch(args, e)
    pc = adapter.cfg.prune
    meta = ticket_meta(args.ticket)
    bits = meta.get("quantize_bits")
    rep = analyze_masks(masks, adapter.conv_pred,
                        xbar_rows=pc.xbar_rows, xbar_cols=pc.xbar_cols,
                        quant_bits=bits,
                        dtype=getattr(adapter.cfg, "dtype", None))
    bytes_d = rep.weight_bytes()
    recipe = meta.get("recipe") or {}
    human_bytes = ""
    if bits:
        human_bytes = (f" | int{bits} weights "
                       f"{bytes_d['quantized_bytes'] / 1e6:.2f}MB "
                       f"(dense {bytes_d['dense_bytes'] / 1e6:.2f}MB)")
    _emit({"event": "report", "arch": args.arch, "ticket": args.ticket,
           "mask_sparsity": sparsity_fraction(masks),
           "xbar_rows": pc.xbar_rows, "xbar_cols": pc.xbar_cols,
           "recipe": recipe.get("name"),
           "quantize_bits": bits,
           "weight_bytes": bytes_d,
           **_hardware_dict(rep)},
          args.json,
          f"{args.arch}: ticket sparsity {sparsity_fraction(masks):.1%} | "
          f"{pc.xbar_rows}x{pc.xbar_cols} crossbars "
          f"{rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}) | cell savings {rep.cell_savings:.1%}"
          + human_bytes)
    return EXIT_OK


def _report_dict(rep) -> dict:
    """ServeReport → JSON payload (the --json serving surface)."""
    return {"requests": rep.requests, "tokens": rep.tokens_generated,
            "decode_steps": rep.decode_steps,
            "slot_occupancy": rep.slot_occupancy,
            "tokens_per_s": rep.tokens_per_s,
            "bsmm": rep.bsmm_enabled,
            "skipped_tile_fraction": rep.skipped_tile_fraction,
            "ttft_p50_ms": rep.ttft_p50 * 1e3,
            "ttft_p95_ms": rep.ttft_p95 * 1e3,
            "tps_p50": rep.tps_p50, "tps_p95": rep.tps_p95,
            "deadline_misses": rep.deadline_misses,
            "swaps": rep.swaps,
            "paged": rep.paged,
            "kv_blocks": rep.kv_blocks,
            "kv_blocks_live": rep.kv_blocks_live,
            "kv_blocks_peak": rep.kv_blocks_peak,
            "kv_block_bytes": rep.kv_block_bytes,
            "kv_bytes_per_token": rep.kv_bytes_per_token}


def _fleet_report_dict(rep) -> dict:
    """FleetReport → JSON payload (merged + per-engine)."""
    return {"engines": rep.engines, "live_engines": rep.live_engines,
            "requests": rep.requests, "tokens": rep.tokens_generated,
            "failovers": rep.failovers, "redispatched": rep.redispatched,
            "swaps": rep.swaps, "tokens_per_s": rep.tokens_per_s,
            "ttft_p50_ms": rep.ttft_p50 * 1e3,
            "ttft_p95_ms": rep.ttft_p95 * 1e3,
            "tps_p50": rep.tps_p50, "tps_p95": rep.tps_p95,
            "deadline_misses": rep.deadline_misses,
            "per_engine": [_report_dict(p) for p in rep.per_engine]}


def _serve_mesh(args):
    """--mesh DxM → a virtual-device test mesh (None when unset)."""
    spec = getattr(args, "mesh", None)
    if not spec:
        return None
    from repro.launch.mesh import make_test_mesh
    d, m = (int(x) for x in spec.lower().split("x"))
    return make_test_mesh(d, m)


def _latency_line(rep) -> str:
    return (f"ttft p50/p95 {rep.ttft_p50 * 1e3:.1f}/"
            f"{rep.ttft_p95 * 1e3:.1f}ms | per-request tok/s p50/p95 "
            f"{rep.tps_p50:.1f}/{rep.tps_p95:.1f} | "
            f"deadline misses {rep.deadline_misses}")


def _serve_setup(args):
    """Shared serve-verb boot: adapter + (prefill, decode) or a
    structured refusal.  Returns (adapter, fns | None, exit_code)."""
    from repro.api.adapters import ServeUnsupported
    from repro.api.registry import make_adapter

    adapter = make_adapter(args.arch, scale=args.scale)
    try:
        fns = adapter.serve_fns()
    except ServeUnsupported as e:
        _emit({"event": "serve_unsupported", "arch": e.arch,
               "family": e.family, "reason": e.reason},
              args.json,
              f"serve: {e.arch} ({e.family} family) has no serving path "
              f"— {e.reason}")
        return adapter, None, EXIT_UNSUPPORTED
    return adapter, fns, EXIT_OK


def _request_frames(adapter, uid: int):
    """Per-request encoder frames for enc-dec families (None for LMs)."""
    if getattr(adapter.cfg, "is_encoder_decoder", False):
        return adapter.serve_frames(uid)
    return None


def cmd_serve(args) -> int:
    import jax

    from repro.serve import Request, ServeEngine
    from repro.serve.manager import TicketMismatch

    adapter, fns, code = _serve_setup(args)
    if fns is None:
        return code
    prefill_fn, decode_fn = fns

    if args.ticket:
        try:
            params, masks = _load_ticket(adapter, args.ticket, args.seed)
        except TicketMismatch as e:
            return _ticket_mismatch(args, e)
    else:
        params = adapter.init_params(jax.random.PRNGKey(args.seed))
        masks = None
    mesh = _serve_mesh(args)

    def mk_engine():
        return ServeEngine(params=params, cfg=adapter.cfg,
                           prefill_fn=prefill_fn, decode_fn=decode_fn,
                           batch_slots=args.slots, capacity=args.capacity,
                           temperature=args.temperature, masks=masks,
                           mesh=mesh)

    rng = np.random.RandomState(args.seed)
    if args.engines > 1:
        from repro.serve import FleetRouter
        router = FleetRouter([mk_engine() for _ in range(args.engines)])
        for i in range(args.requests):
            plen = (args.prompt_len if args.prompt_len
                    else rng.randint(4, 16))
            prompt = rng.randint(0, 200, size=plen)
            router.submit(prompt.astype(np.int32), uid=i,
                          max_new_tokens=args.max_new,
                          frames=_request_frames(adapter, i))
        router.drain()
        rep = router.report
        _emit({"event": "serve_fleet", "arch": args.arch,
               **_fleet_report_dict(rep)},
              args.json,
              f"{args.arch}: fleet of {rep.engines} served "
              f"{rep.requests} requests, {rep.tokens_generated} tokens "
              f"| {rep.tokens_per_s:.1f} tok/s | {_latency_line(rep)}")
        return EXIT_OK
    engine = mk_engine()
    for i in range(args.requests):
        plen = args.prompt_len if args.prompt_len else rng.randint(4, 16)
        prompt = rng.randint(0, 200, size=plen)
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new,
                              frames=_request_frames(adapter, i)))
    engine.run()
    rep = engine.report
    _emit({"event": "serve", "arch": args.arch, **_report_dict(rep)},
          args.json,
          f"{args.arch}: served {rep.requests} requests, "
          f"{rep.tokens_generated} tokens in {rep.decode_steps} decode "
          f"steps | occupancy {rep.slot_occupancy:.0%} | "
          f"{rep.tokens_per_s:.1f} tok/s | {_latency_line(rep)} | "
          + (f"bsmm on ({rep.skipped_tile_fraction:.0%} tiles skipped)"
             if rep.bsmm_enabled else "bsmm off (dense)"))
    return EXIT_OK


def cmd_serve_daemon(args) -> int:
    """Line-protocol control-plane daemon.

    Reads one JSON op per line (stdin or ``--script``)::

        {"op": "request", "prompt": [1,2,3], "max_new_tokens": 8,
         "deadline_s": 2.0}              # admit (frames auto for audio)
        {"op": "pump", "steps": 4}       # advance the scheduler
        {"op": "swap", "name": "b", "ticket": "/path/to/ticket"}
        {"op": "kill", "engine": 1}      # fleet only: fail an engine,
                                         # re-dispatch its requests
        {"op": "status"}                 # health + live report
        {"op": "drain"}                  # serve everything queued
        {"op": "shutdown"}               # drain and exit 0

    Emits one event per line: ``ready``, ``admitted``/``rejected``,
    ``token`` (streaming, as each token is sampled), ``done``,
    ``swap``/``swap_rejected``, ``status``, and a final ``report`` +
    ``shutdown``.  EOF behaves like ``shutdown``.
    """
    import jax

    from repro.distributed.fault_tolerance import HeartbeatMonitor
    from repro.serve import (ServeEngine, ServeFrontend, SubmitRejected,
                             TicketError, TicketManager)

    adapter, fns, code = _serve_setup(args)
    if fns is None:
        return code
    prefill_fn, decode_fn = fns

    manager = TicketManager.from_adapter(adapter, seed=args.seed)
    if args.ticket:
        try:
            rec = manager.register("boot", args.ticket)
        except TicketError as e:
            _emit({"event": "ticket_rejected", "ticket": args.ticket,
                   "reason": e.reason, "detail": str(e)},
                  args.json, f"error: {e}")
            return EXIT_UNSUPPORTED
        params, masks = rec.params, rec.masks
        manager.active = "boot"
    else:
        params = adapter.init_params(jax.random.PRNGKey(args.seed))
        masks = None
    heartbeat = (HeartbeatMonitor(args.heartbeat_dir,
                                  deadline_s=args.heartbeat_deadline)
                 if args.heartbeat_dir else None)
    mesh = _serve_mesh(args)
    fleet = args.engines > 1

    def mk_engine(hb=None):
        return ServeEngine(params=params, cfg=adapter.cfg,
                           prefill_fn=prefill_fn, decode_fn=decode_fn,
                           batch_slots=args.slots,
                           capacity=args.capacity,
                           temperature=args.temperature, masks=masks,
                           heartbeat=hb, mesh=mesh)

    if fleet:
        from repro.serve import FleetRouter
        router = FleetRouter([mk_engine() for _ in range(args.engines)],
                             monitor=heartbeat, max_queue=args.max_queue)
        front, engine = router, router.frontends[0].engine
    else:
        engine = mk_engine(hb=heartbeat)
        router = None
        front = ServeFrontend(engine, max_queue=args.max_queue)
    rng = np.random.RandomState(args.seed)
    next_uid = [0]

    def mk_cb(uid):
        def cb(tok):
            _emit({"event": "token", "uid": uid, "token": int(tok)},
                  args.json, f"  token uid={uid}: {tok}")
        return cb

    def emit_done(done):
        for r in done:
            _emit({"event": "done", "uid": r.uid, "status": r.status,
                   "generation": r.generation,
                   "tokens": [int(t) for t in r.tokens],
                   "ttft_ms": None if r.ttft is None else r.ttft * 1e3},
                  args.json,
                  f"  done uid={r.uid} [{r.status}] gen={r.generation} "
                  f"tokens={r.tokens}")

    _emit({"event": "ready", "arch": args.arch, "ticket": args.ticket,
           "slots": args.slots, "engines": args.engines,
           "mesh": getattr(args, "mesh", None),
           "bsmm": engine.report.bsmm_enabled,
           "generation": engine.current_generation},
          args.json,
          f"daemon ready: {args.arch} slots={args.slots} "
          f"engines={args.engines} "
          + (f"ticket={args.ticket}" if args.ticket else "(unpruned)"))

    stream = open(args.script) if args.script else sys.stdin
    try:
        for line in stream:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            try:
                cmd = json.loads(line)
            except json.JSONDecodeError as e:
                _emit({"event": "error", "reason": f"bad json: {e}"},
                      args.json, f"error: bad json: {e}")
                continue
            op = cmd.get("op")
            if op == "request":
                uid = int(cmd.get("uid", next_uid[0]))
                next_uid[0] = max(next_uid[0], uid) + 1
                prompt = cmd.get("prompt")
                if prompt is None:
                    prompt = rng.randint(
                        1, 200, size=int(cmd.get("prompt_len", 8)))
                try:
                    handle = front.submit(
                        np.asarray(prompt, np.int32), uid=uid,
                        max_new_tokens=int(cmd.get("max_new_tokens",
                                                   args.max_new)),
                        deadline_s=cmd.get("deadline_s"),
                        frames=_request_frames(adapter, uid),
                        on_token=mk_cb(uid))
                except SubmitRejected as e:
                    _emit({"event": "rejected", "uid": uid,
                           "reason": e.reason, "detail": str(e)},
                          args.json,
                          f"rejected uid={uid}: [{e.reason}] {e}")
                else:
                    _emit({"event": "admitted", "uid": uid,
                           "state": handle.status},
                          args.json,
                          f"admitted uid={uid} ({handle.status})")
            elif op == "pump":
                emit_done(front.pump(int(cmd.get("steps", 1))))
            elif op == "drain":
                emit_done(front.drain())
            elif op == "kill":
                if router is None:
                    _emit({"event": "error",
                           "reason": "kill needs --engines > 1"},
                          args.json, "error: kill needs --engines > 1")
                else:
                    idx = int(cmd.get("engine", 0))
                    recs = router.kill(idx)
                    _emit({"event": "killed", "engine": idx,
                           "live": sorted(router.live),
                           "redispatched": len(recs)},
                          args.json,
                          f"killed engine {idx}: {len(recs)} requests "
                          f"re-dispatched, live={sorted(router.live)}")
            elif op == "swap":
                name = cmd.get("name") or cmd.get("ticket")
                try:
                    if name not in manager.tickets:
                        manager.register(name, cmd["ticket"])
                    ev = manager.swap(front, name)
                    skipped = (
                        (ev.events[-1].skipped_tile_fraction
                         if ev.events else 0.0)
                        if router is not None
                        else ev.skipped_tile_fraction)
                    payload = {"event": "swap", "ticket": name,
                               "accepted": ev.accepted,
                               "generation": ev.gid, "reason": ev.reason,
                               "skipped_tile_fraction": skipped}
                    if router is not None:
                        payload["engines"] = len(ev.events)
                        payload["rolled_back"] = ev.rolled_back
                    _emit(payload, args.json,
                          f"swap {name}: "
                          + ("accepted" if ev.accepted
                             else f"REJECTED — {ev.reason}")
                          + f" (gen {ev.gid}, skipped tiles "
                            f"{skipped:.0%})")
                except (TicketError, KeyError) as e:
                    _emit({"event": "swap_rejected", "ticket": name,
                           "reason": getattr(e, "reason", "bad_request"),
                           "detail": str(e)},
                          args.json, f"swap rejected: {e}")
            elif op == "status":
                if router is not None:
                    rep = router.report
                    _emit({"event": "status",
                           "active_ticket": manager.active,
                           "waiting": sum(len(fe.waiting)
                                          for fe in router.frontends),
                           **_fleet_report_dict(rep)},
                          args.json,
                          f"status: {rep.live_engines}/{rep.engines} "
                          f"engines live | failovers {rep.failovers} | "
                          f"{_latency_line(rep)}")
                else:
                    rep = engine.report
                    _emit({"event": "status",
                           "healthy": engine.health.healthy,
                           "health_reason": engine.health.reason,
                           "active_ticket": manager.active,
                           "generation": engine.current_generation,
                           "waiting": len(front.waiting),
                           **_report_dict(rep)},
                          args.json,
                          f"status: healthy={engine.health.healthy} "
                          f"gen={engine.current_generation} "
                          f"waiting={len(front.waiting)} | "
                          f"{_latency_line(rep)}")
            elif op == "shutdown":
                break
            else:
                _emit({"event": "error", "reason": f"unknown op {op!r}"},
                      args.json, f"error: unknown op {op!r}")
    finally:
        if stream is not sys.stdin:
            stream.close()
    emit_done(front.drain())
    if router is not None:
        rep = router.report
        _emit({"event": "report", **_fleet_report_dict(rep)}, args.json,
              f"fleet served {rep.requests} requests, "
              f"{rep.tokens_generated} tokens | failovers "
              f"{rep.failovers} (redispatched {rep.redispatched}) | "
              f"{_latency_line(rep)} | swaps {rep.swaps}")
    else:
        rep = engine.report
        _emit({"event": "report", **_report_dict(rep)}, args.json,
              f"served {rep.requests} requests, {rep.tokens_generated} "
              f"tokens | {_latency_line(rep)} | swaps {rep.swaps}")
    _emit({"event": "shutdown"}, args.json, "daemon shutdown clean")
    return EXIT_OK


def cmd_swap(args) -> int:
    """Zero-drain hot-swap preflight: serve live traffic on the running
    ticket, swap the candidate in MID-DECODE, and prove (a) in-flight
    outputs are bit-identical to a swap-free oracle and (b) the next
    admitted request decodes under the candidate's tile plans."""
    from repro.serve import (Request, ServeFrontend, TicketError,
                             TicketManager)

    adapter, fns, code = _serve_setup(args)
    if fns is None:
        return code

    manager = TicketManager.from_adapter(adapter, seed=args.seed)
    try:
        manager.register("current", args.ticket)
        manager.register("candidate", args.candidate)
    except TicketError as e:
        _emit({"event": "ticket_rejected", "reason": e.reason,
               "detail": str(e)}, args.json, f"error: {e}")
        return EXIT_UNSUPPORTED

    def mk_requests():
        return [Request(uid=i,
                        prompt=np.random.RandomState(1000 + i).randint(
                            1, 200, size=8).astype(np.int32),
                        max_new_tokens=args.max_new,
                        frames=_request_frames(adapter, i))
                for i in range(args.requests)]

    kw = dict(batch_slots=args.slots, capacity=args.capacity)
    # oracle: identical traffic served to completion, no swap
    oracle_eng = manager.make_engine("current", **kw)
    for r in mk_requests():
        oracle_eng.submit(r)
    oracle = {r.uid: list(r.tokens) for r in oracle_eng.run()}
    old_skip = oracle_eng.report.skipped_tile_fraction

    # live: same traffic, candidate swapped in mid-decode
    engine = manager.make_engine("current", **kw)
    frontend = ServeFrontend(engine)
    for r in mk_requests():
        frontend.submit(request=r)
    frontend.pump(args.swap_after)
    ev = manager.swap(frontend, "candidate")
    probe = Request(uid=10_000,
                    prompt=np.random.RandomState(77).randint(
                        1, 200, size=8).astype(np.int32),
                    max_new_tokens=args.max_new,
                    frames=_request_frames(adapter, 10_000))
    frontend.submit(request=probe)
    frontend.drain()

    done = {r.uid: r for r in frontend.finished}
    in_flight = [u for u in oracle if done[u].generation == 0]
    match = all(done[u].tokens == oracle[u] for u in in_flight)
    new_skip = engine.report.skipped_tile_fraction
    ok = ev.accepted and match
    rep = engine.report
    _emit({"event": "swap_check", "arch": args.arch,
           "accepted": ev.accepted, "reason": ev.reason,
           "in_flight_match": match, "in_flight": len(in_flight),
           "probe_generation": probe.generation,
           "old_skipped_tile_fraction": old_skip,
           "new_skipped_tile_fraction": new_skip,
           **_report_dict(rep)},
          args.json,
          f"swap {'OK' if ok else 'FAILED'}: "
          f"{len(in_flight)} in-flight requests "
          f"{'bit-identical' if match else 'DIVERGED'} vs no-swap "
          f"oracle; probe served on gen {probe.generation}; skipped "
          f"tiles {old_skip:.0%} -> {new_skip:.0%} | "
          f"{_latency_line(rep)}")
    return EXIT_OK if ok else EXIT_UNSUPPORTED


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Prune, fine-tune, report, and serve any registered "
                    "architecture through the repro.api session layer.")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("archs", help="list registered archs and families")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_archs)

    p = sub.add_parser("recipes",
                       help="list registered prune recipes (staged "
                            "programs) and which families they tune")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_recipes)


    p = sub.add_parser("lint",
                       help="static sparsity lint: recipe programs, "
                            "tile-plan invariants, and jitted hot-path "
                            "traces (exit 1 on error findings)")
    g = p.add_mutually_exclusive_group(required=False)
    g.add_argument("--arch", default=None,
                   help="any name from `python -m repro.api archs`")
    g.add_argument("--all", action="store_true",
                   help="lint every registered arch (implies --kernels)")
    g.add_argument("--explain", default=None, metavar="CODE",
                   help="print the registry entry for one rule code "
                        "(e.g. --explain K301) and exit")
    p.add_argument("--kernels", action="store_true",
                   help="audit every registered Pallas kernel's "
                        "BlockSpec/grid geometry (K3xx); on by default "
                        "with --all, standalone without --arch")
    p.add_argument("--recipe", default=None,
                   help="recipe to lint instead of the family default: "
                        "a registered name or a path to a recipe .json")
    p.add_argument("--scale", default="tiny", choices=("tiny", "full"),
                   help="config scale the masks/plans/traces are built "
                        "at (tiny: CPU-seconds per arch)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--hlo", action="store_true",
                   help="also compile the serving prefill and "
                        "cross-check the optimized HLO (slower)")
    p.add_argument("--json", action="store_true",
                   help="one JSON report object per arch line")
    p.set_defaults(fn=cmd_lint)

    p = sub.add_parser("prune", help="run a prune recipe (PruningSession)")
    _add_common(p)
    p.add_argument("--recipe", default=None,
                   help="staged prune program: a name from "
                        "`python -m repro.api recipes` or a path to a "
                        "recipe .json (wins over --granularity)")
    p.add_argument("--rounds", type=int, default=3,
                   help="global prune-round budget "
                        "(PruneConfig.max_iters)")
    p.add_argument("--fraction", type=float, default=0.25,
                   help="fraction of remaining weights pruned per round "
                        "(flat schedules; recipes carry per-stage rates)")
    p.add_argument("--tolerance", type=float, default=0.02,
                   help="allowed accuracy drop vs baseline (nats for LMs)")
    p.add_argument("--granularity", default=None,
                   help="comma list overriding the family schedule, "
                        "e.g. expert,filter,index")
    p.add_argument("--steps", type=int, default=None,
                   help="train steps per round (adapter default if unset)")
    p.add_argument("--ticket", default=None,
                   help="export the winning ticket to this directory")
    p.add_argument("--ckpt", default=None,
                   help="session checkpoint dir (resume a killed run)")
    p.set_defaults(fn=cmd_prune)

    p = sub.add_parser("finetune",
                       help="continue training an exported ticket")
    _add_common(p, ticket_required=True)
    p.add_argument("--steps", type=int, default=None)
    p.set_defaults(fn=cmd_finetune)

    p = sub.add_parser("report",
                       help="crossbar accounting of an exported ticket")
    _add_common(p, ticket_required=True)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("serve", help="serve an LM through ServeEngine")
    _add_common(p)
    p.add_argument("--ticket", default=None,
                   help="serve this pruned ticket (block-sparse decode)")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--capacity", type=int, default=128)
    p.add_argument("--prompt-len", type=int, default=None,
                   help="fixed prompt length (default: random 4-15); "
                        "paged engines admit lengths past --capacity")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--engines", type=int, default=1,
                   help="fleet size: front N engines with a FleetRouter "
                        "(least-loaded dispatch)")
    p.add_argument("--mesh", default=None,
                   help="per-engine DxM test mesh (e.g. 1x2): shard "
                        "params/caches/plans over D*M devices — launch "
                        "with XLA_FLAGS=--xla_force_host_platform_"
                        "device_count=N for virtual CPU devices")
    p.set_defaults(fn=cmd_serve)

    p = sub.add_parser("serve-daemon",
                       help="control-plane daemon: one JSON op per stdin "
                            "line (request/pump/swap/status/shutdown), "
                            "streaming token events out")
    _add_common(p)
    p.add_argument("--ticket", default=None,
                   help="boot serving this pruned ticket")
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--capacity", type=int, default=128)
    p.add_argument("--max-new", type=int, default=8,
                   help="default token budget for ops that omit it")
    p.add_argument("--max-queue", type=int, default=64,
                   help="front-end wait-queue bound (admission control)")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--heartbeat-dir", default=None,
                   help="HeartbeatMonitor root: engine ticks beat here "
                        "and stale beats close the admission gate")
    p.add_argument("--heartbeat-deadline", type=float, default=30.0)
    p.add_argument("--engines", type=int, default=1,
                   help="fleet size: FleetRouter over N engines with "
                        "heartbeat failover; adds the kill op "
                        '({"op": "kill", "engine": 1})')
    p.add_argument("--mesh", default=None,
                   help="per-engine DxM test mesh (e.g. 1x2); see "
                        "`serve --mesh`")
    p.add_argument("--script", default=None,
                   help="read ops from this file instead of stdin")
    p.set_defaults(fn=cmd_serve_daemon)

    p = sub.add_parser("swap",
                       help="zero-drain hot-swap preflight: candidate "
                            "ticket vs running ticket on live traffic")
    _add_common(p, ticket_required=True)
    p.add_argument("--candidate", required=True,
                   help="candidate ticket directory to swap in")
    p.add_argument("--requests", type=int, default=3,
                   help="in-flight requests during the swap "
                        "(keep <= --slots for a full in-flight check)")
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--capacity", type=int, default=128)
    p.add_argument("--swap-after", type=int, default=2,
                   help="scheduler ticks before the swap lands")
    p.set_defaults(fn=cmd_swap)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
