"""``python -m repro.api`` — prune / finetune / report / serve any arch.

One CLI over the session layer: every name in ``configs.list_archs() +
list_cnns()`` resolves through the family registry to a working
adapter, so the same subcommands drive CNNs, dense/MoE/hybrid/ssm
transformers, vlm and enc-dec configs.

    python -m repro.api archs
    python -m repro.api recipes
    python -m repro.api prune --arch vgg11 --scale tiny --rounds 1
    python -m repro.api prune --arch scaled_down_cnn --recipe paper --json
    python -m repro.api prune --arch llama3.2-3b --recipe paper-quant
    python -m repro.api report   --arch vgg11 --ticket /tmp/t
    python -m repro.api finetune --arch vgg11 --ticket /tmp/t --steps 20
    python -m repro.api serve    --arch yi-6b --requests 4

``--recipe`` runs a staged prune program (a registered name from
``recipes`` or a path to a recipe ``.json``); without it the legacy
flat granularity schedule applies.  ``--json`` switches event output
to one JSON object per line (machine-readable: round events carry the
stage name/index and kind, sparsity, accuracy, and the bsmm live-tile
fraction) for scripting and bench harnesses.

Exit codes: 0 success; 2 structured refusal (e.g. ``serve`` on a
family with no serving path — reported, not a traceback).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

import numpy as np

EXIT_OK = 0
EXIT_UNSUPPORTED = 2


def _emit(obj: dict, as_json: bool, human: str):
    if as_json:
        print(json.dumps(obj), flush=True)
    else:
        print(human, flush=True)


def _hardware_dict(rep) -> dict:
    return {
        "cell_sparsity": rep.sparsity,
        "cell_savings": rep.cell_savings,
        "xbars_unpruned": rep.xbars_unpruned,
        "xbars_needed": rep.xbars_needed,
        "xbar_savings": rep.xbar_savings,
    }


class TicketMismatch(RuntimeError):
    """Ticket on disk does not fit the adapter's parameter template
    (usually pruned at a different --scale or --arch)."""


def _load_ticket(adapter, path: str, seed: int):
    """Ticket dir → (rewound params, masks) shaped like the adapter.

    Validates the stored mask keys/shapes against the adapter's
    template first: ``import_ticket`` silently skips mismatched keys,
    which would otherwise surface as a deep traceback much later.
    """
    import os

    import jax

    from repro.core import lottery
    from repro.core.masks import make_masks, path_str

    params = adapter.init_params(jax.random.PRNGKey(seed))
    masks_tmpl = make_masks(params, adapter.prunable)
    tmpl_shapes = {}

    def visit(p, leaf):
        if leaf is not None:
            tmpl_shapes[f"m:{path_str(p)}"] = tuple(leaf.shape)
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks_tmpl,
                                     is_leaf=lambda x: x is None)
    data = np.load(os.path.join(path, "ticket.npz"))
    stored = {k: tuple(data[k].shape) for k in data.files
              if k.startswith("m:")}
    if stored != tmpl_shapes:
        missing = sorted(set(tmpl_shapes) - set(stored))
        extra = sorted(set(stored) - set(tmpl_shapes))
        wrong = sorted(k for k in set(stored) & set(tmpl_shapes)
                       if stored[k] != tmpl_shapes[k])
        raise TicketMismatch(
            f"ticket at {path} does not match {adapter.cfg.name}: "
            f"{len(missing)} masks missing, {len(extra)} unexpected, "
            f"{len(wrong)} wrong-shaped"
            + (f" (e.g. {wrong[0]}: {stored[wrong[0]]} vs "
               f"{tmpl_shapes[wrong[0]]})" if wrong else "")
            + " — was it pruned at a different --scale or --arch?")
    w, m = lottery.import_ticket(path, params, masks_tmpl)
    return lottery.rewind(w, m), m


def _ticket_mismatch(args, e: TicketMismatch) -> int:
    _emit({"event": "ticket_mismatch", "arch": args.arch,
           "ticket": args.ticket, "reason": str(e)},
          args.json, f"error: {e}")
    return EXIT_UNSUPPORTED


def _add_common(p: argparse.ArgumentParser, ticket_required: bool = False):
    p.add_argument("--arch", required=True,
                   help="any name from `python -m repro.api archs`")
    p.add_argument("--scale", default="tiny", choices=("tiny", "full"),
                   help="tiny: reduced config + seconds-scale training "
                        "budget; full: the registered config")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true",
                   help="one JSON object per event line")
    if ticket_required:
        p.add_argument("--ticket", required=True,
                       help="ticket directory from `prune --ticket`")


def cmd_archs(args) -> int:
    from repro.api.registry import list_adaptable, resolve_config

    rows = []
    for name in list_adaptable():
        cfg, spec = resolve_config(name)
        rows.append({"arch": name, "family": spec.family,
                     "adapter": spec.adapter_factory.__name__,
                     "granularities": list(spec.granularities or ()),
                     "recipe": spec.recipe,
                     "serves": spec.serves})
    if args.json:
        for r in rows:
            print(json.dumps(r))
    else:
        for r in rows:
            grans = ",".join(r["granularities"]) or "(paper schedule)"
            print(f"{r['arch']:28s} {r['family']:7s} {r['adapter']:14s} "
                  f"grans={grans} recipe={r['recipe']} "
                  f"serves={r['serves']}")
    return EXIT_OK


def cmd_prune(args) -> int:
    from repro.api.registry import make_adapter
    from repro.api.session import PruningSession
    from repro.configs import PruneConfig

    adapter = make_adapter(args.arch, scale=args.scale,
                           **({"steps": args.steps} if args.steps else {}))
    cfg = PruneConfig(prune_fraction=args.fraction, max_iters=args.rounds,
                      accuracy_tolerance=args.tolerance)
    grans = args.granularity.split(",") if args.granularity else None

    def on_event(e):
        stats = getattr(adapter, "last_plan_stats", None)
        live = (1.0 - stats.skipped_tile_fraction
                if stats is not None and stats.routed else None)
        verdict = ("keep" if e.accepted else
                   "scored" if e.kind == "ablate" else "undo")
        _emit({"event": "round", "arch": args.arch,
               "iteration": e.iteration, "stage": e.stage,
               "stage_idx": e.stage_idx, "kind": e.kind,
               "granularity": e.granularity,
               "sparsity_before": e.sparsity_before,
               "sparsity_after": e.sparsity_after,
               "accuracy": e.accuracy, "accepted": e.accepted,
               "live_tile_fraction": live},
              args.json,
              f"round {e.iteration} [{e.stage}] sparsity "
              f"{e.sparsity_before:.3f}->{e.sparsity_after:.3f} "
              f"acc {e.accuracy:.4f} ({verdict})")

    session = PruningSession(adapter, cfg, recipe=args.recipe,
                             granularities=grans,
                             seed=args.seed, ckpt_dir=args.ckpt,
                             callbacks=[on_event])
    if args.steps:
        # an explicit --steps wins over per-stage retrain budgets no
        # matter where the recipe came from (--recipe, the family
        # registry at --scale full, or cfg) — smoke runs stay cheap
        session.recipe = session.recipe.with_retrain_steps(args.steps)
    res = session.run()
    if args.ticket:
        session.export_ticket(args.ticket)
    rep = session.hardware_report()
    _emit({"event": "result", "arch": args.arch,
           "sparsity": res.sparsity, "iterations": len(res.history),
           "recipe": session.recipe.name,
           "stages": [s.name for s in session.recipe.stages],
           "granularities": session.grans,
           "quantize_bits": session.quantize_bits,
           "weight_bytes": rep.weight_bytes(),
           "ticket": args.ticket, **_hardware_dict(rep)},
          args.json,
          f"{args.arch}: sparsity {res.sparsity:.1%} after "
          f"{len(res.history)} rounds of recipe "
          f"'{session.recipe.name}' | crossbars "
          f"{rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}), cell savings {rep.cell_savings:.1%}"
          + (f" | int{session.quantize_bits} QAT accepted"
             if session.quantize_bits else "")
          + (f" | ticket -> {args.ticket}" if args.ticket else ""))
    return EXIT_OK


def cmd_recipes(args) -> int:
    from repro.api.recipes import available_recipes, get_recipe
    from repro.api.registry import available_families, get_family

    tuned_by = {}
    for fam in available_families():
        name = get_family(fam).recipe
        if name:
            tuned_by.setdefault(name, []).append(fam)
    for name in available_recipes():
        r = get_recipe(name)
        row = {"recipe": name,
               "stages": [s.name for s in r.stages],
               "families": tuned_by.get(name, []),
               "description": r.description}
        _emit(row, args.json,
              f"{name:14s} {' -> '.join(row['stages'])}"
              + (f"  [tuned: {','.join(row['families'])}]"
                 if row["families"] else ""))
    return EXIT_OK


def cmd_finetune(args) -> int:
    from repro.api.registry import make_adapter
    from repro.core.lottery import ticket_meta

    adapter = make_adapter(args.arch, scale=args.scale,
                           **({"steps": args.steps} if args.steps else {}))
    try:
        params, masks = _load_ticket(adapter, args.ticket, args.seed)
    except TicketMismatch as e:
        return _ticket_mismatch(args, e)
    # tickets from a recipe with an accepted quantize stage fine-tune
    # quantization-aware — the embedded metadata carries the bits
    bits = ticket_meta(args.ticket).get("quantize_bits")
    trained = adapter.train(params, masks, args.steps, quantize_bits=bits)
    score = adapter.evaluate(trained, masks)
    metrics = getattr(adapter, "last_metrics", {})
    _emit({"event": "finetune", "arch": args.arch, "ticket": args.ticket,
           "steps": args.steps, "score": score,
           "quantize_bits": bits,
           "loss": metrics.get("loss")},
          args.json,
          f"{args.arch}: ticket fine-tuned {args.steps or 'default'} "
          f"steps, eval score {score:.4f}"
          + (f", loss {metrics['loss']:.4f}" if "loss" in metrics else "")
          + (f" (int{bits} QAT)" if bits else ""))
    return EXIT_OK


def cmd_report(args) -> int:
    from repro.api.registry import make_adapter
    from repro.core.hardware import analyze_masks
    from repro.core.lottery import ticket_meta
    from repro.core.masks import sparsity_fraction

    adapter = make_adapter(args.arch, scale=args.scale)
    try:
        _, masks = _load_ticket(adapter, args.ticket, args.seed)
    except TicketMismatch as e:
        return _ticket_mismatch(args, e)
    pc = adapter.cfg.prune
    meta = ticket_meta(args.ticket)
    bits = meta.get("quantize_bits")
    rep = analyze_masks(masks, adapter.conv_pred,
                        xbar_rows=pc.xbar_rows, xbar_cols=pc.xbar_cols,
                        quant_bits=bits,
                        dtype=getattr(adapter.cfg, "dtype", None))
    bytes_d = rep.weight_bytes()
    recipe = meta.get("recipe") or {}
    human_bytes = ""
    if bits:
        human_bytes = (f" | int{bits} weights "
                       f"{bytes_d['quantized_bytes'] / 1e6:.2f}MB "
                       f"(dense {bytes_d['dense_bytes'] / 1e6:.2f}MB)")
    _emit({"event": "report", "arch": args.arch, "ticket": args.ticket,
           "mask_sparsity": sparsity_fraction(masks),
           "xbar_rows": pc.xbar_rows, "xbar_cols": pc.xbar_cols,
           "recipe": recipe.get("name"),
           "quantize_bits": bits,
           "weight_bytes": bytes_d,
           **_hardware_dict(rep)},
          args.json,
          f"{args.arch}: ticket sparsity {sparsity_fraction(masks):.1%} | "
          f"{pc.xbar_rows}x{pc.xbar_cols} crossbars "
          f"{rep.xbars_needed}/{rep.xbars_unpruned} "
          f"(-{rep.xbar_savings:.1%}) | cell savings {rep.cell_savings:.1%}"
          + human_bytes)
    return EXIT_OK


def cmd_serve(args) -> int:
    import jax

    from repro.api.adapters import ServeUnsupported
    from repro.api.registry import make_adapter
    from repro.serve import Request, ServeEngine

    adapter = make_adapter(args.arch, scale=args.scale)
    try:
        prefill_fn, decode_fn = adapter.serve_fns()
    except ServeUnsupported as e:
        _emit({"event": "serve_unsupported", "arch": e.arch,
               "family": e.family, "reason": e.reason},
              args.json,
              f"serve: {e.arch} ({e.family} family) has no serving path "
              f"— {e.reason}")
        return EXIT_UNSUPPORTED

    if args.ticket:
        try:
            params, masks = _load_ticket(adapter, args.ticket, args.seed)
        except TicketMismatch as e:
            return _ticket_mismatch(args, e)
    else:
        params = adapter.init_params(jax.random.PRNGKey(args.seed))
        masks = None
    engine = ServeEngine(params=params, cfg=adapter.cfg,
                         prefill_fn=prefill_fn, decode_fn=decode_fn,
                         batch_slots=args.slots, capacity=args.capacity,
                         temperature=args.temperature, masks=masks)
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        prompt = rng.randint(0, 200, size=rng.randint(4, 16))
        engine.submit(Request(uid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new))
    engine.run()
    rep = engine.report
    _emit({"event": "serve", "arch": args.arch,
           "requests": rep.requests, "tokens": rep.tokens_generated,
           "decode_steps": rep.decode_steps,
           "slot_occupancy": rep.slot_occupancy,
           "tokens_per_s": rep.tokens_per_s,
           "bsmm": rep.bsmm_enabled,
           "skipped_tile_fraction": rep.skipped_tile_fraction},
          args.json,
          f"{args.arch}: served {rep.requests} requests, "
          f"{rep.tokens_generated} tokens in {rep.decode_steps} decode "
          f"steps | occupancy {rep.slot_occupancy:.0%} | "
          f"{rep.tokens_per_s:.1f} tok/s | "
          + (f"bsmm on ({rep.skipped_tile_fraction:.0%} tiles skipped)"
             if rep.bsmm_enabled else "bsmm off (dense)"))
    return EXIT_OK


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Prune, fine-tune, report, and serve any registered "
                    "architecture through the repro.api session layer.")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("archs", help="list registered archs and families")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_archs)

    p = sub.add_parser("recipes",
                       help="list registered prune recipes (staged "
                            "programs) and which families they tune")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_recipes)

    p = sub.add_parser("prune", help="run a prune recipe (PruningSession)")
    _add_common(p)
    p.add_argument("--recipe", default=None,
                   help="staged prune program: a name from "
                        "`python -m repro.api recipes` or a path to a "
                        "recipe .json (wins over --granularity)")
    p.add_argument("--rounds", type=int, default=3,
                   help="global prune-round budget "
                        "(PruneConfig.max_iters)")
    p.add_argument("--fraction", type=float, default=0.25,
                   help="fraction of remaining weights pruned per round "
                        "(flat schedules; recipes carry per-stage rates)")
    p.add_argument("--tolerance", type=float, default=0.02,
                   help="allowed accuracy drop vs baseline (nats for LMs)")
    p.add_argument("--granularity", default=None,
                   help="comma list overriding the family schedule, "
                        "e.g. expert,filter,index")
    p.add_argument("--steps", type=int, default=None,
                   help="train steps per round (adapter default if unset)")
    p.add_argument("--ticket", default=None,
                   help="export the winning ticket to this directory")
    p.add_argument("--ckpt", default=None,
                   help="session checkpoint dir (resume a killed run)")
    p.set_defaults(fn=cmd_prune)

    p = sub.add_parser("finetune",
                       help="continue training an exported ticket")
    _add_common(p, ticket_required=True)
    p.add_argument("--steps", type=int, default=None)
    p.set_defaults(fn=cmd_finetune)

    p = sub.add_parser("report",
                       help="crossbar accounting of an exported ticket")
    _add_common(p, ticket_required=True)
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("serve", help="serve an LM through ServeEngine")
    _add_common(p)
    p.add_argument("--ticket", default=None,
                   help="serve this pruned ticket (block-sparse decode)")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--capacity", type=int, default=128)
    p.add_argument("--temperature", type=float, default=0.0)
    p.set_defaults(fn=cmd_serve)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
