"""Model adapters: bundle init/train/eval/prunability behind one protocol.

Algorithm 1 is model-agnostic — the only model-specific pieces are how
to initialise parameters, train them under a mask, score them, and
decide which leaves are prunable.  A ``ModelAdapter`` packages those
four so ``PruningSession`` (and the examples) never hand-roll training
closures.

The family-specific pieces — prunability predicate, conv-path
predicate, granularity schedule — are *data* attached to the adapter
(``prunable_pred`` / ``conv_path_pred`` / ``granularities``), injected
by the family registry (``repro.api.registry.make_adapter``) so one
adapter class covers every architecture of its family.

``CNNAdapter``, ``LMAdapter`` (dense / moe / hybrid / ssm / vlm
transformers) and ``EncDecAdapter`` (whisper-style) are built on
``repro.train.loop.Trainer`` — the same operational layer (jitted
masked steps, data pipeline, checkpoint/resume) used for production
training, so a model pruned through the session fine-tunes and serves
with zero glue code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import (apply_masks, cnn_conv_path, cnn_prunable,
                              encdec_prunable, lm_prunable, make_masks)
from repro.core.quantize import fake_quantize_tree
from repro.data import (DataPipeline, SyntheticAudio, SyntheticImages,
                        SyntheticLM)
from repro.optim import (adamw, constant, exponential_epoch_decay, masked,
                         sgd, warmup_cosine)
from repro.kernels.bsmm import default_interpret
from repro.models.plans import PlanStats
from repro.train import Trainer, cnn_train_plan, lm_train_plan


class ServeUnsupported(NotImplementedError):
    """An adapter whose family has no ServeEngine path.

    Structured (arch/family/reason) so callers — the CLI ``serve``
    subcommand in particular — can report *why* per architecture
    instead of surfacing a bare traceback.
    """

    def __init__(self, arch: str, family: str, reason: str):
        self.arch = arch
        self.family = family
        self.reason = reason
        super().__init__(f"{arch} ({family}): serving unsupported — "
                         f"{reason}")


class ModelAdapter:
    """Protocol: everything a pruning session needs from a model.

    ``train``/``evaluate`` take ``masks=None`` for the dense model.
    ``evaluate`` returns a scalar where HIGHER IS BETTER (accuracy for
    classifiers; adapters for likelihood models return negative loss).

    ``prunable_pred`` / ``conv_path_pred`` / ``granularities`` /
    ``recipe`` are the per-family registry data; subclasses set
    defaults and ``make_adapter`` overrides them from the family entry.

    ``train`` accepts ``quantize_bits``: when set, the jitted step
    fake-quantizes the prunable weights (straight-through, fixed point
    at that width) so tickets retrain quantization-aware — the
    ``quantize`` recipe stage.  Adapters without a QAT path may ignore
    it.
    """

    cfg: Any = None
    family: str = "custom"
    # None → the session falls back to PruneConfig.granularities
    granularities: Optional[Sequence[str]] = None
    # family-tuned Recipe (or registered recipe name); None → schedule
    recipe: Optional[Any] = None
    prunable_pred: Optional[Callable[[str, Any], bool]] = None
    conv_path_pred: Optional[Callable[[str], bool]] = None

    def init_params(self, rng):
        raise NotImplementedError

    def train(self, params, masks=None, steps: Optional[int] = None,
              *, quantize_bits: Optional[int] = None):
        raise NotImplementedError

    def _qat(self, quantize_bits: Optional[int]):
        """Loss-input transform for quantization-aware retraining."""
        if quantize_bits is None:
            return lambda p: p
        return lambda p: fake_quantize_tree(p, self.prunable,
                                            quantize_bits)

    def evaluate(self, params, masks=None) -> float:
        raise NotImplementedError

    def prunable(self, path: str, leaf) -> bool:
        if self.prunable_pred is None:
            raise NotImplementedError(
                f"{type(self).__name__} has no prunable_pred")
        return self.prunable_pred(path, leaf)

    def conv_pred(self, path: str) -> bool:
        return bool(self.conv_path_pred(path)) if self.conv_path_pred \
            else False

    def serve_fns(self) -> Tuple[Callable, Callable]:
        """(prefill_fn, decode_fn) for ServeEngine handoff."""
        cfg_name = getattr(self.cfg, "name", "<unknown>")
        raise ServeUnsupported(
            cfg_name, self.family,
            f"{type(self).__name__} exposes no prefill/decode pair")


@dataclasses.dataclass
class FunctionAdapter(ModelAdapter):
    """Wrap plain closures — the bridge for ``core.algorithm.realprune``
    callers and for scripted/deterministic tests."""

    params: Any = None
    train_fn: Callable = None           # (params, masks) -> params
    eval_fn: Callable = None            # (params, masks) -> float
    prunable: Callable = None           # (path, leaf) -> bool
    conv_pred: Callable = None          # (path) -> bool
    cfg: Any = None

    def init_params(self, rng):
        return jax.tree.map(lambda x: x, self.params)

    def train(self, params, masks=None, steps=None, *, quantize_bits=None):
        # scripted closures predate QAT; bits are accepted and ignored
        return self.train_fn(params, masks)

    def evaluate(self, params, masks=None) -> float:
        return float(self.eval_fn(params, masks))


class CNNAdapter(ModelAdapter):
    """CNN (VGG/ResNet family) on image batches, trained via ``Trainer``.

    BatchNorm statistics thread through the Trainer's aux-state channel;
    each ``train`` call restarts them from initialisation (every prune
    iteration retrains the rewound ticket from scratch, paper line 3).

    ``use_bsmm``: when retraining under masks, the FC/head matmuls are
    routed through the block-sparse kernel — the plan is rebuilt from
    the CURRENT masks on every ``train`` call, so each deeper prune
    round retrains with proportionally fewer tile passes.  ``None``
    (default) auto-enables on real TPU backends only: under CPU
    interpret emulation the kernels are a correctness path, not a fast
    path, so big CPU runs stay on XLA dense unless you pass ``True``.
    Shapes that don't tile 128 stay dense automatically.
    """

    family = "cnn"

    def __init__(self, cfg, *, data=None, steps: int = 80,
                 batch_size: int = 64, lr: float = 0.05,
                 lr_decay: float = 0.95, decay_every: Optional[int] = None,
                 eval_batches: int = 3, eval_batch_size: int = 128,
                 momentum: float = 0.9, log_every: int = 0,
                 use_bsmm: Optional[bool] = None,
                 bsmm_interpret: Optional[bool] = None):
        from repro.models import cnn as cnn_lib
        self._cnn = cnn_lib
        self.cfg = cfg
        self.prunable_pred = cnn_prunable
        self.conv_path_pred = cnn_conv_path
        self.data = data or SyntheticImages(image_size=cfg.image_size,
                                            noise=0.25)
        self.steps = steps
        self.batch_size = batch_size
        self.lr, self.lr_decay = lr, lr_decay
        self.decay_every = decay_every
        self.eval_batches = eval_batches
        self.eval_batch_size = eval_batch_size
        self.momentum = momentum
        self.log_every = log_every
        self.use_bsmm = (not default_interpret() if use_bsmm is None
                         else use_bsmm)
        self.bsmm_interpret = bsmm_interpret
        self.last_plan_stats = PlanStats()
        self.last_metrics: Dict[str, float] = {}
        self._bn0 = None
        self._bn = None

    # -- protocol ----------------------------------------------------------
    def init_params(self, rng):
        params, bn = self._cnn.init_params(rng, self.cfg)
        self._bn0 = bn
        self._bn = bn
        return params

    def _batch(self, step, size):
        b = self.data.batch(step, size)
        return {"images": jnp.asarray(b["images"]),
                "labels": jnp.asarray(b["labels"])}

    def train(self, params, masks=None, steps=None, *, quantize_bits=None):
        if self._bn0 is None:
            raise RuntimeError("call init_params before train")
        steps = steps or self.steps
        sched = exponential_epoch_decay(
            self.lr, self.lr_decay, self.decay_every or max(steps // 2, 1))
        opt = sgd(sched, momentum=self.momentum)
        if masks is not None:
            opt = masked(opt, masks)
            params = apply_masks(params, masks)
        plans, self.last_plan_stats = (
            cnn_train_plan(masks, interpret=self.bsmm_interpret)
            if masks is not None and self.use_bsmm else (None, PlanStats()))
        qat = self._qat(quantize_bits)

        def loss(p, state, batch):
            l, (new_state, _) = self._cnn.loss_fn(qat(p), state, self.cfg,
                                                  batch, train=True,
                                                  plans=plans)
            return l, (new_state, {})

        # donate=False: the session re-applies masks to the same w_init
        # snapshot across iterations, so caller buffers must survive
        trainer = Trainer(
            loss_fn=loss, optimizer=opt, params=params,
            data_iter=DataPipeline(
                lambda s: self._batch(s, self.batch_size), prefetch=0),
            ckpt_dir=None, aux_state=self._bn0, donate=False)
        self.last_metrics = trainer.run(steps, log_every=self.log_every)
        self._bn = trainer.state.aux
        return trainer.state.params

    def evaluate(self, params, masks=None) -> float:
        accs = []
        for i in range(self.eval_batches):
            b = self._batch(10_000 + i, self.eval_batch_size)
            accs.append(float(self._cnn.accuracy(
                params, self._bn, self.cfg, b["images"], b["labels"])))
        return float(np.mean(accs))


class LMAdapter(ModelAdapter):
    """Decoder-only transformer family — dense, MoE, hybrid
    (attention + RG-LRU), ssm (xLSTM) and vlm (patch-prefix) archs all
    run through ``models.transformer.forward``, so ONE adapter covers
    every block kind; the family registry supplies the per-family
    prunability predicate and granularity schedule as data.

    ``evaluate`` returns NEGATIVE mean cross-entropy on held-out batches
    (higher is better, so the session's accuracy gate applies
    unchanged; set ``PruneConfig.accuracy_tolerance`` in nats).

    ``use_bsmm``: retrain under masks through the block-sparse kernels
    (attention q/k/v/o + MLP + stacked MoE experts, fwd and bwd);
    ``None`` auto-enables on real TPU backends only — see
    ``CNNAdapter``.
    """

    family = "dense"

    def __init__(self, cfg, *, data=None, steps: int = 100,
                 batch_size: int = 8, seq_len: int = 128,
                 peak_lr: float = 3e-4, warmup: int = 20,
                 eval_batches: int = 2, microbatch: Optional[int] = None,
                 remat: bool = False, log_every: int = 0,
                 step_deadline_s: Optional[float] = None,
                 use_bsmm: Optional[bool] = None,
                 bsmm_interpret: Optional[bool] = None):
        from repro.models import transformer as tfm
        self._tfm = tfm
        self.cfg = cfg
        self.family = getattr(cfg, "family", "dense")
        self.prunable_pred = lm_prunable
        self.data = data or SyntheticLM(
            vocab_size=min(int(cfg.vocab_size), 256), seq_len=seq_len,
            seed=0)
        self.steps = steps
        self.batch_size = batch_size
        self.peak_lr, self.warmup = peak_lr, warmup
        self.eval_batches = eval_batches
        self.microbatch, self.remat = microbatch, remat
        self.log_every = log_every
        self.step_deadline_s = step_deadline_s
        # None → auto: block-sparse retraining on real TPU backends only
        # (interpret-mode emulation is for correctness, not speed)
        self.use_bsmm = (not default_interpret() if use_bsmm is None
                         else use_bsmm)
        self.bsmm_interpret = bsmm_interpret
        self.last_plan_stats = PlanStats()
        self.last_metrics: Dict[str, float] = {}
        self.last_comm_stats: Dict[str, float] = {}

    # -- protocol ----------------------------------------------------------
    def init_params(self, rng):
        return self._tfm.init_params(rng, self.cfg)

    def _patches(self, step: int, size: int):
        """Deterministic patch-prefix embeddings for vlm configs
        (stateless: f(step), like the synthetic data sources)."""
        rng = np.random.RandomState((1_000_003 * step + 11) % (2 ** 31 - 1))
        return jnp.asarray(rng.randn(
            size, self.cfg.num_patch_tokens,
            self.cfg.d_model).astype(np.float32))

    def _batch(self, step):
        b = self.data.batch(step, self.batch_size)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if getattr(self.cfg, "num_patch_tokens", 0):
            out["patches"] = self._patches(step, self.batch_size)
        return out

    def make_trainer(self, params, masks=None, *, steps: Optional[int] = None,
                     start_step: int = 0, ckpt_dir: Optional[str] = None,
                     ckpt_every: int = 50, async_ckpt: bool = True,
                     learning_rate: Optional[float] = None,
                     quantize_bits: Optional[int] = None) -> Trainer:
        """A fully-wired Trainer for these weights — the session/ticket
        handoff point for long runs that need their own checkpoints.

        With ``masks`` (and ``use_bsmm``), the train step closes over a
        block-sparse plan derived from the CURRENT masks: forward and
        both backward matmuls of every routed projection skip dead
        128×128 tiles, so retraining a sparser ticket costs fewer MXU
        passes.  The plan is static — re-jitted per prune round.
        """
        steps = steps or self.steps
        sched = (constant(learning_rate) if learning_rate is not None
                 else warmup_cosine(self.peak_lr,
                                    min(self.warmup, max(steps // 2, 1)),
                                    steps))
        opt = adamw(sched)
        compressor = None
        if masks is not None:
            opt = masked(opt, masks)
            params = apply_masks(params, masks)
            # data-parallel gradient exchange only ships live
            # coordinates: the masked optimizer already zeroes pruned
            # grads and re-masks params, so dropping them on the wire
            # is bitwise-neutral (adamw has no global-norm coupling)
            from repro.distributed.compression import MaskAwareCompressor
            compressor = MaskAwareCompressor(masks)
        plan, self.last_plan_stats = (
            lm_train_plan(masks, interpret=self.bsmm_interpret)
            if masks is not None and self.use_bsmm else (None, PlanStats()))
        qat = self._qat(quantize_bits)
        loss = (lambda p, batch:
                self._tfm.loss_fn(qat(p), self.cfg, batch, plan=plan))
        return Trainer(
            loss_fn=loss, optimizer=opt, params=params,
            data_iter=DataPipeline(self._batch, start_step=start_step,
                                   prefetch=0),
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, async_ckpt=async_ckpt,
            microbatch=self.microbatch, remat=self.remat, donate=False,
            step_deadline_s=self.step_deadline_s, compressor=compressor)

    def train(self, params, masks=None, steps=None, *, start_step: int = 0,
              ckpt_dir: Optional[str] = None,
              learning_rate: Optional[float] = None,
              quantize_bits: Optional[int] = None):
        trainer = self.make_trainer(params, masks, steps=steps,
                                    start_step=start_step, ckpt_dir=ckpt_dir,
                                    learning_rate=learning_rate,
                                    quantize_bits=quantize_bits)
        self.last_metrics = trainer.run(steps or self.steps,
                                        log_every=self.log_every)
        self.last_comm_stats = {}
        if "sent_fraction" in self.last_metrics:
            sf = float(self.last_metrics["sent_fraction"])
            total = sum(int(np.asarray(l).size)
                        for l in jax.tree.leaves(params) if l is not None)
            self.last_comm_stats = {
                "sent_fraction": sf,
                "bytes_per_step": int(round(sf * total)) * 4,
            }
        return trainer.state.params

    def evaluate(self, params, masks=None) -> float:
        losses = []
        for i in range(self.eval_batches):
            loss, _ = self._tfm.loss_fn(params, self.cfg,
                                        self._batch(10_000 + i))
            losses.append(float(loss))
        return -float(np.mean(losses))

    def serve_fns(self):
        # vlm configs serve text-only prompts (no patch prefix): the
        # engine's prompt protocol is token-only, and the transformer
        # treats patches as an optional batch key
        return self._tfm.prefill, self._tfm.decode_step


class EncDecAdapter(ModelAdapter):
    """Whisper-style encoder-decoder on synthetic mel-frame/transcript
    pairs (``SyntheticAudio``), trained via ``Trainer``.

    ``evaluate`` returns NEGATIVE decoder cross-entropy (higher is
    better).  Prunability covers encoder/decoder self-attention, MLPs,
    and the decoder cross-attention (``encdec_prunable``).  Serving
    uses the engine's frames lane: a ``Request`` carries its encoder
    frames alongside the decoder prompt, and the greedy decoder loop
    runs behind the same Request/ServeReport surface as the LM families.
    """

    family = "audio"

    def __init__(self, cfg, *, data=None, steps: int = 60,
                 batch_size: int = 4, seq_len: int = 32,
                 peak_lr: float = 3e-4, warmup: int = 10,
                 eval_batches: int = 2, log_every: int = 0):
        from repro.models import encdec
        self._mod = encdec
        self.cfg = cfg
        self.family = getattr(cfg, "family", "audio")
        self.prunable_pred = encdec_prunable
        self.data = data or SyntheticAudio(
            vocab_size=min(int(cfg.vocab_size), 256), seq_len=seq_len,
            n_frames=int(cfg.encoder_seq_len), d_model=int(cfg.d_model),
            seed=0)
        self.steps = steps
        self.batch_size = batch_size
        self.peak_lr, self.warmup = peak_lr, warmup
        self.eval_batches = eval_batches
        self.log_every = log_every
        self.last_plan_stats = PlanStats()
        self.last_metrics: Dict[str, float] = {}

    # -- protocol ----------------------------------------------------------
    def init_params(self, rng):
        return self._mod.init_params(rng, self.cfg)

    def _batch(self, step):
        b = self.data.batch(step, self.batch_size)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def train(self, params, masks=None, steps=None, *, quantize_bits=None):
        steps = steps or self.steps
        sched = warmup_cosine(self.peak_lr,
                              min(self.warmup, max(steps // 2, 1)), steps)
        opt = adamw(sched)
        if masks is not None:
            opt = masked(opt, masks)
            params = apply_masks(params, masks)
        qat = self._qat(quantize_bits)

        def loss(p, batch):
            return self._mod.loss_fn(qat(p), self.cfg, batch)

        trainer = Trainer(
            loss_fn=loss, optimizer=opt, params=params,
            data_iter=DataPipeline(self._batch, prefetch=0),
            ckpt_dir=None, donate=False)
        self.last_metrics = trainer.run(steps, log_every=self.log_every)
        return trainer.state.params

    def evaluate(self, params, masks=None) -> float:
        losses = []
        for i in range(self.eval_batches):
            loss, _ = self._mod.loss_fn(params, self.cfg,
                                        self._batch(10_000 + i))
            losses.append(float(loss))
        return -float(np.mean(losses))

    def serve_fns(self):
        # the engine routes requests with frames through its enc-dec
        # prefill lane ({"tokens", "frames"} batch, exact-length); the
        # decoder's per-step signature matches the LM protocol
        return self._mod.prefill, self._mod.decode_step

    def serve_frames(self, uid: int = 0) -> np.ndarray:
        """Deterministic synthetic encoder frames for one request —
        the serving-side analogue of the training ``SyntheticAudio``
        batches (CLI/demo input when no real mel frames exist)."""
        rng = np.random.RandomState(uid)
        return rng.randn(self.cfg.encoder_seq_len,
                         self.cfg.d_model).astype(np.float32) * 0.1
