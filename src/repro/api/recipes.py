"""Declarative PruneRecipe API: staged prune programs.

The paper's result is a *program*, not a knob: Algorithm 1 walks a
granularity schedule behind an accuracy gate, the ticket retrains from
scratch, and the hardware saving assumes the ReRAM-native fixed-point
representation.  A ``Recipe`` makes that program first-class — an
ordered tuple of ``Stage``s, each declaring what it does and how it is
budgeted/gated — and ``PruningSession`` interprets it (resumable
mid-stage, checkpoint carries ``(stage_idx, step)``).

Stage kinds:

  ``prune``     — iterative rounds at one granularity (any name in
                  ``core.strategies``): train → prune ``rate`` of the
                  remaining weights → eval-gate.  The stage ends when a
                  round is rejected (coarse→fine hand-off), when
                  ``target_sparsity`` is reached, or after
                  ``max_rounds`` accepted+rejected rounds.
  ``quantize``  — quantization-aware retrain: the ticket trains with
                  straight-through fake quantization at ``bits``
                  (``core.quantize`` × masks wired into the jitted
                  step) and is gated on its *quantized* accuracy.
  ``ablate``    — the paper's schedule-ablation table: retrain once,
                  then score a one-round prune at every granularity in
                  ``granularities`` (whole-``xbar`` included by
                  default) WITHOUT committing any mask — pure
                  measurement, streamed as ``kind="ablate"`` events.

Recipes serialise losslessly (``to_dict``/``from_dict``, JSON file
round-trip), are registered by name (``register_recipe`` /
``get_recipe``), and compile from the legacy flat surface
(``from_granularities`` — the ``granularities=`` shim).  Built-ins:

  paper        — filter → channel → index (Algorithm 1's schedule)
  paper-quant  — the paper schedule + an 8-bit quantize stage
  paper-xbar   — whole-xbar first pass, then the paper schedule
  ablation     — the schedule-ablation sweep (xbar/filter/channel/index)

Per-family tuned full-scale recipes live in ``repro.api.registry``
(``FamilySpec.recipe``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.core.strategies import PAPER_SCHEDULE, require_strategies

STAGE_KINDS = ("prune", "quantize", "ablate")

# default ablation sweep: the coarsest crossbar-aligned structure first
ABLATION_SWEEP: Tuple[str, ...] = ("xbar",) + PAPER_SCHEDULE


@dataclass(frozen=True)
class Stage:
    """One step of a prune program.  Field semantics by ``kind``:

    prune:    ``granularity`` (required), ``rate`` per round,
              ``target_sparsity`` / ``max_rounds`` stage budgets.
    quantize: ``bits`` (8 or 16 — the platform's fixed-point widths).
    ablate:   ``granularities`` sweep, scored at ``rate``.

    Shared: ``retrain_steps`` overrides the adapter's per-round train
    budget; ``accuracy_drop`` overrides the session's gate tolerance
    for this stage only (``None`` → ``PruneConfig.accuracy_tolerance``).
    """
    kind: str
    name: str = ""
    granularity: Optional[str] = None
    rate: float = 0.25
    target_sparsity: Optional[float] = None
    max_rounds: Optional[int] = None
    retrain_steps: Optional[int] = None
    accuracy_drop: Optional[float] = None
    bits: int = 8
    granularities: Tuple[str, ...] = ()

    def __post_init__(self):
        # validation errors name the offending field (and, through
        # ``Recipe``'s wrapping, the recipe name + stage index) — the
        # analysis.recipe_lint R001 rule reuses these messages verbatim
        if self.kind not in STAGE_KINDS:
            raise ValueError(f"field 'kind': unknown stage kind "
                             f"{self.kind!r}; known: {STAGE_KINDS}")
        if self.kind == "prune":
            if not self.granularity:
                raise ValueError("field 'granularity': prune stage "
                                 "needs a granularity")
            require_strategies([self.granularity])
            if not (0.0 < self.rate < 1.0):
                raise ValueError(f"field 'rate': prune rate must be in "
                                 f"(0, 1), got {self.rate}")
            if self.target_sparsity is not None and not (
                    0.0 < self.target_sparsity < 1.0):
                raise ValueError(f"field 'target_sparsity': must be in "
                                 f"(0, 1), got {self.target_sparsity}")
            if self.max_rounds is not None and self.max_rounds < 1:
                raise ValueError(f"field 'max_rounds': must be >= 1, "
                                 f"got {self.max_rounds}")
        elif self.kind == "quantize":
            if self.bits not in (8, 16):
                raise ValueError(f"field 'bits': quantize bits must be "
                                 f"8 or 16, got {self.bits}")
        elif self.kind == "ablate":
            sweep = self.granularities or ABLATION_SWEEP
            require_strategies(sweep)
            object.__setattr__(self, "granularities", tuple(sweep))
            if not (0.0 < self.rate < 1.0):
                raise ValueError(f"field 'rate': ablate rate must be in "
                                 f"(0, 1), got {self.rate}")
        if not self.name:
            object.__setattr__(self, "name", self._default_name())

    def _default_name(self) -> str:
        if self.kind == "prune":
            return f"prune:{self.granularity}"
        if self.kind == "quantize":
            return f"quantize:int{self.bits}"
        return "ablate:" + ",".join(self.granularities)

    def to_dict(self) -> dict:
        out = {"kind": self.kind, "name": self.name}
        if self.kind == "prune":
            out.update(granularity=self.granularity, rate=self.rate)
            if self.target_sparsity is not None:
                out["target_sparsity"] = self.target_sparsity
            if self.max_rounds is not None:
                out["max_rounds"] = self.max_rounds
        elif self.kind == "quantize":
            out["bits"] = self.bits
        else:
            out.update(granularities=list(self.granularities),
                       rate=self.rate)
        if self.retrain_steps is not None:
            out["retrain_steps"] = self.retrain_steps
        if self.accuracy_drop is not None:
            out["accuracy_drop"] = self.accuracy_drop
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "Stage":
        d = dict(d)
        if "granularities" in d:
            d["granularities"] = tuple(d["granularities"])
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown stage field(s) {unknown}; "
                             f"known: {sorted(known)}")
        return cls(**d)


def prune_stage(granularity: str, **kw) -> Stage:
    return Stage(kind="prune", granularity=granularity, **kw)


def quantize_stage(bits: int = 8, **kw) -> Stage:
    return Stage(kind="quantize", bits=bits, **kw)


def ablate_stage(granularities: Sequence[str] = (), **kw) -> Stage:
    return Stage(kind="ablate", granularities=tuple(granularities), **kw)


@dataclass(frozen=True)
class Recipe:
    """An ordered, serializable prune program."""
    name: str
    stages: Tuple[Stage, ...]
    description: str = ""

    def __post_init__(self):
        if not self.stages:
            raise ValueError(f"recipe {self.name!r} has no stages")
        stages = []
        for i, s in enumerate(self.stages):
            if isinstance(s, Stage):
                stages.append(s)
                continue
            try:
                stages.append(Stage.from_dict(s))
            except (ValueError, TypeError, KeyError) as e:
                label = ""
                if isinstance(s, dict) and (s.get("name") or s.get("kind")):
                    label = f" ({s.get('name') or s.get('kind')})"
                raise type(e)(
                    f"recipe {self.name!r} stage[{i}]{label}: {e}") from e
        object.__setattr__(self, "stages", tuple(stages))

    @property
    def prune_granularities(self) -> Tuple[str, ...]:
        return tuple(s.granularity for s in self.stages
                     if s.kind == "prune")

    @property
    def quantize_bits(self) -> Optional[int]:
        """Bits of the last quantize stage (None without one)."""
        bits = [s.bits for s in self.stages if s.kind == "quantize"]
        return bits[-1] if bits else None

    def to_dict(self) -> dict:
        return {"name": self.name, "description": self.description,
                "stages": [s.to_dict() for s in self.stages]}

    @classmethod
    def from_dict(cls, d: dict) -> "Recipe":
        # raw stage dicts go through __post_init__, which wraps their
        # validation errors with the recipe name + stage index/name
        return cls(name=d["name"], description=d.get("description", ""),
                   stages=tuple(d["stages"]))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "Recipe":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "Recipe":
        with open(path) as f:
            return cls.from_json(f.read())

    def replace(self, **kw) -> "Recipe":
        return dataclasses.replace(self, **kw)

    def with_retrain_steps(self, steps: int) -> "Recipe":
        """Every stage's retrain budget overridden to ``steps`` — what
        an explicit ``--steps`` means regardless of where the recipe
        came from (tuned budgets are full-scale; smoke runs aren't)."""
        return self.replace(stages=tuple(
            dataclasses.replace(s, retrain_steps=steps)
            for s in self.stages))


def from_granularities(granularities: Sequence[str], *,
                       rate: float = 0.25, name: str = "legacy",
                       **stage_kw) -> Recipe:
    """Compile a flat granularity schedule to a staged recipe — the
    ``granularities=`` shim.  One prune stage per granularity with no
    per-stage budget reproduces the old cursor loop exactly: rounds
    repeat at a granularity until one is rejected, then the program
    falls through to the next (finer) stage."""
    grans = require_strategies(granularities)
    return Recipe(
        name=name,
        description="compiled from a flat granularity schedule",
        stages=tuple(prune_stage(g, rate=rate, **stage_kw)
                     for g in grans))


# ---------------------------------------------------------------------------
# Named-recipe registry
# ---------------------------------------------------------------------------
_RECIPES: Dict[str, Recipe] = {}


def register_recipe(recipe: Recipe) -> Recipe:
    """Later registrations replace earlier ones (project overrides)."""
    _RECIPES[recipe.name] = recipe
    return recipe


def get_recipe(name: str) -> Recipe:
    if name not in _RECIPES:
        raise KeyError(f"unknown recipe {name!r}; "
                       f"registered: {available_recipes()}")
    return _RECIPES[name]


def available_recipes() -> Tuple[str, ...]:
    return tuple(sorted(_RECIPES))


RecipeLike = Union[Recipe, str, dict]


def resolve_recipe(spec: RecipeLike) -> Recipe:
    """Recipe instance | registered name | path to a .json | dict."""
    if isinstance(spec, Recipe):
        return spec
    if isinstance(spec, dict):
        return Recipe.from_dict(spec)
    if isinstance(spec, str):
        if spec in _RECIPES:
            return _RECIPES[spec]
        if spec.endswith(".json") or os.path.sep in spec:
            if not os.path.exists(spec):
                raise FileNotFoundError(
                    f"recipe file {spec!r} not found (and no registered "
                    f"recipe has that name; known: {available_recipes()})")
            return Recipe.load(spec)
        raise KeyError(f"unknown recipe {spec!r}; registered: "
                       f"{available_recipes()} (or pass a path to a "
                       ".json recipe file)")
    raise TypeError(f"cannot resolve a recipe from {type(spec).__name__}")


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------
register_recipe(Recipe(
    name="paper",
    description="Algorithm 1's coarse-to-fine schedule: filter -> "
                "channel -> index, 25% of remaining weights per round.",
    stages=tuple(prune_stage(g) for g in PAPER_SCHEDULE)))

register_recipe(Recipe(
    name="paper-quant",
    description="The paper schedule followed by an int8 "
                "quantization-aware retrain of the winning ticket "
                "(the ReRAM-native fixed-point representation).",
    stages=tuple(prune_stage(g) for g in PAPER_SCHEDULE)
    + (quantize_stage(8),)))

register_recipe(Recipe(
    name="paper-xbar",
    description="Whole-crossbar first pass (coarsest structure), then "
                "the paper schedule.",
    stages=(prune_stage("xbar"),)
    + tuple(prune_stage(g) for g in PAPER_SCHEDULE)))

register_recipe(Recipe(
    name="ablation",
    description="Schedule-ablation sweep: score one prune round at "
                "each granularity (incl. whole-xbar) without "
                "committing masks — the paper's ablation table.",
    stages=(ablate_stage(ABLATION_SWEEP),)))
