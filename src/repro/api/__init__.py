"""``repro.api`` — the single entry point for pruning, training, and
serving pruned models.

    from repro.api import PruningSession, make_adapter
    adapter = make_adapter("vgg16", scale="tiny")   # ANY registered arch
    session = PruningSession(adapter, PruneConfig())
    result = session.run()                       # resumable Algorithm 1
    session.export_ticket("/tickets/vgg16")      # winning ticket out
    engine = session.serve_engine()              # LMs: straight to serving

Or from the shell (same machinery):

    python -m repro.api prune --arch vgg16 --scale tiny --rounds 3

Pruning runs are *recipes* — staged programs (``prune`` granularity
stages, a ``quantize`` QAT stage, an ``ablate`` sweep) interpreted by
the session:

    session = PruningSession(adapter, recipe="paper-quant")

Layering:

    recipes.py  — Stage/Recipe (serializable), named registry,
                  built-ins, the granularities= shim compiler
    adapters.py — ModelAdapter protocol + CNN/LM/EncDec adapters on
                  Trainer (family specifics injected as data)
    registry.py — family-keyed registry: make_adapter() for every
                  name in configs.list_archs() + list_cnns(), plus the
                  tuned full-scale per-family recipes
    session.py  — PruningSession (recipe interpreter: events,
                  mid-stage checkpoint/resume, ticket handoff)
    cli.py      — prune / finetune / report / serve / recipes

plus ``structured_prune`` for one-shot (no accuracy gate) schedules.
Strategy registration for custom granularities lives in
``repro.core.strategies``; re-exported here for convenience.
"""
from repro.api.adapters import (  # noqa: F401
    CNNAdapter, EncDecAdapter, FunctionAdapter, LMAdapter, ModelAdapter,
    ServeUnsupported,
)
from repro.api.recipes import (  # noqa: F401
    Recipe, Stage, ablate_stage, available_recipes, from_granularities,
    get_recipe, prune_stage, quantize_stage, register_recipe,
    resolve_recipe,
)
from repro.api.registry import (  # noqa: F401
    FamilySpec, available_families, get_family, list_adaptable,
    make_adapter, register_family,
)
from repro.api.session import PruningSession, structured_prune  # noqa: F401
from repro.core.algorithm import PruneEvent, PruneResult  # noqa: F401
from repro.core.strategies import (  # noqa: F401
    GranularityStrategy, TileGeometry, available_strategies, get_strategy,
    register_strategy,
)
