"""``repro.api`` — the single entry point for pruning, training, and
serving pruned models.

    from repro.api import CNNAdapter, PruningSession
    session = PruningSession(CNNAdapter(cfg), PruneConfig())
    result = session.run()                       # resumable Algorithm 1
    session.export_ticket("/tickets/vgg16")      # winning ticket out
    engine = session.serve_engine()              # LMs: straight to serving

Layering:

    adapters.py — ModelAdapter protocol + CNN/LM adapters on Trainer
    session.py  — PruningSession (events, checkpoint/resume, handoff)

plus ``structured_prune`` for one-shot (no accuracy gate) schedules.
Strategy registration for custom granularities lives in
``repro.core.strategies``; re-exported here for convenience.
"""
from repro.api.adapters import (  # noqa: F401
    CNNAdapter, FunctionAdapter, LMAdapter, ModelAdapter,
)
from repro.api.session import PruningSession, structured_prune  # noqa: F401
from repro.core.algorithm import PruneEvent, PruneResult  # noqa: F401
from repro.core.strategies import (  # noqa: F401
    GranularityStrategy, TileGeometry, available_strategies, get_strategy,
    register_strategy,
)
