from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, load_pytree, pack_json, save_pytree, unpack_json,
)
