"""Fault-tolerant checkpointing: atomic, sharded, async, resharding.

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        manifest.json        # pytree structure + leaf → file map + meta
        leaf_00000.npy ...   # one file per leaf (host-local values)
      step_000123.COMMITTED  # atomic commit marker (rename-last)
      LATEST                 # text file holding the newest committed step

Guarantees used by the fault-tolerance layer:
  * a checkpoint is visible only after its COMMITTED marker exists
    (writer crashes leave at most a garbage step_* dir, never a torn
    "latest");
  * ``restore`` can load onto a *different* mesh than the one that
    saved: leaves are saved as full (addressable) arrays and re-placed
    with the target sharding — elastic restart after losing hosts;
  * async mode runs serialization on a background thread so the train
    loop only blocks on device→host transfer.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import path_str


def pack_json(obj) -> np.ndarray:
    """JSON-serializable object → uint8 leaf for checkpoint pytrees.

    Variable-length session state (event histories, resolved recipes)
    rides through the array-only checkpoint format as UTF-8 bytes; the
    restore template is any uint8 array (shape is taken from disk).
    """
    return np.frombuffer(json.dumps(obj).encode("utf-8"), np.uint8).copy()


def unpack_json(arr, default=None):
    """Inverse of ``pack_json``; ``default`` for empty/absent leaves."""
    data = np.asarray(arr, np.uint8).tobytes()
    if not data:
        return default
    return json.loads(data.decode("utf-8"))


def _flatten_with_paths(tree):
    leaves = []

    def visit(path, leaf):
        leaves.append((path_str(path), leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree,
                                     is_leaf=lambda x: x is None)
    return leaves


def save_pytree(tree, directory: str):
    """Write one pytree to ``directory`` (no commit semantics)."""
    os.makedirs(directory, exist_ok=True)
    manifest = {"leaves": [], "version": 1}
    for i, (path, leaf) in enumerate(_flatten_with_paths(tree)):
        entry = {"path": path, "index": i}
        if leaf is None:
            entry["none"] = True
        else:
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(directory, fname), arr)
            entry.update({"file": fname, "dtype": str(arr.dtype),
                          "shape": list(arr.shape)})
        manifest["leaves"].append(entry)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def load_pytree(directory: str, template, shardings=None):
    """Load into the structure of ``template``; optionally device_put with
    per-leaf shardings (pytree of NamedSharding or None)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat_sh = {}
    if shardings is not None:
        for p, s in _flatten_with_paths(shardings):
            flat_sh[p] = s

    def fill(path, leaf):
        p = path_str(path)
        e = by_path.get(p)
        if e is None or e.get("none"):
            return leaf
        arr = np.load(os.path.join(directory, e["file"]))
        sh = flat_sh.get(p)
        if sh is not None:
            return jax.device_put(arr, sh)
        # numpy template leaves restore host-side, bypassing JAX dtype
        # canonicalisation (jnp.asarray would silently downcast float64
        # checkpoints to float32 when x64 is off)
        if isinstance(leaf, np.ndarray) and not isinstance(leaf, jax.Array):
            return arr
        return jnp.asarray(arr)

    return jax.tree_util.tree_map_with_path(fill, template,
                                            is_leaf=lambda x: x is None)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_save: bool = False):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def _marker(self, step: int) -> str:
        return self._step_dir(step) + ".COMMITTED"

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree, blocking: Optional[bool] = None):
        """Checkpoint ``tree`` at ``step`` (atomically)."""
        host_tree = jax.tree.map(
            lambda x: None if x is None else np.asarray(x), tree,
            is_leaf=lambda x: x is None)
        if self.async_save and not (blocking or False):
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree)

    def _write(self, step: int, host_tree):
        d = self._step_dir(step)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(host_tree, tmp)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        with open(self._marker(step), "w") as f:
            f.write(str(time.time()))
        with open(os.path.join(self.root, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.root, "LATEST.tmp"),
                   os.path.join(self.root, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # -- restore ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        committed = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)\.COMMITTED", name)
            if m and os.path.isdir(self._step_dir(int(m.group(1)))):
                committed.append(int(m.group(1)))
        return max(committed) if committed else None

    def restore(self, template, step: Optional[int] = None, shardings=None):
        """Load the newest committed checkpoint (or ``step``) into the
        template's structure; returns (step, tree) or (None, template)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, template
        tree = load_pytree(self._step_dir(step), template, shardings)
        return step, tree

    # -- retention ---------------------------------------------------------
    def _gc(self):
        steps = []
        for name in os.listdir(self.root):
            m = re.fullmatch(r"step_(\d+)\.COMMITTED", name)
            if m:
                steps.append(int(m.group(1)))
        for s in sorted(steps)[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            try:
                os.remove(self._marker(s))
            except OSError:
                pass
