"""Sharded, prefetching, restartable data pipeline.

``ShardedBatcher`` turns a stateless batch generator into per-host
global arrays placed on a mesh (each host materialises only its
data-parallel slice — the multi-host pattern), with background-thread
prefetch.  Because generators are stateless (batch = f(seed, step)),
resuming from a checkpointed step index reproduces the exact stream —
no iterator state to persist.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class DataPipeline:
    """Iterator over f(step) with prefetch and explicit step accounting."""

    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int = 0, prefetch: int = 2):
        self._fn = batch_fn
        self.step = start_step
        self._prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if prefetch > 0:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._fn(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._thread is None:
            batch = self._fn(self.step)
            self.step += 1
            return batch
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()


class ShardedBatcher:
    """Places global batches on a mesh, sharded over the DP axes."""

    def __init__(self, batch_fn, mesh, dp_axes=("data",), prefetch: int = 0):
        self.mesh = mesh
        self.dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
        self.pipe = DataPipeline(batch_fn, prefetch=prefetch)

    def sharding_for(self, arr: np.ndarray):
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(self.dp_axes, *([None] * (arr.ndim - 1)))
        return NamedSharding(self.mesh, spec)

    def __next__(self):
        batch = next(self.pipe)
        return {k: jax.device_put(v, self.sharding_for(v))
                for k, v in batch.items()}

    def __iter__(self):
        return self
