"""Deterministic synthetic datasets (offline container — no downloads).

Both generators are *stateless*: batch = f(seed, step).  That makes the
data pipeline checkpoint-free (restart at step k reproduces the exact
stream), which is the fault-tolerance property large-scale pipelines
need anyway.

SyntheticLM    — token streams with learnable n-gram structure: a fixed
                 random transition table T: the next token is a function
                 of the previous two plus noise.  A model that learns T
                 drives CE well below the uniform-entropy floor.
SyntheticImages— CIFAR-like 32×32×3 images: class = which of 10 fixed
                 random pattern templates is embedded (plus noise), so
                 accuracy is meaningful and reaches ~100% on small nets.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    order: int = 2
    noise: float = 0.05

    def _table(self):
        rng = np.random.RandomState(self.seed)
        return rng.randint(0, self.vocab_size,
                           size=(self.vocab_size, self.vocab_size))

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        """Markov stream: t_{i+1} = T[t_{i-1}, t_i] with ε-noise."""
        T = self._table()
        rng = np.random.RandomState((self.seed * 1_000_003 + step)
                                    % (2 ** 31 - 1))
        toks = np.zeros((batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab_size, batch_size)
        toks[:, 1] = rng.randint(0, self.vocab_size, batch_size)
        for i in range(2, self.seq_len + 1):
            nxt = T[toks[:, i - 2], toks[:, i - 1]]
            flip = rng.rand(batch_size) < self.noise
            nxt = np.where(flip, rng.randint(0, self.vocab_size, batch_size),
                           nxt)
            toks[:, i] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass(frozen=True)
class SyntheticImages:
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    seed: int = 0
    noise: float = 0.3

    def _templates(self) -> np.ndarray:
        rng = np.random.RandomState(self.seed)
        return rng.randn(self.num_classes, self.image_size, self.image_size,
                         self.channels).astype(np.float32)

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        tmpl = self._templates()
        rng = np.random.RandomState((self.seed * 1_000_003 + step + 7)
                                    % (2 ** 31 - 1))
        labels = rng.randint(0, self.num_classes, batch_size)
        imgs = tmpl[labels] + self.noise * rng.randn(
            batch_size, self.image_size, self.image_size,
            self.channels).astype(np.float32)
        return {"images": imgs.astype(np.float32),
                "labels": labels.astype(np.int32)}


@functools.lru_cache(maxsize=8)
def _audio_codebook(seed: int, vocab: int, d_model: int) -> np.ndarray:
    """Token → frame-embedding codebook; pure function of its key, so
    the per-batch randn is paid once (keyed small so old datasets
    don't pin memory)."""
    rng = np.random.RandomState(seed + 17)
    return rng.randn(vocab, d_model).astype(np.float32)


@dataclass(frozen=True)
class SyntheticAudio:
    """Mel-frame / transcript pairs for the whisper-style enc-dec stub.

    Frames are deterministic per (seed, step) pseudo-embeddings whose
    leading rows encode the target token stream through a fixed random
    codebook, so the decoder's cross-attention has real signal to learn
    from; the token stream itself is the same Markov source as
    ``SyntheticLM`` (stateless: batch = f(seed, step)).
    """
    vocab_size: int
    seq_len: int
    n_frames: int
    d_model: int
    seed: int = 0
    noise: float = 0.1

    def _codebook(self) -> np.ndarray:
        return _audio_codebook(self.seed, self.vocab_size, self.d_model)

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        lm = SyntheticLM(self.vocab_size, self.seq_len, self.seed)
        b = lm.batch(step, batch_size)
        rng = np.random.RandomState((self.seed * 999_983 + step + 3)
                                    % (2 ** 31 - 1))
        frames = self.noise * rng.randn(
            batch_size, self.n_frames, self.d_model).astype(np.float32)
        code = self._codebook()
        n = min(self.n_frames, self.seq_len)
        frames[:, :n] += code[b["labels"][:, :n]]
        return {"frames": frames, "tokens": b["tokens"],
                "labels": b["labels"]}


def lm_batch(vocab: int, seq_len: int, batch: int, step: int = 0,
             seed: int = 0) -> Dict[str, np.ndarray]:
    return SyntheticLM(vocab, seq_len, seed).batch(step, batch)


def cifar_like_batch(batch: int, step: int = 0, seed: int = 0
                     ) -> Dict[str, np.ndarray]:
    return SyntheticImages(seed=seed).batch(step, batch)
