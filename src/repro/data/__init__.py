from repro.data.synthetic import (  # noqa: F401
    SyntheticAudio, SyntheticImages, SyntheticLM, cifar_like_batch, lm_batch,
)
from repro.data.pipeline import DataPipeline, ShardedBatcher  # noqa: F401
