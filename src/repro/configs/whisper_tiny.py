"""whisper-tiny — encoder-decoder, conv audio frontend (STUB).

[arXiv:2212.04356; unverified] 4L d_model=384 6H d_ff=1536 vocab=51865.
Frontend is a stub per the assignment: ``input_specs()`` provides
precomputed mel-frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                     # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    mlp_bias=True,
    qkv_bias=True,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq_len=1500,           # 30 s @ 50 Hz after conv stride-2
    tie_embeddings=True,
    subquadratic=False,
    source="[arXiv:2212.04356; unverified]",
))
