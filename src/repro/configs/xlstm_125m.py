"""xlstm-125m — alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517; unverified] 12L d_model=768 4H vocab=50304, d_ff=0
(xLSTM blocks carry their own up/down projections).
"""
from repro.configs.base import ArchConfig, MLSTM, SLSTM, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=(MLSTM, SLSTM),
    rnn_width=1536,                 # 2x up-projection inside blocks
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    subquadratic=True,
    source="[arXiv:2405.04517; unverified]",
))
