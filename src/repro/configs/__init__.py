from repro.configs.base import (  # noqa: F401
    ALL_SHAPES, ATTN, LOCAL_ATTN, MLSTM, MXU_TILE, RGLRU, SLSTM,
    ArchConfig, CNNConfig, ConvSpec, MLAConfig, MoEConfig, PruneConfig,
    ShapeSpec, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    get_arch, get_cnn, get_shape, list_archs, list_cnns, register, scaled_down,
    scaled_down_cnn,
)
