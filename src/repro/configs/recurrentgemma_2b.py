"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1:2 pattern.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  Pattern: two RG-LRU (recurrent) blocks then one local
sliding-window attention block (window 2048), per the Griffin paper.
"""
from repro.configs.base import ArchConfig, LOCAL_ATTN, RGLRU, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    local_window=2048,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    rnn_width=2560,
    conv1d_width=4,
    gated_mlp=True,
    act="gelu",
    tie_embeddings=True,
    subquadratic=True,
    source="[arXiv:2402.19427; hf]",
))
