"""Config system: architecture + shape + pruning + run configs.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
under ``repro.configs``; ``get_arch(name)`` resolves ``--arch`` ids.

Configs are plain dataclasses (no framework deps) so that importing a
config never touches jax device state — required for the dry-run, which
must set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds for the hybrid/ssm families.
# ---------------------------------------------------------------------------
ATTN = "attn"            # (global) self-attention block
LOCAL_ATTN = "local"     # sliding-window / chunked self-attention block
RGLRU = "rglru"          # recurrentgemma RG-LRU recurrent block
MLSTM = "mlstm"          # xLSTM matrix-LSTM block
SLSTM = "slstm"          # xLSTM scalar-LSTM block

# ---------------------------------------------------------------------------
# Hardware tile geometry.  The paper's ReRAM crossbar and the TPU MXU
# share one 128×128 weight-tile shape; this single constant is the
# source of truth for every kernel tile default, the packing lane
# width, and ``PruneConfig.xbar_rows/xbar_cols``.  It lives here (the
# framework-free config layer) so any module can import it without
# touching jax or pallas.
# ---------------------------------------------------------------------------
MXU_TILE = 128

# ---------------------------------------------------------------------------
# Per-backend VMEM budget for kernel launch geometry (bytes).  The
# kernel auditor (analysis.kernel_audit, rule K305) bounds every
# registered kernel's estimated VMEM residency — double-buffered
# input/output blocks plus scratch — against this.  TPU: ~16 MiB of
# VMEM per TensorCore (v4/v5 class).  CPU runs the kernels in
# interpret mode against host memory, but mirrors the TPU budget so a
# tile shape that audits green here also fits when interpret is turned
# off on real hardware.
# ---------------------------------------------------------------------------
VMEM_BUDGET_BYTES = {
    "tpu": 16 * 2 ** 20,
    "cpu": 16 * 2 ** 20,
}


def vmem_budget(backend: str = "tpu") -> int:
    """VMEM byte budget for ``backend`` (unknown backends get the TPU
    budget — the conservative target every kernel must fit)."""
    return VMEM_BUDGET_BYTES.get(backend, VMEM_BUDGET_BYTES["tpu"])


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    # layers [first_moe_layer, n_layers) are MoE; earlier layers use dense FFN
    first_moe_layer: int = 0
    # MoE every k-th layer from first_moe_layer (llama4 interleaving = 2)
    moe_every: int = 1
    router_noise: float = 0.0
    capacity_factor: float = 1.25

    def is_moe_layer(self, i: int) -> bool:
        return i >= self.first_moe_layer and (i - self.first_moe_layer) % self.moe_every == 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in ALL_SHAPES]}")


@dataclass(frozen=True)
class PruneConfig:
    """ReaLPrune / baseline pruning configuration (paper Algorithm 1)."""
    method: str = "realprune"          # realprune | ltp | block | cap | none
    prune_fraction: float = 0.25       # p: fraction of remaining weights pruned / iter
    max_iters: int = 20                # MAX_ITER
    epochs_per_iter: int = 1           # E (paper: epochs; here: eval-gated rounds)
    xbar_rows: int = MXU_TILE          # ReRAM crossbar geometry == TPU tile geometry
    xbar_cols: int = MXU_TILE
    accuracy_tolerance: float = 0.0    # allowed drop vs baseline ("no accuracy drop")
    granularities: Tuple[str, ...] = ("filter", "channel", "index")
    # named repro.api.recipes recipe; overrides `granularities` when set
    # (explicit session recipe/granularities args still win)
    recipe: Optional[str] = None


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # dense | moe | hybrid | ssm | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // n_heads
    qkv_bias: bool = False             # qwen2-style QKV bias
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    act: str = "silu"                  # silu (gated) | gelu
    gated_mlp: bool = True             # llama-style SwiGLU (d_ff is the hidden dim)
    rope_theta: float = 10_000.0
    # attention windowing: None = full attention; int = sliding window size
    local_window: Optional[int] = None
    # per-layer block pattern; None => all ATTN. Cycled to n_layers.
    block_pattern: Optional[Tuple[str, ...]] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # recurrent (rglru / xlstm) extras
    rnn_width: Optional[int] = None
    conv1d_width: int = 4
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500
    # vlm stub: number of prepended image-patch embeddings for train shapes
    num_patch_tokens: int = 0
    # does this arch support sub-quadratic long-context decode?
    subquadratic: bool = False
    # dtype for params/compute at scale
    dtype: str = "bfloat16"
    prune: PruneConfig = field(default_factory=PruneConfig)
    source: str = ""                   # provenance note [source; tier]

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern is None:
            return tuple([ATTN] * self.n_layers)
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the unembedding shards 16-ways × 128 lanes."""
        mult = 2048
        return ((self.vocab_size + mult - 1) // mult) * mult

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + norms)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for i, kind in enumerate(self.blocks):
            total += self._block_params(kind, layer=i)
        total += d  # final norm
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += self._block_params(ATTN, cross=False)
            total += d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(
            1 for i, _k in enumerate(self.blocks) if m.is_moe_layer(i)
        )
        ff_mult = 3 if self.gated_mlp else 2
        dense_all = n_moe_layers * m.num_experts * ff_mult * d * m.d_ff_expert
        dense_active = n_moe_layers * m.top_k * ff_mult * d * m.d_ff_expert
        return total - dense_all + dense_active

    def _block_params(self, kind: str, cross: bool = False, layer: int = 10**9) -> int:
        d = self.d_model
        hd = self.head_dim_
        nq, nkv = self.n_heads, self.n_kv_heads
        p = 2 * d  # two norms
        if kind in (ATTN, LOCAL_ATTN):
            if self.mla is not None:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                p += d * m.q_lora_rank + m.q_lora_rank * nq * qk_hd
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                p += nq * m.v_head_dim * d
            else:
                p += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
                if self.qkv_bias:
                    p += (nq + 2 * nkv) * hd
        elif kind == RGLRU:
            w = self.rnn_width or d
            p += d * w * 2 + w * d        # in (x,gate) + out proj
            p += w * self.conv1d_width    # temporal conv
            p += 3 * w                    # a-gate, input-gate params, a_param
        elif kind == MLSTM:
            w = self.rnn_width or 2 * d
            p += d * w * 2 + w * d        # up (x2) + down
            p += 3 * (w // max(self.n_heads, 1)) * w  # q,k,v per-head proj approx
            p += 3 * w                    # i,f,o gates (per-channel)
        elif kind == SLSTM:
            w = self.rnn_width or d
            p += 4 * d * w + 4 * w * w    # ifzo input + recurrent
            p += d * w * 2 + w * d        # up/down proj
        # FFN
        if kind in (ATTN, LOCAL_ATTN, RGLRU) and self.d_ff > 0:
            mlt = 3 if self.gated_mlp else 2
            if self.moe is not None and self.moe.is_moe_layer(layer):
                m = self.moe
                p += d * m.num_experts  # router
                p += m.num_experts * mlt * d * m.d_ff_expert
                p += m.num_shared_experts * mlt * d * (m.d_ff_shared or m.d_ff_expert)
            else:
                p += mlt * d * self.d_ff
        if cross:
            p += d + d * nq * hd + 2 * d * nkv * hd + nq * hd * d  # cross-attn + norm
        return p


# ---------------------------------------------------------------------------
# CNN configs (the paper's own models: VGG-11/16/19, ResNet-18 on CIFAR-10)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ConvSpec:
    out_channels: int
    kernel: int = 3
    stride: int = 1
    pool: bool = False       # 2x2 maxpool after this conv (VGG style)
    residual: bool = False   # start of a ResNet basic block


@dataclass(frozen=True)
class CNNConfig:
    name: str
    family: str
    convs: Tuple[ConvSpec, ...]
    fc: Tuple[int, ...]
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    prune: PruneConfig = field(default_factory=PruneConfig)
    source: str = ""

    def param_count(self) -> int:
        total, ic = 0, self.in_channels
        for c in self.convs:
            total += c.out_channels * ic * c.kernel * c.kernel
            ic = c.out_channels
        feat = ic
        for f in self.fc:
            total += feat * f
            feat = f
        total += feat * self.num_classes
        return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_ARCH_REGISTRY = {}
_CNN_REGISTRY = {}


def register(cfg):
    if isinstance(cfg, ArchConfig):
        _ARCH_REGISTRY[cfg.name] = cfg
    elif isinstance(cfg, CNNConfig):
        _CNN_REGISTRY[cfg.name] = cfg
    else:  # pragma: no cover
        raise TypeError(type(cfg))
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_REGISTRY)}")
    return _ARCH_REGISTRY[name]


def get_cnn(name: str) -> CNNConfig:
    _ensure_loaded()
    if name not in _CNN_REGISTRY:
        raise KeyError(f"unknown cnn {name!r}; known: {sorted(_CNN_REGISTRY)}")
    return _CNN_REGISTRY[name]


def list_archs() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_ARCH_REGISTRY)


def list_cnns() -> Sequence[str]:
    _ensure_loaded()
    return sorted(_CNN_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    for mod in (
        "recurrentgemma_2b", "phi3_vision_4_2b", "yi_6b", "command_r_35b",
        "llama3_2_3b", "qwen2_72b", "deepseek_v3_671b", "llama4_maverick_400b",
        "whisper_tiny", "xlstm_125m",
        "vgg11", "vgg16", "vgg19", "resnet18",
    ):
        importlib.import_module(f"repro.configs.{mod}")


def scaled_down_cnn(cfg: CNNConfig, *, max_channels: int = 16,
                    max_fc: int = 64, **overrides) -> CNNConfig:
    """Reduced same-structure CNN config for CPU smoke tests: the conv
    stack keeps its depth/stride/pool/residual pattern with channel
    counts capped, so the crossbar unrolls stay family-shaped."""
    convs = tuple(dataclasses.replace(c, out_channels=min(c.out_channels,
                                                          max_channels))
                  for c in cfg.convs)
    small = dict(convs=convs, fc=tuple(min(f, max_fc) for f in cfg.fc),
                 name=cfg.name + "-smoke")
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


def scaled_down(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 8),
            top_k=min(moe.top_k, 2),
            d_ff_expert=64,
            d_ff_shared=64 if moe.num_shared_experts else 0,
            first_moe_layer=min(moe.first_moe_layer, 1),
        )
    mla = cfg.mla
    if mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.block_pattern is None
                     else max(4, len(cfg.block_pattern))),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256 if cfg.d_ff > 0 else 0,
        head_dim=32,
        vocab_size=512,
        rnn_width=128 if cfg.rnn_width else None,
        local_window=min(cfg.local_window, 64) if cfg.local_window else None,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 64),
        num_patch_tokens=min(cfg.num_patch_tokens, 16),
        moe=moe,
        mla=mla,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
