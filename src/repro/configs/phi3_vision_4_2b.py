"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct; hf] 32L d_model=3072 32H
(GQA kv=32) d_ff=8192 vocab=32064.  Vision frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings
(num_patch_tokens per image) that are prepended to the text sequence.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    gated_mlp=True,
    act="silu",
    num_patch_tokens=576,          # CLIP ViT-L/14 @336px → 24×24 patches
    subquadratic=False,
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf]",
))
