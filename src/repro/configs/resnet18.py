"""ResNet-18 (CIFAR-10 variant) — the paper's Fig. 8 model (C1-C17).

[arXiv:1512.03385; verified] stem conv + 8 basic blocks (2 convs each)
= 17 conv layers, widths 64-64x4-128x4-256x4-512x4, FC 512->10.
Strided (stride=2) residual stage transitions, as in the paper.
"""
from repro.configs.base import CNNConfig, ConvSpec, register

CONFIG = register(CNNConfig(
    name="resnet18",
    family="cnn",
    convs=(
        ConvSpec(64),                             # C1 stem
        ConvSpec(64, residual=True), ConvSpec(64),          # block 1
        ConvSpec(64, residual=True), ConvSpec(64),          # block 2
        ConvSpec(128, stride=2, residual=True), ConvSpec(128),  # block 3
        ConvSpec(128, residual=True), ConvSpec(128),        # block 4
        ConvSpec(256, stride=2, residual=True), ConvSpec(256),  # block 5
        ConvSpec(256, residual=True), ConvSpec(256),        # block 6
        ConvSpec(512, stride=2, residual=True), ConvSpec(512),  # block 7
        ConvSpec(512, residual=True), ConvSpec(512),        # block 8
    ),
    fc=(),
    num_classes=10,
    source="[arXiv:1512.03385; verified]",
))
