"""llama4-maverick-400b-a17b — MoE top-1 + shared expert, chunked attention.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
iRoPE-style chunked attention (8192 window) on 3 of 4 layers makes
long-context decode sub-quadratic in practice.
"""
from repro.configs.base import ArchConfig, ATTN, LOCAL_ATTN, MoEConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                      # shared-path FFN width
    vocab_size=202_048,
    gated_mlp=True,
    act="silu",
    rope_theta=500_000.0,
    local_window=8192,
    block_pattern=(LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN, ATTN),
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        d_ff_shared=8192,
        first_moe_layer=1,
        moe_every=2,                # llama4 interleaved MoE (every other layer)
    ),
    subquadratic=True,              # NoPE global layers skipped at 500k via window
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
))
