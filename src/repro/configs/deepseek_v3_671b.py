"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed, top-8).

[arXiv:2412.19437; hf] 61L d_model=7168 128H (kv=128 via MLA latent)
d_ff_expert=2048 vocab=129280.  First 3 layers dense (d_ff=18432),
remaining 58 MoE.  MTP head noted; primary step is next-token.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,                     # dense layers' FFN width
    vocab_size=129_280,
    gated_mlp=True,
    act="silu",
    rope_theta=10_000.0,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        first_moe_layer=3,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    subquadratic=False,
    source="[arXiv:2412.19437; hf]",
))
