"""yi-6b — llama-architecture dense GQA. [arXiv:2403.04652; hf]

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    gated_mlp=True,
    act="silu",
    rope_theta=5_000_000.0,
    subquadratic=False,
    source="[arXiv:2403.04652; hf]",
))
