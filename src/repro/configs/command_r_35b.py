"""command-r-35b — dense GQA, no-bias, layernorm.

[hf:CohereForAI/c4ai-command-r-v01; unverified] 40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256_000,
    gated_mlp=True,
    act="silu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    subquadratic=False,
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
))
