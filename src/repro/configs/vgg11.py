"""VGG-11 (CIFAR-10 variant) — one of the paper's four evaluation CNNs.

[arXiv:1409.1556 config A; verified] Conv widths 64-128-256x2-512x4,
classifier 512->10 (CIFAR convention: single FC head, 2x2 maxpools).
"""
from repro.configs.base import (CNNConfig, ConvSpec, register,
                                scaled_down_cnn)

CONFIG = register(CNNConfig(
    name="vgg11",
    family="cnn",
    convs=(
        ConvSpec(64, pool=True),
        ConvSpec(128, pool=True),
        ConvSpec(256), ConvSpec(256, pool=True),
        ConvSpec(512), ConvSpec(512, pool=True),
        ConvSpec(512), ConvSpec(512, pool=True),
    ),
    fc=(),
    num_classes=10,
    source="[arXiv:1409.1556; verified]",
))

# the registry's reduced smoke CNN as a first-class arch: CI and the
# recipe benchmarks address the tiny model by name instead of relying
# on the --scale tiny reduction of a full config
register(scaled_down_cnn(CONFIG, name="scaled_down_cnn"))
