"""VGG-19 (CIFAR-10 variant) — one of the paper's four evaluation CNNs.

[arXiv:1409.1556 config E; verified] 143M params at ImageNet scale; the
CIFAR variant used by LTP-style studies drops the 4096-wide FC head.
"""
from repro.configs.base import CNNConfig, ConvSpec, register

CONFIG = register(CNNConfig(
    name="vgg19",
    family="cnn",
    convs=(
        ConvSpec(64), ConvSpec(64, pool=True),
        ConvSpec(128), ConvSpec(128, pool=True),
        ConvSpec(256), ConvSpec(256), ConvSpec(256), ConvSpec(256, pool=True),
        ConvSpec(512), ConvSpec(512), ConvSpec(512), ConvSpec(512, pool=True),
        ConvSpec(512), ConvSpec(512), ConvSpec(512), ConvSpec(512, pool=True),
    ),
    fc=(),
    num_classes=10,
    source="[arXiv:1409.1556; verified]",
))
