"""Gradient compression for the DP all-reduce.

Two compressors, both with error feedback (residual accumulation so the
compression error is re-injected next step — required for convergence):

  * ``TopKCompressor``   — keep the top-k fraction by |g| per leaf.
  * ``MaskAwareCompressor`` — the ReaLPrune-specific trick: pruned
    coordinates are *structurally* zero every step, so they are dropped
    from communication entirely (free 1/(1-sparsity)× reduction), then
    top-k is applied to the survivors.

``compressed_psum`` is the shard_map collective: each DP shard
contributes its top-k (values, indices); an all_gather of the sparse
representation + local scatter-add replaces the dense all-reduce.
Traffic: 2·k floats/ints per shard instead of the full gradient.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TopKCompressor:
    k_fraction: float = 0.01

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, residual):
        """Returns (sparse_grads, new_residual, stats).

        sparse_grads has the same dense shapes but only top-k nonzeros —
        the traffic reduction is realised by ``compressed_psum`` /
        counted by ``stats['sent_fraction']``.
        """
        sent = 0
        total = 0

        def comp(g, r):
            nonlocal sent, total
            acc = g.astype(jnp.float32) + r
            flat = acc.reshape(-1)
            k = max(1, int(self.k_fraction * flat.size))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            vals = flat[idx]
            out = jnp.zeros_like(flat).at[idx].set(vals)
            sent += k
            total += flat.size
            return out.reshape(g.shape).astype(g.dtype), \
                (flat - out).reshape(g.shape)

        pairs = jax.tree.map(comp, grads, residual)
        sparse = jax.tree.map(lambda t: t[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return sparse, new_res, {"sent_fraction": sent / max(total, 1)}


@dataclass
class MaskAwareCompressor:
    """Skip pruned coordinates, then top-k the survivors.

    With 95% ReaLPrune sparsity the dense gradient all-reduce shrinks
    20× before any lossy compression — the paper's hardware saving
    reused as a communication saving.
    """
    masks: Any
    k_fraction: float = 1.0       # 1.0 = lossless w.r.t. surviving weights

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, residual):
        from repro.core.masks import apply_masks
        sent = 0
        total = 0

        def count(g, m):
            nonlocal sent, total
            total += g.size
            sent += int(np.asarray(m).sum()) if m is not None else g.size
            return g

        masked = apply_masks(grads, self.masks)
        jax.tree_util.tree_map(
            lambda g: None, grads)  # structure walk only
        # count statically
        from repro.core.masks import path_str
        flat_masks = {}

        def visitm(path, leaf):
            flat_masks[path_str(path)] = leaf
            return leaf
        jax.tree_util.tree_map_with_path(visitm, self.masks,
                                         is_leaf=lambda x: x is None)

        def visitg(path, leaf):
            nonlocal sent, total
            m = flat_masks.get(path_str(path))
            total += leaf.size
            sent += leaf.size if m is None else int(np.asarray(m).sum())
            return leaf
        jax.tree_util.tree_map_with_path(visitg, grads)

        if self.k_fraction < 1.0:
            inner = TopKCompressor(self.k_fraction)
            sparse, new_res, st = inner.compress(masked, residual)
            st["sent_fraction"] *= sent / max(total, 1)
            return sparse, new_res, st
        return masked, residual, {"sent_fraction": sent / max(total, 1)}


def compressed_psum(x, axis_name: str, k: int):
    """Top-k sparse all-reduce primitive for use inside shard_map.

    Each shard sends (values, indices) of its local top-k; the gather +
    scatter-add reconstructs Σ_shards topk(g_shard).  Traffic per link:
    O(k · n_shards) instead of O(size).
    """
    flat = x.reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    all_vals = jax.lax.all_gather(vals, axis_name)      # (n, k)
    all_idx = jax.lax.all_gather(idx, axis_name)
    out = jnp.zeros_like(flat)
    out = out.at[all_idx.reshape(-1)].add(all_vals.reshape(-1))
    return out.reshape(x.shape)


def dp_allreduce_compressed(grads_fn, mesh, dp_axis: str, k_fraction: float):
    """Wrap a per-shard grad function with a compressed DP all-reduce
    under shard_map (used by the optional compressed train step)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def reduced(*args):
        def inner(*a):
            g = grads_fn(*a)
            return jax.tree.map(
                lambda t: compressed_psum(
                    t, dp_axis, max(1, int(k_fraction * t.size))), g)
        return shard_map(inner, mesh=mesh,
                         in_specs=P(dp_axis), out_specs=P())(*args)

    return reduced
