"""Sharding rules: logical-axis assignment with divisibility fallbacks.

Parallelism layout (DESIGN.md §4):
  * DP  — batch over ('pod', 'data')
  * TP  — projections column/row-parallel over 'model'
  * EP  — MoE expert axis over 'model'
  * SP  — decode KV caches sequence-sharded over 'model' when head
          counts don't divide (flash-decode style partial softmax)

Every rule degrades gracefully: a dimension is sharded only when the
mesh axis divides it, so the same code lowers on (16,16), (2,16,16) and
a 1-device CPU (smoke tests see a trivial mesh and all-replicated
specs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param-name classes (last path component)
_COL_PARALLEL = {"wq", "wk", "wv", "up", "gate", "w_in", "w_gate",
                 "w_uq", "w_uk", "w_uv", "wi", "wf", "wz",
                 "frame_adapter", "patch_proj"}
_ROW_PARALLEL = {"wo", "down", "w_out"}
_VOCAB_PARALLEL = {"table"}
_REPLICATED = {"router", "lam", "bi", "bf", "bq", "bk", "bv", "bz", "bo",
               "scale", "bias", "up_b", "down_b", "b"}


def _last_key(path: str) -> str:
    return path.split("/")[-1]


# attention projections whose sharded dim is n_heads*head_dim — a shard
# narrower than head_dim splits a head across devices, which the repo
# never allows (see cache_spec: involuntary SPMD remat in the attention
# einsums, and on multi-axis CPU meshes XLA's repartition of the RoPE'd
# k path is numerically unstable)
_HEAD_COL = {"wq", "wk", "wv", "w_uq", "w_uk", "w_uv"}
_HEAD_ROW = {"wo"}


@dataclass
class ShardingRules:
    mesh: Mesh
    head_dim: Optional[int] = None

    def __post_init__(self):
        names = self.mesh.axis_names
        self.dp_axes = tuple(a for a in ("pod", "data") if a in names)
        self.tp_axis = "model" if "model" in names else None
        self.tp_size = (self.mesh.shape[self.tp_axis]
                        if self.tp_axis else 1)
        self.dp_size = int(np.prod([self.mesh.shape[a]
                                    for a in self.dp_axes])) or 1

    # ------------------------------------------------------------------
    def _tp_if(self, dim: int):
        """'model' iff the axis exists and divides dim."""
        if self.tp_axis and dim % self.tp_size == 0 and dim >= self.tp_size:
            return self.tp_axis
        return None

    def _tp_if_heads(self, dim: int):
        """'model' iff it divides dim AND shards land on head boundaries
        (no-op guard when ``head_dim`` is unknown)."""
        ax = self._tp_if(dim)
        if ax and self.head_dim \
                and (dim // self.tp_size) % self.head_dim != 0:
            return None
        return ax

    def _dp_if(self, dim: int):
        if self.dp_axes and dim % self.dp_size == 0:
            return self.dp_axes
        return None

    # ------------------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for one parameter leaf (stacked dims included)."""
        name = _last_key(path)
        nd = len(shape)
        if nd == 0:
            return P()
        is_moe = "/moe/" in path and name in ("up", "gate", "down")
        if is_moe:
            # (…, E, d, f): expert-parallel over model
            spec = [None] * nd
            spec[-3] = self._tp_if(shape[-3])
            return P(*spec)
        if name in _VOCAB_PARALLEL and nd >= 2:
            spec = [None] * nd
            spec[-2] = self._tp_if(shape[-2])     # vocab dim of (V, d)
            return P(*spec)
        if name in _REPLICATED or nd == 1:
            return P(*([None] * nd))
        if name in _COL_PARALLEL:
            tp = self._tp_if_heads if name in _HEAD_COL else self._tp_if
            spec = [None] * nd
            spec[-1] = tp(shape[-1])
            if spec[-1] is None and nd >= 2:
                spec[-2] = self._tp_if(shape[-2])
            return P(*spec)
        if name in _ROW_PARALLEL:
            tp = self._tp_if_heads if name in _HEAD_ROW else self._tp_if
            spec = [None] * nd
            spec[-2] = tp(shape[-2])
            if spec[-2] is None:
                spec[-1] = self._tp_if(shape[-1])
            return P(*spec)
        if name == "w" and nd >= 3:
            # block-diagonal (…, nb, bs, bs): shard the block axis
            spec = [None] * nd
            spec[-3] = self._tp_if(shape[-3])
            return P(*spec)
        if nd >= 2:
            # default: try column-parallel
            spec = [None] * nd
            spec[-1] = self._tp_if(shape[-1])
            return P(*spec)
        return P(*([None] * nd))

    def params_shardings(self, params_tree):
        """NamedSharding pytree for a (shape-)pytree of parameters."""
        from repro.core.masks import path_str

        def mk(path, leaf):
            if leaf is None:
                return None
            spec = self.param_spec(path_str(path), leaf.shape)
            return NamedSharding(self.mesh, spec)

        return jax.tree_util.tree_map_with_path(
            mk, params_tree, is_leaf=lambda x: x is None)

    # ------------------------------------------------------------------
    def opt_state_shardings(self, opt_tree, zero1: bool = True):
        """ZeRO-1: optimizer moments additionally sharded over 'data'.

        Each m/v leaf keeps its parameter's TP spec and gets the 'data'
        axis on the first remaining divisible dim (often the scan/stack
        dim) — cutting the dominant train-state memory by dp_size.  XLA
        inserts the reduce-scatter/all-gather pair this implies.
        """
        from repro.core.masks import path_str
        data_ax = "data" if "data" in self.mesh.axis_names else None
        dsize = self.mesh.shape.get("data", 1) if data_ax else 1

        def mk(path, leaf):
            if leaf is None:
                return None
            p = path_str(path)
            spec = list(self.param_spec(p, leaf.shape))
            if zero1 and data_ax and p.split("/")[0] in ("m", "v", "mu"):
                for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
                    if s is None and dim % dsize == 0 and dim >= dsize:
                        spec[i] = data_ax
                        break
            return NamedSharding(self.mesh, P(*spec))

        return jax.tree_util.tree_map_with_path(
            mk, opt_tree, is_leaf=lambda x: x is None)

    # ------------------------------------------------------------------
    def batch_spec(self, shape: Tuple[int, ...]) -> P:
        """Inputs: batch over DP axes, rest replicated."""
        if not shape:
            return P()
        return P(self._dp_if(shape[0]), *([None] * (len(shape) - 1)))

    def batch_shardings(self, batch_tree):
        return jax.tree.map(
            lambda l: NamedSharding(self.mesh, self.batch_spec(l.shape)),
            batch_tree)

    # ------------------------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """KV caches / recurrent states (stacked: leading reps dim).

        Heuristic: dim0 may be the scan-stack (reps) — we detect batch
        as the dim matching a DP-shardable size; shard heads on model
        when divisible, else the sequence/capacity dim (SP decode).
        """
        nd = len(shape)
        if nd == 0:
            return P()
        spec: list = [None] * nd
        # find the batch dim: first dim (or second for stacked caches)
        for bdim in range(min(2, nd)):
            if self._dp_if(shape[bdim]) is not None:
                spec[bdim] = self._dp_if(shape[bdim])
                break
        else:
            bdim = -1
        # shard one more dim on model: prefer heads (dim -2 of k/v),
        # else the capacity/sequence dim (head_dim sharding forces
        # involuntary SPMD remat in attention einsums — never pick it)
        if self.tp_axis:
            for cand in (nd - 2, nd - 3):
                if 0 <= cand < nd and spec[cand] is None \
                        and cand != bdim \
                        and shape[cand] % self.tp_size == 0 \
                        and shape[cand] >= self.tp_size:
                    spec[cand] = self.tp_axis
                    break
        return P(*spec)

    def cache_shardings(self, cache_tree):
        from repro.core.masks import path_str

        def mk(path, leaf):
            if leaf is None:
                return None
            return NamedSharding(self.mesh,
                                 self.cache_spec(path_str(path), leaf.shape))

        return jax.tree_util.tree_map_with_path(
            mk, cache_tree, is_leaf=lambda x: x is None)

    # ------------------------------------------------------------------
    def plan_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for one TilePlan index array.

        The compacted dispatch arrays are per-tile-column (forward
        ``idx``/``counts``: one row per N tile) or per-tile-row
        (transposed ``idx_t``/``counts_t``: one row per K tile) — the
        same axes the col-/row-parallel weight specs cut, so axis 0
        shards over 'model' when it divides and replicates otherwise.
        The flat live-tile coordinates (``kk``/``nn``) index the whole
        bitmap and stay replicated."""
        if not shape:
            return P()
        spec = [None] * len(shape)
        if name in ("idx", "counts", "idx_t", "counts_t"):
            spec[0] = self._tp_if(shape[0])
        return P(*spec)

    def shard_plan(self, plan_tree):
        """Device-put every TilePlan's index arrays with NamedShardings
        (static int fields and None leaves pass through untouched)."""
        fields = ("idx", "counts", "idx_t", "counts_t", "kk", "nn")

        def put(tp):
            if tp is None or not hasattr(tp, "_replace"):
                return tp
            upd = {}
            for f in fields:
                arr = getattr(tp, f, None)
                if arr is None:
                    continue
                sh = NamedSharding(self.mesh,
                                   self.plan_spec(f, np.shape(arr)))
                upd[f] = jax.device_put(jnp.asarray(arr), sh)
            return tp._replace(**upd)

        return jax.tree.map(
            put, plan_tree,
            is_leaf=lambda x: x is None or hasattr(x, "_replace"))

    # ------------------------------------------------------------------
    def activation_constrainer(self):
        """Returns f(x, tag_tuple) for transformer.set_constrain_fn."""
        mesh = self.mesh

        def constrain(x, tags):
            if len(tags) != x.ndim:
                return x
            spec = []
            for dim, tag in zip(x.shape, tags):
                if tag == "dp":
                    spec.append(self._dp_if(dim))
                elif tag == "model":
                    spec.append(self._tp_if(dim))
                else:
                    spec.append(None)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))

        return constrain


_INSTALLED: Optional[ShardingRules] = None


def install(rules: Optional[ShardingRules]):
    """Activate activation constraints + MoE grouping (None → reset)."""
    global _INSTALLED
    from repro.models import hooks

    _INSTALLED = rules
    if rules is None:
        hooks.set_constrain_fn(lambda x, tags: x)
        hooks.set_moe_groups(1)
    else:
        hooks.set_constrain_fn(rules.activation_constrainer())
        hooks.set_moe_groups(rules.dp_size)


def installed() -> Optional[ShardingRules]:
    """The rules currently installed (so scoped installers — the
    sharded ``ServeEngine`` traces — can save/restore around a trace)."""
    return _INSTALLED
