"""Fault tolerance: restart supervision, elastic resharding, stragglers.

Checkpoint/restart is the backbone (CheckpointManager provides atomic
commits); this module adds the cluster-side policies:

  * ``Supervisor``      — run-to-completion wrapper: on a step failure
    it restores the newest committed checkpoint and retries, up to
    ``max_restarts`` (the single-process stand-in for a pod-level
    restart controller).  Failure injection hooks make this testable.
  * ``elastic_restore`` — load a checkpoint saved on mesh A onto mesh B
    (fewer/more hosts): leaves are read as full arrays and re-placed
    with B's shardings — the recovery path after losing a slice.
  * ``HeartbeatMonitor``— file-based liveness (one file per worker);
    workers past the deadline are reported for re-slicing.  Stands in
    for the coordination-service heartbeat on a real cluster.
  * Straggler mitigation policy lives in ``train.loop.Trainer``
    (per-step deadline + callback); here we provide ``SkipStraggler``
    — the synchronous-skip policy object.
"""
from __future__ import annotations

import logging
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed.sharding import ShardingRules

log = logging.getLogger("fault_tolerance")


@dataclass
class Supervisor:
    """Restart loop around a training function.

    train_once(state) must raise on failure; returns final state.
    ``inject_failure`` (tests): map step→exception to raise.
    """
    make_trainer: Callable[[], Any]       # () -> Trainer (resumes itself)
    max_restarts: int = 3

    def run(self, num_steps: int) -> Any:
        restarts = 0
        while True:
            trainer = self.make_trainer()
            remaining = num_steps - trainer.state.step
            if remaining <= 0:
                return trainer
            try:
                trainer.run(remaining)
                return trainer
            except Exception as e:  # noqa: BLE001
                restarts += 1
                log.warning("training failed at step %d (%s); restart %d/%d",
                            trainer.state.step, e, restarts,
                            self.max_restarts)
                if restarts > self.max_restarts:
                    raise


def elastic_restore(ckpt_dir: str, template, new_mesh,
                    step: Optional[int] = None):
    """Restore a checkpoint onto a different mesh (elastic scaling)."""
    rules = ShardingRules(new_mesh)
    mgr = CheckpointManager(ckpt_dir)
    shardings = {
        "params": rules.params_shardings(template["params"]),
        "opt_state": jax.tree.map(lambda _: None, template["opt_state"]),
        "step": None,
    } if isinstance(template, dict) and "params" in template else None
    return mgr.restore(template, step=step, shardings=shardings)


@dataclass
class HeartbeatMonitor:
    """File-based liveness; ``clock`` is injectable so the serving
    fleet's failover tests can drive dead/revived transitions without
    real sleeps (the router and its engines share one clock)."""
    root: str
    deadline_s: float = 60.0
    clock: Callable[[], float] = time.time

    def beat(self, worker: str):
        os.makedirs(self.root, exist_ok=True)
        path = os.path.join(self.root, f"{worker}.hb")
        with open(path, "w") as f:
            f.write(str(self.clock()))

    def dead_workers(self) -> List[str]:
        now = self.clock()
        dead = []
        if not os.path.isdir(self.root):
            return dead
        for name in os.listdir(self.root):
            if not name.endswith(".hb"):
                continue
            with open(os.path.join(self.root, name)) as f:
                try:
                    last = float(f.read().strip())
                except ValueError:
                    last = 0.0
            if now - last > self.deadline_s:
                dead.append(name[:-3])
        return dead

    def age(self, worker: str) -> Optional[float]:
        """Seconds since ``worker`` last beat (None: never beat).

        The serving control plane beats once per scheduler tick
        (``ServeEngine.step``); ``serve.frontend`` reads staleness via
        ``dead_workers`` to close the engine's admission gate when the
        decode loop wedges."""
        path = os.path.join(self.root, f"{worker}.hb")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            try:
                last = float(f.read().strip())
            except ValueError:
                return None
        return self.clock() - last


@dataclass
class SkipStraggler:
    """Synchronous-skip policy: tolerate up to ``budget`` slow steps per
    window, then escalate (callback — e.g. trigger re-slicing)."""
    deadline_s: float
    budget: int = 3
    window: int = 100
    escalate: Callable[[int], None] = lambda step: None
    _events: List[int] = field(default_factory=list)

    def __call__(self, step: int, dt: float):
        self._events = [s for s in self._events if step - s < self.window]
        self._events.append(step)
        log.warning("straggler at step %d: %.2fs > %.2fs (%d/%d in window)",
                    step, dt, self.deadline_s, len(self._events), self.budget)
        if len(self._events) > self.budget:
            self.escalate(step)
            self._events.clear()
