from repro.distributed.sharding import ShardingRules  # noqa: F401
