"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """Trivial 1-device mesh for smoke tests (keeps the same code path)."""
    return make_test_mesh()


def make_test_mesh(data: int = 1, model: int = 1):
    """(data, model) mesh over host-platform (virtual) devices.

    Sized for test/CI runs launched with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag
    must be set before jax initialises — subprocess it, never set it
    in-process after import).  ``(1, 1)`` is the old ``make_cpu_mesh``
    smoke path and needs no flag.
    """
    need = data * model
    have = jax.device_count()
    if have < need:
        raise ValueError(
            f"test mesh ({data}x{model}) needs {need} devices, found "
            f"{have}; launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    return jax.make_mesh((data, model), ("data", "model"))
