"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b \
        [--smoke] [--steps N] [--ckpt DIR] [--zero1] [--pruned FRAC]

On this CPU container use ``--smoke`` (reduced same-family config, real
data/optimizer/checkpoint stack).  On a real TPU pod the same script
builds the production mesh, installs the sharding rules and runs the
identical code path — the dry-run (``repro.launch.dryrun``) proves every
assigned config compiles for that path.

Pipeline-parallelism note: PP is intentionally not used (DESIGN.md §4);
scan-over-layers + TP/EP/SP covers the assigned scales.  A PP stage
would slot in as an outer mesh axis plus a collective-permute schedule
around ``_run_segments`` — the hook point is marked below.
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro.configs import get_arch, scaled_down
from repro.data import DataPipeline, SyntheticLM
from repro.distributed.fault_tolerance import SkipStraggler, Supervisor
from repro.distributed.sharding import ShardingRules, install
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import encdec
from repro.models import transformer as tfm
from repro.optim import adamw, masked, warmup_cosine
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    n_dev = len(jax.devices())
    if args.smoke or n_dev == 1:
        cfg = scaled_down(get_arch(args.arch), dtype="float32")
        mesh = make_test_mesh()
    else:  # pragma: no cover — real-pod path, proven by the dry-run
        cfg = get_arch(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = ShardingRules(mesh)
    install(rules)

    mod = encdec if cfg.is_encoder_decoder else tfm
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    if n_dev > 1:  # pragma: no cover
        params = jax.device_put(params, rules.params_shardings(params))

    gen = SyntheticLM(vocab_size=min(cfg.vocab_size, 1024), seq_len=args.seq)

    def batch_fn(step):
        b = gen.batch(step, args.batch)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.is_encoder_decoder:
            out["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model))
        return out

    def loss_fn(p, b):
        return mod.loss_fn(p, cfg, b)

    def make_trainer():
        return Trainer(
            loss_fn=loss_fn,
            optimizer=adamw(warmup_cosine(args.lr, 20, args.steps)),
            params=params,
            data_iter=DataPipeline(batch_fn, prefetch=2),
            ckpt_dir=args.ckpt, ckpt_every=50, async_ckpt=True,
            step_deadline_s=60.0,
            on_straggler=SkipStraggler(deadline_s=60.0))

    with mesh:
        sup = Supervisor(make_trainer=make_trainer, max_restarts=3)
        trainer = sup.run(args.steps)
    print(f"done at step {trainer.state.step}")


if __name__ == "__main__":
    main()
