"""Scan-trip-count correction for XLA cost analysis.

``compiled.cost_analysis()`` visits each while-loop body ONCE (verified:
a 10-step scanned matmul reports 1× the body flops, the unrolled version
10×).  Our models scan over layers, so raw HLO flops/bytes/collectives
undercount by roughly the scan trip count.

This module computes the per-cell correction factor

    κ = (Σ_s reps_s·F_s + F_rest) / (Σ_s F_s + F_rest)

from analytic per-segment forward-flop weights F_s (matmul + attention
terms; MoE counted at *active* expert flops).  κ is exact for uniform
stacks (all layers identical ⇒ κ → reps·F/(F) scaled by the head term)
and flop-weighted for hybrid/tail layouts.  The same κ is applied to
bytes and collective bytes — per-layer bytes/collectives track per-layer
flops within an architecture; the once-per-step gradient all-reduce is
slightly overcounted by this (bounded, noted in EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import List, Tuple

from repro.configs.base import (ATTN, ArchConfig, LOCAL_ATTN, MLSTM, RGLRU,
                                SLSTM, ShapeSpec)
from repro.models.transformer import layer_signature, segments_of


def _attn_ctx(cfg: ArchConfig, kind: str, shape: ShapeSpec) -> float:
    """Mean attended context length per query token."""
    S = shape.seq_len
    if shape.kind == "decode":
        ctx = float(S)
    else:
        ctx = S / 2.0
    if kind == LOCAL_ATTN and cfg.local_window:
        ctx = min(ctx, float(cfg.local_window))
    return ctx


def block_flops_per_token(cfg: ArchConfig, sig, shape: ShapeSpec) -> float:
    """Analytic forward flops per token for one block."""
    kind, is_moe = sig
    d, hd = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    mat = 0.0
    if kind in (ATTN, LOCAL_ATTN):
        if cfg.mla is not None:
            m = cfg.mla
            dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
            mat += d * m.q_lora_rank + m.q_lora_rank * nq * (dn + dr)
            mat += d * (m.kv_lora_rank + dr)
            mat += m.kv_lora_rank * nq * (dn + dv)
            mat += nq * dv * d
            qk_dim, v_dim = dn + dr, dv
        else:
            mat += d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            qk_dim, v_dim = hd, hd
        ctx = _attn_ctx(cfg, kind, shape)
        mat += ctx * nq * (qk_dim + v_dim)       # scores + weighted values
    elif kind == RGLRU:
        w = cfg.rnn_width or d
        mat += 2 * d * w + w * d                 # in/gate/out projections
        mat += 2 * w * (w // max(cfg.n_heads, 1))  # block-diag gates
    elif kind == MLSTM:
        w = cfg.rnn_width or 2 * d
        mat += 2 * d * w + w * d
        mat += 3 * w * (w // max(cfg.n_heads, 1))
        mat += 2 * (w // max(cfg.n_heads, 1)) ** 2 * max(cfg.n_heads, 1)
    elif kind == SLSTM:
        mat += 4 * d * d + 4 * d * (d // max(cfg.n_heads, 1))
        mat += 4 * d * d + 2 * d * d             # post gated MLP
    if cfg.d_ff > 0 and kind in (ATTN, LOCAL_ATTN, RGLRU):
        mlt = 3 if cfg.gated_mlp else 2
        if is_moe and cfg.moe is not None:
            m = cfg.moe
            mat += d * m.num_experts             # router
            active = m.top_k + m.num_shared_experts
            mat += active * mlt * d * m.d_ff_expert
        else:
            mat += mlt * d * cfg.d_ff
    return 2.0 * mat


def segment_flop_weights(cfg: ArchConfig, shape: ShapeSpec
                         ) -> Tuple[List[Tuple[float, int]], float]:
    """([(body_flops, reps)], rest_flops) — absolute fwd flops per step."""
    B, S = shape.global_batch, shape.seq_len
    n_tokens = B * (1 if shape.kind == "decode" else S)
    if cfg.is_encoder_decoder:
        # encoder: one scanned segment over n_encoder_layers
        enc_sig = (ATTN, False)
        enc_tokens = B * cfg.encoder_seq_len
        enc_body = block_flops_per_token(cfg, enc_sig, shape) * enc_tokens
        segs = [(enc_body, cfg.n_encoder_layers)]
        # decoder is a Python loop (unrolled — counted correctly): rest
        dec = block_flops_per_token(cfg, enc_sig, shape) * n_tokens * 1.7
        rest = dec * cfg.n_layers
        rest += 2.0 * cfg.d_model * cfg.padded_vocab * (
            n_tokens if shape.kind != "prefill" else B)
        return segs, rest
    segs = []
    for seg in segments_of(cfg):
        body = sum(block_flops_per_token(cfg, sig, shape) for sig in seg.sigs)
        segs.append((body * n_tokens, seg.reps))
    head_tokens = n_tokens if shape.kind != "prefill" else B
    rest = 2.0 * cfg.d_model * cfg.padded_vocab * head_tokens
    return segs, rest


def scan_correction(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """κ ≥ 1: multiply raw cost_analysis totals by this."""
    segs, rest = segment_flop_weights(cfg, shape)
    counted = sum(f for f, _ in segs) + rest
    true = sum(f * r for f, r in segs) + rest
    return true / max(counted, 1.0)


def corrected_roofline(rec: dict, cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Apply κ to a dry-run record's raw roofline dict (returns a copy)."""
    from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
    kappa = scan_correction(cfg, shape)
    r = dict(rec)
    for k_src, k_dst in (("flops", "flops"), ("bytes", "bytes"),
                         ("collective_bytes", "collective_bytes")):
        r[k_dst] = rec[k_src] * kappa
    r["kappa"] = kappa
    r["compute_s"] = r["flops"] / PEAK_FLOPS
    r["memory_s"] = r["bytes"] / HBM_BW
    r["collective_s"] = r["collective_bytes"] / ICI_BW
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    r["bottleneck"] = max(terms, key=terms.get)
    step = max(terms.values())
    n = rec.get("n_chips", 256)
    if rec.get("model_flops"):
        r["useful_flops_ratio"] = rec["model_flops"] / (r["flops"] * n)
        r["mfu"] = rec["model_flops"] / (step * n * PEAK_FLOPS) if step else 0
    return r
