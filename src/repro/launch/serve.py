"""Production serving launcher (control plane over the batched engine).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        [--requests N] [--pruned FRAC] [--deadline S] [--heartbeat-dir D] \
        [--engines N] [--mesh DxM]

Requests are admitted through ``serve.frontend.ServeFrontend``: a
bounded intake queue backs onto the engine's capacity check, deadlines
cancel expired slots mid-decode, and (with ``--heartbeat-dir``) the
engine's per-tick heartbeat gates admission when the decode loop
wedges.  ``--engines N`` fronts N engines with a ``FleetRouter``
(least-loaded dispatch + heartbeat failover); ``--mesh DxM`` runs each
engine sharded over a (data, model) test mesh (virtual devices on CPU —
launch with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
Same mesh/sharding story as train.py: ``--smoke`` runs the reduced
config on CPU; the full configs' serve_step lowering for the production
meshes is proven by ``repro.launch.dryrun`` (prefill_32k / decode_32k /
long_500k cells).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, scaled_down
from repro.core import algorithm as alg
from repro.core.masks import apply_masks, lm_prunable, make_masks, \
    sparsity_fraction
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import transformer as tfm
from repro.serve import FleetRouter, ServeEngine, ServeFrontend


def parse_mesh(spec):
    """'2x4' → (data=2, model=4)."""
    d, m = (int(x) for x in spec.lower().split("x"))
    return d, m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pruned", type=float, default=0.0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (expired "
                         "requests free their slot mid-decode)")
    ap.add_argument("--heartbeat-dir", default=None,
                    help="HeartbeatMonitor root for decode-loop liveness")
    ap.add_argument("--engines", type=int, default=1,
                    help="fleet size (FleetRouter over N engines)")
    ap.add_argument("--mesh", default=None,
                    help="per-engine DxM test mesh, e.g. 1x2 (needs "
                         "D*M virtual/physical devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.smoke or n_dev == 1 or args.mesh:
        cfg = scaled_down(get_arch(args.arch), dtype="float32")
        mesh = (make_test_mesh(*parse_mesh(args.mesh)) if args.mesh
                else make_test_mesh())
    else:  # pragma: no cover
        cfg = get_arch(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = None
    if args.pruned > 0:
        masks = make_masks(params, lm_prunable)
        per_step = 1 - (1 - args.pruned) ** (1 / 3)
        for gran in ("filter", "channel", "index"):
            masks = alg.prune_step(params, masks, gran, per_step,
                                   lambda p: False)
        params = apply_masks(params, masks)
        print(f"serving at {sparsity_fraction(masks):.1%} sparsity "
              f"(crossbar-aware)")

    monitor = (HeartbeatMonitor(args.heartbeat_dir, deadline_s=30.0)
               if args.heartbeat_dir else None)

    def make_engine(heartbeat=None, worker="engine"):
        # engines install the rules scoped around their own traces, so
        # a fleet of sharded engines coexists in one process
        return ServeEngine(params=params, cfg=cfg,
                           prefill_fn=tfm.prefill,
                           decode_fn=tfm.decode_step,
                           batch_slots=8, capacity=256, masks=masks,
                           heartbeat=heartbeat, heartbeat_worker=worker,
                           mesh=mesh)

    rng = np.random.RandomState(0)
    if args.engines > 1:
        router = FleetRouter([make_engine() for _ in range(args.engines)],
                             monitor=monitor)
        for i in range(args.requests):
            router.submit(
                rng.randint(0, 200, rng.randint(4, 32)).astype(np.int32),
                uid=i, max_new_tokens=args.max_new,
                deadline_s=args.deadline)
        router.drain()
        rep = router.report
        print(f"fleet: {rep.live_engines}/{rep.engines} engines, "
              f"{rep.requests} requests, {rep.tokens_generated} tokens "
              f"({rep.tokens_per_s:.1f} tok/s, "
              f"failovers {rep.failovers}, "
              f"redispatched {rep.redispatched})")
        print(f"latency: ttft p50/p95 {rep.ttft_p50 * 1e3:.1f}/"
              f"{rep.ttft_p95 * 1e3:.1f}ms | per-request tok/s p50/p95 "
              f"{rep.tps_p50:.1f}/{rep.tps_p95:.1f} | "
              f"deadline misses {rep.deadline_misses}")
        return

    engine = make_engine(heartbeat=monitor)
    frontend = ServeFrontend(engine)
    for i in range(args.requests):
        frontend.submit(
            rng.randint(0, 200, rng.randint(4, 32)).astype(np.int32),
            uid=i, max_new_tokens=args.max_new,
            deadline_s=args.deadline)
    frontend.drain()
    rep = engine.report
    print(f"served {rep.requests} requests, {rep.tokens_generated} tokens "
          f"in {rep.decode_steps} decode steps "
          f"(occupancy {rep.slot_occupancy:.0%}, "
          f"{rep.tokens_per_s:.1f} tok/s, "
          f"bsmm={'on' if rep.bsmm_enabled else 'off'})")
    print(f"latency: ttft p50/p95 {rep.ttft_p50 * 1e3:.1f}/"
          f"{rep.ttft_p95 * 1e3:.1f}ms | per-request tok/s p50/p95 "
          f"{rep.tps_p50:.1f}/{rep.tps_p95:.1f} | "
          f"deadline misses {rep.deadline_misses}")


if __name__ == "__main__":
    main()
