"""Production serving launcher (control plane over the batched engine).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        [--requests N] [--pruned FRAC] [--deadline S] [--heartbeat-dir D]

Requests are admitted through ``serve.frontend.ServeFrontend``: a
bounded intake queue backs onto the engine's capacity check, deadlines
cancel expired slots mid-decode, and (with ``--heartbeat-dir``) the
engine's per-tick heartbeat gates admission when the decode loop
wedges.  Same mesh/sharding story as train.py: ``--smoke`` runs the
reduced config on CPU; the full configs' serve_step lowering for the
production meshes is proven by ``repro.launch.dryrun`` (prefill_32k /
decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch, scaled_down
from repro.core import algorithm as alg
from repro.core.masks import apply_masks, lm_prunable, make_masks, \
    sparsity_fraction
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.distributed.sharding import ShardingRules, install
from repro.launch.mesh import make_cpu_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.serve import ServeEngine, ServeFrontend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pruned", type=float, default=0.0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (expired "
                         "requests free their slot mid-decode)")
    ap.add_argument("--heartbeat-dir", default=None,
                    help="HeartbeatMonitor root for decode-loop liveness")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    if args.smoke or n_dev == 1:
        cfg = scaled_down(get_arch(args.arch), dtype="float32")
        mesh = make_cpu_mesh()
    else:  # pragma: no cover
        cfg = get_arch(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    install(ShardingRules(mesh))

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = None
    if args.pruned > 0:
        masks = make_masks(params, lm_prunable)
        per_step = 1 - (1 - args.pruned) ** (1 / 3)
        for gran in ("filter", "channel", "index"):
            masks = alg.prune_step(params, masks, gran, per_step,
                                   lambda p: False)
        params = apply_masks(params, masks)
        print(f"serving at {sparsity_fraction(masks):.1%} sparsity "
              f"(crossbar-aware)")

    heartbeat = (HeartbeatMonitor(args.heartbeat_dir, deadline_s=30.0)
                 if args.heartbeat_dir else None)
    with mesh:
        engine = ServeEngine(params=params, cfg=cfg,
                             prefill_fn=tfm.prefill,
                             decode_fn=tfm.decode_step,
                             batch_slots=8, capacity=256, masks=masks,
                             heartbeat=heartbeat)
        frontend = ServeFrontend(engine)
        rng = np.random.RandomState(0)
        for i in range(args.requests):
            frontend.submit(
                rng.randint(0, 200, rng.randint(4, 32)).astype(np.int32),
                uid=i, max_new_tokens=args.max_new,
                deadline_s=args.deadline)
        frontend.drain()
    rep = engine.report
    print(f"served {rep.requests} requests, {rep.tokens_generated} tokens "
          f"in {rep.decode_steps} decode steps "
          f"(occupancy {rep.slot_occupancy:.0%}, "
          f"{rep.tokens_per_s:.1f} tok/s, "
          f"bsmm={'on' if rep.bsmm_enabled else 'off'})")
    print(f"latency: ttft p50/p95 {rep.ttft_p50 * 1e3:.1f}/"
          f"{rep.ttft_p95 * 1e3:.1f}ms | per-request tok/s p50/p95 "
          f"{rep.tps_p50:.1f}/{rep.tps_p95:.1f} | "
          f"deadline misses {rep.deadline_misses}")


if __name__ == "__main__":
    main()
