"""Step builders + input specs for every (architecture × shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
dry-run pattern.  ``build_step`` returns the pure step function plus the
ShapeDtypeStruct argument trees:

  * train   — (params, opt_state, batch)   → (params, opt_state, metrics)
  * prefill — (params, batch)              → (last-logits, caches)
  * decode  — (params, caches, token)      → (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import encdec
from repro.models import transformer as tfm
from repro.models.layers import _dtype
from repro.optim import Optimizer, adamw, constant


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def cache_capacity(cfg: ArchConfig, shape: ShapeSpec) -> int:
    return int(shape.seq_len)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model inputs of one cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = _dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            return {
                "frames": _sds((B, cfg.encoder_seq_len, cfg.d_model), dt),
                "tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32),
            }
        if cfg.num_patch_tokens:
            p = cfg.num_patch_tokens
            return {
                "tokens": _sds((B, S - p), jnp.int32),
                "patches": _sds((B, p, cfg.d_model), dt),
                "labels": _sds((B, S - p), jnp.int32),
            }
        return {"tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.is_encoder_decoder:
            return {
                "frames": _sds((B, cfg.encoder_seq_len, cfg.d_model), dt),
                "tokens": _sds((B, S), jnp.int32),
            }
        if cfg.num_patch_tokens:
            p = cfg.num_patch_tokens
            return {"tokens": _sds((B, S - p), jnp.int32),
                    "patches": _sds((B, p, cfg.d_model), dt)}
        return {"tokens": _sds((B, S), jnp.int32)}
    # decode: one new token; caches provided separately
    return {"tokens": _sds((B, 1), jnp.int32)}


def params_spec(cfg: ArchConfig):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    init = encdec.init_params if cfg.is_encoder_decoder else tfm.init_params
    return jax.eval_shape(lambda k: init(k, cfg), jax.ShapeDtypeStruct(
        (2,), jnp.uint32))


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    cap = cache_capacity(cfg, shape)
    if cfg.is_encoder_decoder:
        return encdec.cache_spec(cfg, shape.global_batch, cap)
    return tfm.cache_spec(cfg, shape.global_batch, cap)


@dataclass
class StepBundle:
    fn: Any                 # the pure step function
    args: Tuple             # ShapeDtypeStruct trees, positional
    kind: str


def make_optimizer(cfg: ArchConfig) -> Optimizer:
    return adamw(constant(1e-4))


def opt_state_spec(cfg: ArchConfig, pspec):
    opt = make_optimizer(cfg)
    return jax.eval_shape(opt.init, pspec)


def build_step(cfg: ArchConfig, shape: ShapeSpec) -> StepBundle:
    mod = encdec if cfg.is_encoder_decoder else tfm
    if shape.kind == "train":
        opt = make_optimizer(cfg)

        def train_step(params, opt_state, batch):
            def lf(p, b):
                loss, metrics = mod.loss_fn(p, cfg, b)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, {"loss": loss, **metrics}

        pspec = params_spec(cfg)
        return StepBundle(train_step,
                          (pspec, opt_state_spec(cfg, pspec),
                           input_specs(cfg, shape)), "train")
    if shape.kind == "prefill":
        cap = cache_capacity(cfg, shape)

        def prefill_step(params, batch):
            return mod.prefill(params, cfg, batch, cap)

        return StepBundle(prefill_step,
                          (params_spec(cfg), input_specs(cfg, shape)),
                          "prefill")

    def decode_step(params, caches, token):
        return mod.decode_step(params, cfg, caches, token)

    return StepBundle(decode_step,
                      (params_spec(cfg), cache_specs(cfg, shape),
                       input_specs(cfg, shape)["tokens"]), "decode")


# ---------------------------------------------------------------------
# Cell skip logic (assignment rules; reasons recorded in the dry-run)
# ---------------------------------------------------------------------
def cell_skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k dense-KV decode is "
                "sub-quadratic-only per assignment (see DESIGN.md)")
    return None
