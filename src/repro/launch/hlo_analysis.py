"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` supplies HLO FLOPs and bytes; collective traffic is
NOT in cost_analysis, so we parse the optimized HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,4096]{...}' → bytes (0 for unparsable/tuple parts)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_dtype_census(hlo_text: str) -> Dict[str, int]:
    """Count shape occurrences per known dtype in an HLO text.

    Used by the jaxpr auditor's compiled-artifact cross-check: an f64
    entry in an optimized module means an x64 promotion survived all
    the way through compilation (rule J206).  Unknown dtype tokens are
    ignored, like in ``_shape_bytes``.
    """
    census: Dict[str, int] = {}
    for m in _SHAPE_RE.finditer(hlo_text):
        dt = m.group(1)
        if dt in _DTYPE_BYTES:
            census[dt] = census.get(dt, 0) + 1
    return census


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: int):
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO.

    HLO lines look like:
        %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups=...
    The shape on the LHS is the op's (per-participant) output; we use it
    as the traffic proxy for each collective instance.  while-loop
    bodies are counted once (trip counts are applied by the caller for
    scan-over-layers via the 'reps' multiplier when known).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLLECTIVES:
            # match `= <shape> <kind>(` or `= <shape> <kind>-start(`
            m = re.search(r"=\s*([^=]*?)\s+" + kind + r"(?:-start)?\(", s)
            if m:
                stats.add(kind, _shape_bytes(m.group(1)))
                break
    return stats


_WHILE_TRIP_RE = re.compile(
    r'trip_count["\s:=]+(\d+)')


def while_trip_counts(hlo_text: str) -> List[int]:
    """Extract known trip counts of while loops (scan-over-layers)."""
    return [int(m.group(1)) for m in _WHILE_TRIP_RE.finditer(hlo_text)]


@dataclass
class RooflineTerms:
    """All inputs are PER-DEVICE quantities.

    jax ``compiled.cost_analysis()`` reports the SPMD-partitioned
    (per-device) module — verified empirically: a (1024³) matmul on a
    4×4 mesh reports 2·M·K·N/16 flops.  So each term divides by a
    single chip's peak; the '(chips × peak)' of the assignment formula
    is already applied by the partitioner.  ``model_flops`` is global
    and gets divided by n_chips for the useful-flops ratio.
    """
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HBM bytes
    collective_b: float          # per-device collective bytes (output proxy)
    n_chips: int
    model_flops: float = 0.0     # global analytic model flops

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_b / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        if not self.flops:
            return 0.0
        return self.model_flops / (self.flops * self.n_chips)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.n_chips * PEAK_FLOPS)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_b,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu, "n_chips": self.n_chips,
        }


def roofline_from_compiled(compiled, n_chips: int,
                           model_flops: float = 0.0,
                           hlo_text: Optional[str] = None) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    # cost_analysis totals are whole-program; under SPMD they are
    # per-device values already partitioned.
    return RooflineTerms(flops=flops, bytes_accessed=nbytes,
                         collective_b=float(coll.total_bytes),
                         n_chips=n_chips, model_flops=model_flops)
