import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/initialisation (device count locks at init)

_DOC = """Multi-pod dry-run driver.

For every (architecture × input shape) cell:
  1. build the step (train / prefill / decode) with ShapeDtypeStruct
     inputs — no allocation;
  2. jit with in/out shardings from ShardingRules on the production
     mesh (16×16 single-pod; 2×16×16 multi-pod);
  3. ``.lower().compile()`` — sharding/collective/memory bugs surface
     here;
  4. record memory_analysis / cost_analysis / collective bytes into a
     JSON cache consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
# (module docstring kept in _DOC: the XLA_FLAGS lines must come first)

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALL_SHAPES, get_arch, get_shape, list_archs
from repro.distributed.sharding import ShardingRules, install
from repro.launch import steps as steps_lib
from repro.launch.hlo_analysis import (RooflineTerms, collective_bytes,
                                       roofline_from_compiled)
from repro.launch.mesh import make_production_mesh


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params
    excluding vocab embeddings, D = tokens processed by the step."""
    n_active = cfg.active_param_count()
    n_embed = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings
                                                else 2)
    n = max(n_active - n_embed, 1)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    d = shape.global_batch * 1
    return 2.0 * n * d


def apply_variant(cfg, opts: Dict[str, Any]):
    """Config transforms for perf-iteration variants.

    ``pruned=<frac>`` — structural ReaLPrune overlay: crossbar-aware
    column pruning of FFN/expert matrices packs to a narrower matmul
    (the 'freed crossbar columns reused' semantics), so the variant
    lowers with d_ff scaled by (1-frac), padded to 256 lanes.
    """
    import dataclasses as dc
    if opts.get("remat"):
        from repro.models import transformer as _tfm
        _tfm.set_remat(True, policy=str(opts["remat"]))
    if opts.get("capacity") and cfg.moe is not None:
        cfg = dc.replace(cfg, moe=dc.replace(
            cfg.moe, capacity_factor=float(opts["capacity"])))
    if opts.get("pruned"):
        frac = float(opts["pruned"])
        keep = 1.0 - frac

        def pad256(x):
            return max(256, int(x * keep + 255) // 256 * 256)
        changes = {}
        if cfg.d_ff > 0:
            changes["d_ff"] = pad256(cfg.d_ff)
        if cfg.moe is not None:
            changes["moe"] = dc.replace(cfg.moe,
                                        d_ff_expert=pad256(cfg.moe.d_ff_expert),
                                        d_ff_shared=pad256(cfg.moe.d_ff_shared)
                                        if cfg.moe.d_ff_shared else 0)
        if cfg.rnn_width:
            changes["rnn_width"] = pad256(cfg.rnn_width)
        cfg = dc.replace(cfg, **changes)
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             opt_flags: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    opts = opt_flags or {}
    cfg = apply_variant(get_arch(arch), opts)
    shape = get_shape(shape_name)
    skip = steps_lib.cell_skip_reason(cfg, shape)
    variant = ",".join(f"{k}={v}" for k, v in sorted(opts.items()))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
    }
    if variant:
        rec["variant"] = variant
    if skip:
        rec["status"] = "SKIP"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.reshape(-1))
    rules = ShardingRules(mesh)
    install(rules)
    try:
        bundle = steps_lib.build_step(cfg, shape)
        in_shardings = _arg_shardings(rules, bundle,
                                      zero1=bool(opts.get("zero1")))
        t0 = time.time()
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=in_shardings)
            lowered = jitted.lower(*bundle.args)
            compiled = lowered.compile()
        t1 = time.time()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        terms = roofline_from_compiled(
            compiled, n_chips,
            model_flops=model_flops_estimate(cfg, shape), hlo_text=hlo)
        coll = collective_bytes(hlo)
        rec.update({
            "status": "OK",
            "compile_s": round(t1 - t0, 1),
            "n_chips": n_chips,
            "memory": _mem_dict(mem),
            "roofline": terms.as_dict(),
            "collectives": {
                "bytes_by_kind": coll.bytes_by_kind,
                "count_by_kind": coll.count_by_kind,
            },
        })
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed silently
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        install(None)
        from repro.models import transformer as _tfm
        _tfm.set_remat(True, policy="full")
    return rec


def _arg_shardings(rules: ShardingRules, bundle, zero1: bool = False):
    out = []
    for i, arg in enumerate(bundle.args):
        if bundle.kind == "train":
            if i == 0:
                out.append(rules.params_shardings(arg))
            elif i == 1:
                out.append(rules.opt_state_shardings(arg) if zero1
                           else rules.params_shardings(arg))
            else:
                out.append(rules.batch_shardings(arg))
        elif bundle.kind == "prefill":
            out.append(rules.params_shardings(arg) if i == 0
                       else rules.batch_shardings(arg))
        else:  # decode: (params, caches, token)
            if i == 0:
                out.append(rules.params_shardings(arg))
            elif i == 1:
                out.append(rules.cache_shardings(arg))
            else:
                out.append(rules.batch_shardings(arg))
    return tuple(out)


def _mem_dict(mem) -> Dict[str, float]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true",
                    help="merge into an existing results file")
    ap.add_argument("--opt", default="",
                    help="perf-variant flags, e.g. 'zero1' or 'pruned=0.5'"
                         " or 'zero1,pruned=0.9'")
    args = ap.parse_args()
    opt_flags = {}
    for tok in args.opt.split(","):
        if not tok:
            continue
        if "=" in tok:
            k, v = tok.split("=", 1)
            opt_flags[k] = v
        else:
            opt_flags[tok] = True

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES] if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") == "OK"}
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done and not opt_flags:
                    continue
                rec = run_cell(arch, shape, multi_pod=mp,
                               opt_flags=opt_flags)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f"compute={r['compute_s']:.2e}s "
                             f"memory={r['memory_s']:.2e}s "
                             f"coll={r['collective_s']:.2e}s "
                             f"bound={r['bottleneck']} "
                             f"mem/dev={rec['memory']['total_bytes_per_device']/2**30:.2f}GiB "
                             f"compile={rec['compile_s']}s")
                elif status == "FAIL":
                    extra = rec["error"][:200]
                else:
                    extra = rec["reason"][:80]
                print(f"[{status}] {arch} × {shape} × {key[2]}  {extra}",
                      flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "OK")
    n_skip = sum(1 for r in results if r["status"] == "SKIP")
    n_fail = sum(1 for r in results if r["status"] == "FAIL")
    print(f"\n{n_ok} OK, {n_skip} SKIP, {n_fail} FAIL → {args.out}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
