from repro.train.loop import TrainState, Trainer, make_train_step  # noqa: F401
from repro.train.plans import cnn_train_plan, lm_train_plan  # noqa: F401
