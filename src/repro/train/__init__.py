from repro.train.loop import TrainState, Trainer, make_train_step  # noqa: F401
