"""Training loop: jitted masked train step, microbatching, remat, and a
host-side Trainer that wires data / checkpointing / fault tolerance.

The train step is a pure function (params, opt_state, batch, masks) →
(params, opt_state, metrics); ``Trainer`` adds the operational layer a
real cluster needs: auto-resume from the newest committed checkpoint,
periodic async saves, deterministic data (stateless step streams), and
a straggler/failure policy hook.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.masks import apply_masks
from repro.optim import Optimizer

log = logging.getLogger("train")


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0
    aux: Any = None                  # non-gradient model state (e.g. BN stats)


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    microbatch: Optional[int] = None,
                    remat: bool = False,
                    donate: bool = True,
                    compressor=None,
                    has_aux_state: bool = False):
    """Build a jitted train step.

    loss_fn: (params, batch) -> (loss, metrics_dict)
    microbatch: if set, split the batch's leading axis into chunks and
        accumulate gradients with ``lax.scan`` (bitwise-deterministic).
    remat: wrap loss_fn in jax.checkpoint (activation rematerialisation).
    compressor: optional gradient compressor (TopK / MaskAware from
        repro.distributed.compression); its error-feedback residual is
        threaded through opt_state under the key "_compress_residual".
    has_aux_state: the model threads non-gradient state (BatchNorm
        statistics, EMA buffers) through the step.  loss_fn then has
        signature (params, state, batch) -> (loss, (new_state, metrics))
        and the built step is (params, opt_state, state, batch) ->
        (params, opt_state, new_state, metrics).
    """
    lf = jax.checkpoint(loss_fn) if remat else loss_fn
    if has_aux_state:
        if microbatch is not None or compressor is not None:
            raise ValueError("aux state is not supported together with "
                             "microbatching or gradient compression")

        def aux_step_fn(params, opt_state, state, batch):
            def inner(p):
                loss, (new_state, metrics) = lf(p, state, batch)
                return loss, (new_state, metrics)

            (loss, (new_state, metrics)), grads = jax.value_and_grad(
                inner, has_aux=True)(params)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
            metrics = dict(metrics)
            metrics["loss"] = loss
            return new_params, new_opt, new_state, metrics

        return jax.jit(aux_step_fn, donate_argnums=(0, 1) if donate else ())
    grad_fn = jax.value_and_grad(lf, has_aux=True)

    def step_fn(params, opt_state, batch):
        if compressor is not None:
            opt_state, residual = (opt_state["_opt"],
                                   opt_state["_compress_residual"])
        if microbatch is None:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def chunk(batch, i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * microbatch, microbatch, 0), batch)

            n = jax.tree.leaves(batch)[0].shape[0] // microbatch

            def body(carry, i):
                acc, loss_acc = carry
                (loss, _), g = grad_fn(params, chunk(batch, i))
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(n))
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
            metrics = {}
        metrics = dict(metrics)
        if compressor is not None:
            grads, residual, cstats = compressor.compress(grads, residual)
            metrics["sent_fraction"] = cstats["sent_fraction"]
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        if compressor is not None:
            new_opt = {"_opt": new_opt, "_compress_residual": residual}
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step_fn, donate_argnums=donate_argnums)


def init_opt_state(optimizer: Optimizer, params, compressor=None):
    """Optimizer state, wrapping the compressor residual when present."""
    state = optimizer.init(params)
    if compressor is not None:
        return {"_opt": state, "_compress_residual": compressor.init(params)}
    return state


class Trainer:
    """Operational wrapper: resume → train → checkpoint → (survive)."""

    def __init__(self, *, loss_fn, optimizer: Optimizer, params,
                 data_iter, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 100, keep: int = 3,
                 async_ckpt: bool = True,
                 microbatch: Optional[int] = None, remat: bool = False,
                 compressor=None,
                 aux_state=None,
                 donate: bool = True,
                 step_deadline_s: Optional[float] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self._has_aux = aux_state is not None
        self.step_fn = make_train_step(loss_fn, optimizer,
                                       microbatch=microbatch, remat=remat,
                                       compressor=compressor, donate=donate,
                                       has_aux_state=self._has_aux)
        self.optimizer = optimizer
        self.data_iter = data_iter
        self.ckpt = (CheckpointManager(ckpt_dir, keep=keep,
                                       async_save=async_ckpt)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.state = TrainState(
            params, init_opt_state(optimizer, params, compressor), 0,
            aux_state)
        self.step_deadline_s = step_deadline_s
        self.on_straggler = on_straggler or (
            lambda step, dt: log.warning(
                "straggler: step %d took %.2fs (deadline %.2fs)", step, dt,
                self.step_deadline_s))
        self._maybe_resume()

    def _maybe_resume(self):
        if self.ckpt is None:
            return
        tmpl = {"params": self.state.params,
                "opt_state": self.state.opt_state,
                "step": jnp.zeros((), jnp.int32)}
        if self._has_aux:
            tmpl["aux"] = self.state.aux
        step, tree = self.ckpt.restore(tmpl)
        if step is not None:
            self.state = TrainState(tree["params"], tree["opt_state"],
                                    int(tree["step"]),
                                    tree.get("aux", self.state.aux))
            log.info("resumed from checkpoint at step %d", self.state.step)

    def save(self, blocking: bool = False):
        if self.ckpt is None:
            return
        tree = {
            "params": self.state.params,
            "opt_state": self.state.opt_state,
            "step": jnp.asarray(self.state.step, jnp.int32)}
        if self._has_aux:
            tree["aux"] = self.state.aux
        self.ckpt.save(self.state.step, tree, blocking=blocking)

    def run(self, num_steps: int, log_every: int = 50) -> Dict[str, float]:
        metrics = {}
        target = self.state.step + num_steps
        while self.state.step < target:
            batch = next(self.data_iter)
            t0 = time.perf_counter()
            if self._has_aux:
                params, opt_state, aux, metrics = self.step_fn(
                    self.state.params, self.state.opt_state,
                    self.state.aux, batch)
            else:
                params, opt_state, metrics = self.step_fn(
                    self.state.params, self.state.opt_state, batch)
                aux = self.state.aux
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.step_deadline_s is not None and dt > self.step_deadline_s:
                self.on_straggler(self.state.step, dt)
            self.state = TrainState(params, opt_state, self.state.step + 1,
                                    aux)
            if self.state.step % self.ckpt_every == 0:
                self.save()
            if log_every and self.state.step % log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", self.state.step,
                         float(metrics["loss"]), dt)
        if self.ckpt is not None:
            self.save(blocking=True)
            self.ckpt.wait()
        return {k: float(v) for k, v in metrics.items()}
