"""Mask pytree → training-time ``TilePlan`` pytrees.

The paper's headline claim is that crossbar-aware pruning makes CNN
*training* ~20× faster, not just the deployed hardware smaller.  The
TPU analogue: once a ticket's masks are known, every retrain step's
matmuls (forward, dx, dw) can run through the block-sparse Pallas
kernels (``kernels.bsmm``) and scale with the live-tile count.  These
builders derive the per-weight plans from a session's mask pytree; the
adapters rebuild them after every prune round and close them into the
re-jitted train step, so later (sparser) retrain rounds are
proportionally cheaper.

The LM plan reuses the decode-plan walker (``models.plans``): the
training forward consumes the exact same structure — segments →
positions → {"attn": {...}, "mlp": {...}} — that the decode step does.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

from repro.configs.base import MXU_TILE
from repro.kernels.bsmm import default_interpret, make_tile_plan
from repro.models.plans import PlanStats, build_decode_plan


def lm_train_plan(masks, *, tile: int = MXU_TILE,
                  interpret: Optional[bool] = None
                  ) -> Tuple[Optional[list], PlanStats]:
    """Transformer mask pytree → (train plan, PlanStats).

    Scanned segments union their bitmaps over the repeat axis (see
    ``models.plans.build_decode_plan``) — conservative but exact, since
    pruned weights are exact zeros.
    """
    if interpret is None:
        interpret = default_interpret()
    return build_decode_plan(masks, tile=tile, interpret=interpret)


def cnn_train_plan(masks, *, tile: int = MXU_TILE,
                   interpret: Optional[bool] = None
                   ) -> Tuple[Optional[dict], PlanStats]:
    """CNN mask pytree → ({"fc": [plan|None, ...], "head": plan|None},
    PlanStats) for ``models.cnn.forward`` — or (None, stats) when no FC
    or head weight is routable (shapes that don't tile stay dense)."""
    stats = PlanStats()
    if interpret is None:
        interpret = default_interpret()
    if not isinstance(masks, dict):
        return None, stats

    def leaf_plan(entry: Any, label: str):
        m = entry.get("w") if isinstance(entry, dict) else None
        if m is None:
            return None
        m = np.asarray(m)
        if m.ndim != 2:
            return None
        plan = make_tile_plan(m, tile=tile, interpret=interpret)
        if plan is None:
            stats.dense_fallback += 1
            return None
        stats.routed += 1
        stats.live_tiles += plan.live_tiles
        stats.total_tiles += plan.total_tiles
        stats.by_layer.append((label, plan.live_tiles, plan.total_tiles))
        return plan

    fc = [leaf_plan(e, f"fc.{j}") for j, e in enumerate(masks.get("fc", []))]
    head = leaf_plan(masks.get("head"), "head")
    if head is None and not any(p is not None for p in fc):
        return None, stats
    return {"fc": fc, "head": head}, stats
