"""Block-sparse matmul Pallas TPU kernel — the paper's "turned-off
crossbar" realised on the MXU.

A crossbar whose rows/cols are all zero can be power-gated (paper
Fig. 2); the TPU analogue is a 128×128 weight tile that is never DMA'd
HBM→VMEM and never issued to the MXU.  The kernel gets, per output tile
column j, a *compacted* list of live K-tile indices (scalar-prefetched,
so index maps can steer the DMA engine):

    grid = (M/bm, N/bn, KMAX)            KMAX = max_j nnz_k(j)
    x block   (bm, bk) at (i, idx[j,k])  ← skips dead K tiles entirely
    w block   (bk, bn) at (idx[j,k], j)
    out block (bm, bn) at (i, j), f32 VMEM accumulator

Tiles beyond a column's live count are masked with ``pl.when`` (their
DMA re-reads a valid tile; no wrong data is accumulated).  Compute and
bandwidth both scale with the *live tile count* — the paper's hardware
savings, as FLOP/byte savings.

The mask is static at compile time (pruning is a one-time offline step,
paper §V.C), so the compacted indices are baked in as constants.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def compact_tile_indices(tile_mask: np.ndarray) -> Tuple[np.ndarray,
                                                         np.ndarray, int]:
    """Per column j of the (Kt, Nt) tile mask: live k indices + counts.

    Returns (idx (Nt, KMAX) int32, count (Nt,) int32, KMAX).
    Dead slots point at tile 0 (valid DMA target, masked in-kernel).
    """
    tm = np.asarray(tile_mask) != 0
    Kt, Nt = tm.shape
    counts = tm.sum(axis=0).astype(np.int32)
    kmax = max(int(counts.max()) if Nt else 0, 1)
    idx = np.zeros((Nt, kmax), np.int32)
    for j in range(Nt):
        live = np.nonzero(tm[:, j])[0]
        idx[j, : len(live)] = live
    return idx, counts, kmax


def _bsmm_kernel(count_ref, idx_ref, x_ref, w_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < count_ref[j])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsmm_pallas(x, w, tile_mask: np.ndarray, *, bm: int = 128,
                bk: int = 128, bn: int = 128,
                interpret: bool = True):
    """x: (M, K) @ block-sparse w: (K, N) → (M, N).

    ``tile_mask``: host numpy (⌈K/bk⌉, ⌈N/bn⌉) — static sparsity.
    ``interpret=True`` runs the kernel body on CPU (this container);
    on real TPU pass interpret=False.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes must tile: {(M, K, N)} vs {(bm, bk, bn)}"
    idx, counts, kmax = compact_tile_indices(tile_mask)
    assert idx.shape[0] == N // bn and tile_mask.shape[0] == K // bk
    return _bsmm_compact(x, w, idx, counts, kmax, bm=bm, bk=bk, bn=bn,
                         interpret=interpret)


def _bsmm_compact(x, w, idx, counts, kmax: int, *, bm: int, bk: int,
                  bn: int, interpret: bool):
    M, K = x.shape
    N = w.shape[1]
    grid = (M // bm, N // bn, kmax)
    kernel = pl.pallas_call(
        _bsmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk),
                             lambda i, j, k, cnt, idx: (i, idx[j, k])),
                pl.BlockSpec((bk, bn),
                             lambda i, j, k, cnt, idx: (idx[j, k], j)),
            ],
            out_specs=pl.BlockSpec((bm, bn),
                                   lambda i, j, k, cnt, idx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return kernel(jnp.asarray(counts), jnp.asarray(idx), x, w)


# ---------------------------------------------------------------------------
# Tile plans: precompiled sparsity metadata for serving-time matmuls
# ---------------------------------------------------------------------------
class TilePlan(NamedTuple):
    """Static bsmm dispatch data for one pruned (K, N) weight.

    Built once offline from the pruning masks (``make_tile_plan``);
    closed over by the jitted decode step so the compacted indices are
    compile-time constants, exactly like the crossbar bitstream the
    paper bakes into the ReRAM controller.
    """
    idx: np.ndarray         # (Nt, KMAX) int32 — live K-tile ids per column
    counts: np.ndarray      # (Nt,) int32
    kmax: int
    tile: int               # square tile edge (the MXU/crossbar 128)
    live_tiles: int
    total_tiles: int
    interpret: bool = True


def make_tile_plan(mask: np.ndarray, *, tile: int = 128,
                   interpret: bool = True) -> Optional[TilePlan]:
    """Elementwise {0,1} mask (K, N) → ``TilePlan`` or None if the shape
    does not tile evenly (caller falls back to a dense matmul)."""
    m = np.asarray(mask)
    if m.ndim != 2:
        return None
    K, N = m.shape
    if K == 0 or N == 0 or K % tile or N % tile:
        return None
    bitmap = (m != 0).reshape(K // tile, tile, N // tile, tile).any((1, 3))
    idx, counts, kmax = compact_tile_indices(bitmap.astype(np.int32))
    return TilePlan(idx=idx, counts=counts, kmax=kmax, tile=tile,
                    live_tiles=int(bitmap.sum()),
                    total_tiles=int(bitmap.size), interpret=interpret)


def plan_matmul(x, w, plan: Optional[TilePlan]):
    """x (..., K) @ w (K, N) routed through the block-sparse kernel.

    ``plan=None`` is the dense path.  Rows are zero-padded up to a
    sublane multiple (decode batches are tiny: a handful of slots), so
    decode-time compute/bandwidth still scales with the live-tile count
    along K — the dimension pruning actually thins.
    """
    if plan is None:
        return x @ w
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    M = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(M, K)
    # pad M to a multiple of 8 (f32 sublane); large M tiles at 128
    mp = -M % 8
    Mp = M + mp
    if Mp >= plan.tile:
        mp += -Mp % plan.tile
        Mp = M + mp
        bm = plan.tile
    else:
        bm = Mp
    if mp:
        x2 = jnp.pad(x2, ((0, mp), (0, 0)))
    out = _bsmm_compact(x2, w, plan.idx, plan.counts, plan.kmax,
                        bm=bm, bk=plan.tile, bn=plan.tile,
                        interpret=plan.interpret)
    if mp:
        out = out[:M]
    return out.reshape(*lead, N)


def _masked_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref):
    """Dense-grid variant: every tile DMA'd, dead tiles skip the MXU.

    This models LTP's crossbar-UNAWARE sparsity on TPU: bytes still
    move (no bandwidth saved) even when compute is skipped — the
    kernel-level version of the paper's Fig. 2 argument.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.any(m_ref[...] != 0))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...],
                                w_ref[...] * m_ref[...].astype(w_ref.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def masked_matmul_pallas(x, w, mask, *, bm: int = 128, bk: int = 128,
                         bn: int = 128, interpret: bool = True):
    """Elementwise-masked matmul with per-tile MXU skip (no DMA skip)."""
    M, K = x.shape
    _, N = w.shape
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    grid = (M // bm, N // bn, K // bk)
    kernel = pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return kernel(x, w, mask)
