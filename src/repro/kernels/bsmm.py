"""Block-sparse matmul Pallas TPU kernel — the paper's "turned-off
crossbar" realised on the MXU.

A crossbar whose rows/cols are all zero can be power-gated (paper
Fig. 2); the TPU analogue is a 128×128 weight tile that is never DMA'd
HBM→VMEM and never issued to the MXU.  The kernel gets, per output tile
column j, a *compacted* list of live K-tile indices (scalar-prefetched,
so index maps can steer the DMA engine):

    grid = (M/bm, N/bn, KMAX)            KMAX = max_j nnz_k(j)
    x block   (bm, bk) at (i, idx[j,k])  ← skips dead K tiles entirely
    w block   (bk, bn) at (idx[j,k], j)
    out block (bm, bn) at (i, j), f32 VMEM accumulator

Tiles beyond a column's live count are masked with ``pl.when`` (their
DMA re-reads a valid tile; no wrong data is accumulated).  Compute and
bandwidth both scale with the *live tile count* — the paper's hardware
savings, as FLOP/byte savings.

The mask is static at compile time (pruning is a one-time offline step,
paper §V.C), so the compacted indices are baked in as constants.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MXU_TILE
from repro.kernels.compat import CompilerParams
from repro.kernels.spec import BlockMap, KernelSpec, ScratchSpec


class GeometryError(ValueError):
    """A mask/weight shape disagrees with the tile/crossbar geometry.

    Raised where the disagreement is detected (plan construction, plan
    application) instead of surfacing later as an opaque index error
    deep inside a Pallas grid.  Carries the offending ``shape``, the
    ``tile`` edge, and a ``where`` location so lint findings and
    tracebacks can name the exact projection.
    """

    def __init__(self, reason: str, *, shape=None, tile=None, where=""):
        self.reason = reason
        self.shape = None if shape is None else tuple(shape)
        self.tile = tile
        self.where = where
        parts = [reason]
        if shape is not None:
            parts.append(f"shape={self.shape}")
        if tile is not None:
            parts.append(f"tile={tile}")
        if where:
            parts.append(f"at {where}")
        super().__init__(" | ".join(parts))


def default_interpret() -> bool:
    """Emulate the Pallas kernels everywhere except on a real TPU
    backend (interpret mode is a correctness path, not a fast path)."""
    return jax.default_backend() != "tpu"


def tile_bitmap(mask: np.ndarray, bk: int = MXU_TILE,
                bn: int = MXU_TILE) -> np.ndarray:
    """Elementwise {0,1} mask (K, N) → tile liveness (⌈K/bk⌉, ⌈N/bn⌉)."""
    m = np.asarray(mask) != 0
    K, N = m.shape
    pk, pn = (-K) % bk, (-N) % bn
    if pk or pn:
        m = np.pad(m, ((0, pk), (0, pn)))
    return m.reshape(m.shape[0] // bk, bk, m.shape[1] // bn, bn) \
            .any(axis=(1, 3)).astype(np.int32)


def compact_tile_indices(tile_mask: np.ndarray) -> Tuple[np.ndarray,
                                                         np.ndarray, int]:
    """Per column j of the (Kt, Nt) tile mask: live k indices + counts.

    Returns (idx (Nt, KMAX) int32, count (Nt,) int32, KMAX).
    Dead slots point at tile 0 (valid DMA target, masked in-kernel).
    """
    tm = np.asarray(tile_mask) != 0
    Kt, Nt = tm.shape
    counts = tm.sum(axis=0).astype(np.int32)
    kmax = max(int(counts.max()) if Nt else 0, 1)
    idx = np.zeros((Nt, kmax), np.int32)
    for j in range(Nt):
        live = np.nonzero(tm[:, j])[0]
        idx[j, : len(live)] = live
    return idx, counts, kmax


# Epilogue activations the flush can apply in-register (f32 accumulator
# → act → output dtype, one pass over the output instead of two)
_EPILOGUE_ACTS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _epilogue(z, act: Optional[str]):
    if act is None:
        return z
    if act not in _EPILOGUE_ACTS:
        raise ValueError(f"unsupported epilogue act {act!r}; "
                         f"known: {sorted(_EPILOGUE_ACTS)}")
    return _EPILOGUE_ACTS[act](z)


def _bsmm_kernel(count_ref, idx_ref, x_ref, w_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < count_ref[j])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _bsmm_epilogue_kernel(count_ref, idx_ref, x_ref, w_ref, b_ref, o_ref,
                          acc_ref, *, act: Optional[str]):
    """``_bsmm_kernel`` with the bias+activation epilogue fused into the
    flush: the f32 accumulator gets ``+ b`` and the activation while it
    is still in VMEM, saving the extra HBM round-trip a separate
    bias/act pass would cost."""
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < count_ref[j])
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        z = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _epilogue(z, act).astype(o_ref.dtype)


def bsmm_pallas(x, w, tile_mask: np.ndarray, *, bm: int = MXU_TILE,
                bk: int = MXU_TILE, bn: int = MXU_TILE,
                interpret: bool = True):
    """x: (M, K) @ block-sparse w: (K, N) → (M, N).

    ``tile_mask``: host numpy (⌈K/bk⌉, ⌈N/bn⌉) — static sparsity.
    ``interpret=True`` runs the kernel body on CPU (this container);
    on real TPU pass interpret=False.
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise GeometryError("x/w contraction dims disagree",
                            shape=(K, K2), where="bsmm_pallas")
    if M % bm or K % bk or N % bn:
        raise GeometryError(f"shapes must tile {(bm, bk, bn)}",
                            shape=(M, K, N), where="bsmm_pallas")
    idx, counts, kmax = compact_tile_indices(tile_mask)
    assert idx.shape[0] == N // bn and tile_mask.shape[0] == K // bk
    return _bsmm_compact(x, w, idx, counts, kmax, bm=bm, bk=bk, bn=bn,
                         interpret=interpret)


def bsmm_fwd_spec(idx, counts, kmax: int, *, M: int, K: int, N: int,
                  bm: int, bk: int, bn: int, dtype=jnp.float32,
                  fused: bool = False) -> KernelSpec:
    """Launch geometry of the forward bsmm (optionally with the fused
    bias epilogue).  The returned spec's index maps ARE the ones the
    ``pallas_call`` executes — ``_bsmm_compact`` builds from it."""
    idx = np.asarray(idx, np.int32)
    counts = np.asarray(counts, np.int32)
    inputs = [
        BlockMap("x", (bm, bk),
                 lambda i, j, k, cnt, idx: (i, idx[j, k]),
                 (M, K), dtype, gather=True),
        BlockMap("w", (bk, bn),
                 lambda i, j, k, cnt, idx: (idx[j, k], j),
                 (K, N), dtype, gather=True),
    ]
    if fused:
        inputs.append(BlockMap("bias", (1, bn),
                               lambda i, j, k, cnt, idx: (0, j),
                               (1, N), dtype))
    return KernelSpec(
        name="bsmm_fwd_epilogue" if fused else "bsmm_fwd",
        grid=(M // bm, N // bn, kmax),
        dims=("parallel", "parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockMap("out", (bm, bn),
                          lambda i, j, k, cnt, idx: (i, j),
                          (M, N), dtype),),
        scratch=(ScratchSpec((bm, bn), jnp.float32, "accumulator"),),
        scalars=(counts, idx),
        guard=lambda i, j, k, cnt, idx: bool(k < cnt[j]),
        cell_flops=2.0 * bm * bk * bn,
        notes="live K-tile accumulation per output column",
    )


def _bsmm_compact(x, w, idx, counts, kmax: int, *, bm: int, bk: int,
                  bn: int, interpret: bool, bias=None,
                  act: Optional[str] = None):
    M, K = x.shape
    N = w.shape[1]
    fused = bias is not None or act is not None
    spec = bsmm_fwd_spec(idx, counts, kmax, M=M, K=K, N=N, bm=bm, bk=bk,
                         bn=bn, dtype=x.dtype, fused=fused)
    body = functools.partial(_bsmm_epilogue_kernel, act=act) if fused \
        else _bsmm_kernel
    kernel = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=spec.num_scalar_prefetch,
            grid=spec.grid,
            in_specs=spec.pallas_in_specs(),
            out_specs=spec.pallas_out_specs()[0],
            scratch_shapes=spec.pallas_scratch(),
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=interpret,
    )
    if fused:
        b = jnp.zeros((1, N), x.dtype) if bias is None \
            else jnp.asarray(bias).reshape(1, N)
        return kernel(jnp.asarray(counts), jnp.asarray(idx), x, w, b)
    return kernel(jnp.asarray(counts), jnp.asarray(idx), x, w)


# ---------------------------------------------------------------------------
# Tile plans: precompiled sparsity metadata for serving-time matmuls
# ---------------------------------------------------------------------------
class TilePlan(NamedTuple):
    """Static bsmm dispatch data for one pruned (K, N) weight.

    Built once offline from the pruning masks (``make_tile_plan``);
    closed over by the jitted decode/train step so the compacted indices
    are compile-time constants, exactly like the crossbar bitstream the
    paper bakes into the ReRAM controller.

    The forward plan (``idx``/``counts``/``kmax``) steers ``out = x @ w``
    skipping dead K tiles.  The *transposed* plan (``idx_t``/``counts_t``
    /``nmax``) steers the backward ``dx = g @ wᵀ`` the same way along N,
    and the flat live-tile coordinates (``kk``/``nn``) let the ``dw``
    kernel materialise only live (bk, bn) tiles — dead-tile weight grads
    are identically zero because the mask is static.
    """
    idx: np.ndarray         # (Nt, KMAX) int32 — live K-tile ids per column
    counts: np.ndarray      # (Nt,) int32
    kmax: int
    tile: int               # square tile edge (the MXU/crossbar 128)
    live_tiles: int
    total_tiles: int
    interpret: bool = True
    idx_t: Optional[np.ndarray] = None    # (Kt, NMAX) live N-tile ids per row
    counts_t: Optional[np.ndarray] = None  # (Kt,)
    nmax: int = 1
    kk: Optional[np.ndarray] = None       # (L,) K-tile id of each live tile
    nn: Optional[np.ndarray] = None       # (L,) N-tile id of each live tile


def make_tile_plan(mask: np.ndarray, *, tile: int = MXU_TILE,
                   interpret: bool = True,
                   strict: bool = False,
                   where: str = "make_tile_plan") -> Optional[TilePlan]:
    """Elementwise {0,1} mask (K, N) → ``TilePlan``.

    A shape that does not tile evenly returns ``None`` (the caller's
    dense fallback) — or, with ``strict=True``, raises a structured
    ``GeometryError`` naming the shape/tile/location, for callers that
    expect the geometry to hold (lint, tests, TPU launches).  An
    invalid ``tile`` always raises.
    """
    if tile <= 0:
        raise GeometryError(f"tile edge must be positive, got {tile}",
                            tile=tile, where=where)
    m = np.asarray(mask)
    if m.ndim != 2:
        if strict:
            raise GeometryError("mask must be 2-D to tile",
                                shape=m.shape, tile=tile, where=where)
        return None
    K, N = m.shape
    if K == 0 or N == 0 or K % tile or N % tile:
        if strict:
            raise GeometryError("mask shape does not tile evenly",
                                shape=m.shape, tile=tile, where=where)
        return None
    bitmap = tile_bitmap(m, tile, tile)
    idx, counts, kmax = compact_tile_indices(bitmap)
    idx_t, counts_t, nmax = compact_tile_indices(bitmap.T)
    kk, nn = np.nonzero(bitmap)
    return TilePlan(idx=idx, counts=counts, kmax=kmax, tile=tile,
                    live_tiles=int(bitmap.sum()),
                    total_tiles=int(bitmap.size), interpret=interpret,
                    idx_t=idx_t, counts_t=counts_t, nmax=nmax,
                    kk=kk.astype(np.int32), nn=nn.astype(np.int32))


# ---------------------------------------------------------------------------
# Backward kernels: dx via the transposed plan, dw over live tiles only
# ---------------------------------------------------------------------------
def _bsmm_dx_kernel(count_ref, idx_ref, g_ref, w_ref, o_ref, acc_ref):
    """dx[i, k] = Σ_n g[i, n] @ w[k, n]ᵀ over live N tiles of K-row k."""
    k = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(t < count_ref[k])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            g_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsmm_dx_spec(idx_t, counts_t, nmax: int, *, M: int, K: int, N: int,
                 bm: int, tile: int, dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of the dx backward: the transposed plan steers
    ``g @ wᵀ`` over live N tiles of each K-row."""
    idx_t = np.asarray(idx_t, np.int32)
    counts_t = np.asarray(counts_t, np.int32)
    bk = bn = tile
    return KernelSpec(
        name="bsmm_dx",
        grid=(M // bm, K // bk, nmax),
        dims=("parallel", "parallel", "arbitrary"),
        inputs=(
            BlockMap("g", (bm, bn),
                     lambda i, k, t, cnt, idx: (i, idx[k, t]),
                     (M, N), dtype, gather=True),
            BlockMap("w", (bk, bn),
                     lambda i, k, t, cnt, idx: (k, idx[k, t]),
                     (K, N), dtype, gather=True),
        ),
        outputs=(BlockMap("dx", (bm, bk),
                          lambda i, k, t, cnt, idx: (i, k),
                          (M, K), dtype),),
        scratch=(ScratchSpec((bm, bk), jnp.float32, "accumulator"),),
        scalars=(counts_t, idx_t),
        guard=lambda i, k, t, cnt, idx: bool(t < cnt[k]),
        cell_flops=2.0 * bm * bk * bn,
        notes="transposed plan: live N-tile accumulation per K-row",
    )


def _bsmm_dx(g, w, plan: TilePlan, *, bm: int):
    """g (M, N) @ (w ⊙ bitmap)ᵀ → (M, K), skipping dead N tiles.

    The grid's last dimension is ``nmax`` = max live N-tiles per K-row
    (the transposed analogue of the forward ``kmax``), so backward
    input-grad compute scales with live tiles exactly like the forward.
    """
    M, N = g.shape
    K = w.shape[0]
    spec = bsmm_dx_spec(plan.idx_t, plan.counts_t, plan.nmax, M=M, K=K,
                        N=N, bm=bm, tile=plan.tile, dtype=g.dtype)
    kernel = pl.pallas_call(
        _bsmm_dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=spec.num_scalar_prefetch,
            grid=spec.grid,
            in_specs=spec.pallas_in_specs(),
            out_specs=spec.pallas_out_specs()[0],
            scratch_shapes=spec.pallas_scratch(),
        ),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=plan.interpret,
    )
    return kernel(jnp.asarray(plan.counts_t), jnp.asarray(plan.idx_t), g, w)


def _bsmm_dw_kernel(kk_ref, nn_ref, x_ref, g_ref, o_ref, acc_ref):
    """dw tile l = Σ_m x[m, kk[l]]ᵀ @ g[m, nn[l]] — live tiles only."""
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(m == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def bsmm_dw_spec(kk, nn, *, M: int, K: int, N: int, bm: int, tile: int,
                 dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of the dw backward: grid (L, M/bm) over the flat
    live-tile coordinates — no guard, every cell is live by
    construction (dead tiles are never in ``kk``/``nn``)."""
    kk = np.asarray(kk, np.int32)
    nn = np.asarray(nn, np.int32)
    bk = bn = tile
    L = int(kk.shape[0])
    return KernelSpec(
        name="bsmm_dw",
        grid=(L, M // bm),
        dims=("parallel", "arbitrary"),
        inputs=(
            BlockMap("x", (bm, bk),
                     lambda l, m, kk, nn: (m, kk[l]),
                     (M, K), dtype, gather=True),
            BlockMap("g", (bm, bn),
                     lambda l, m, kk, nn: (m, nn[l]),
                     (M, N), dtype, gather=True),
        ),
        outputs=(BlockMap("dw_tiles", (1, bk, bn),
                          lambda l, m, kk, nn: (l, 0, 0),
                          (L, bk, bn), dtype),),
        scratch=(ScratchSpec((bk, bn), jnp.float32, "accumulator"),),
        scalars=(kk, nn),
        guard=None,
        cell_flops=2.0 * bm * bk * bn,
        notes="live (bk, bn) grad tiles only; scattered to dense after",
    )


def _bsmm_dw(x2, g, plan: TilePlan, *, bm: int, out_dtype):
    """xᵀ (K, M) @ g (M, N) → (K, N), materialising ONLY live tiles.

    The grid is (L, M/bm) with L = live-tile count: dead tiles are never
    DMA'd and never issued to the MXU (their grads are identically zero
    under a static mask).  The compacted (L, bk, bn) tile stack is then
    scattered into the dense (K, N) grad — live-tile bandwidth only.
    """
    M, K = x2.shape
    N = g.shape[1]
    bk = bn = plan.tile
    Kt, Nt = K // bk, N // bn
    L = int(plan.kk.shape[0])
    if L == 0:
        return jnp.zeros((K, N), out_dtype)
    spec = bsmm_dw_spec(plan.kk, plan.nn, M=M, K=K, N=N, bm=bm,
                        tile=plan.tile, dtype=out_dtype)
    kernel = pl.pallas_call(
        _bsmm_dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=spec.num_scalar_prefetch,
            grid=spec.grid,
            in_specs=spec.pallas_in_specs(),
            out_specs=spec.pallas_out_specs()[0],
            scratch_shapes=spec.pallas_scratch(),
        ),
        out_shape=jax.ShapeDtypeStruct((L, bk, bn), out_dtype),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=plan.interpret,
    )
    tiles = kernel(jnp.asarray(plan.kk), jnp.asarray(plan.nn), x2, g)
    dw = jnp.zeros((Kt, Nt, bk, bn), out_dtype)
    dw = dw.at[jnp.asarray(plan.kk), jnp.asarray(plan.nn)].set(tiles)
    return dw.transpose(0, 2, 1, 3).reshape(K, N)


def bsmm_apply(x2, w, plan: TilePlan, *, bm: int, bias=None,
               act: Optional[str] = None):
    """Differentiable ``x2 (M, K) @ (w ⊙ tile-bitmap) (K, N)``.

    Forward AND both backward matmuls run through block-sparse Pallas
    kernels, so a retrain step's cost scales with the live-tile count in
    every pass — the paper's "pruning makes training faster" claim on
    the MXU.  The VJP is exact for the tile-masked product: ``dw`` is
    zero on dead tiles (never computed); callers that also carry an
    elementwise mask (``ops.sparse_dense``) recover the elementwise
    gradient through the chain rule of ``w * mask``.

    ``bias``/``act`` fuse a ``+ b`` / activation epilogue into the
    kernel flush (one pass over the output instead of two).  The
    backward recomputes the pre-activation block-sparsely — nothing
    dense sneaks in — and returns ``db = dz.sum(0)`` alongside dx/dw.
    """
    if plan.idx_t is None or plan.kk is None:
        raise ValueError("TilePlan lacks backward metadata — rebuild it "
                         "with make_tile_plan()")

    if bias is None and act is None:
        @jax.custom_vjp
        def f(x2, w):
            return _bsmm_compact(x2, w, plan.idx, plan.counts, plan.kmax,
                                 bm=bm, bk=plan.tile, bn=plan.tile,
                                 interpret=plan.interpret)

        def f_fwd(x2, w):
            return f(x2, w), (x2, w)

        def f_bwd(res, g):
            x2, w = res
            dx = _bsmm_dx(g, w, plan, bm=bm).astype(x2.dtype)
            dw = _bsmm_dw(x2, g, plan, bm=bm, out_dtype=w.dtype)
            return dx, dw

        f.defvjp(f_fwd, f_bwd)
        return f(x2, w)

    if act is not None and act not in _EPILOGUE_ACTS:
        raise ValueError(f"unsupported epilogue act {act!r}; "
                         f"known: {sorted(_EPILOGUE_ACTS)}")
    N = plan.counts.shape[0] * plan.tile
    b = jnp.zeros((N,), x2.dtype) if bias is None \
        else jnp.asarray(bias).reshape(N)

    def _compact(x2, w, b, a):
        return _bsmm_compact(x2, w, plan.idx, plan.counts, plan.kmax,
                             bm=bm, bk=plan.tile, bn=plan.tile,
                             interpret=plan.interpret, bias=b, act=a)

    @jax.custom_vjp
    def f(x2, w, b):
        return _compact(x2, w, b, act)

    def f_fwd(x2, w, b):
        return f(x2, w, b), (x2, w, b)

    def f_bwd(res, g):
        x2, w, b = res
        if act is None:
            dz = g
        else:
            # recompute the pre-activation block-sparsely, then pull the
            # cotangent through the activation alone
            z = _compact(x2, w, b, None)
            dz = jax.vjp(_EPILOGUE_ACTS[act], z)[1](g)[0]
        dx = _bsmm_dx(dz, w, plan, bm=bm).astype(x2.dtype)
        dw = _bsmm_dw(x2, dz, plan, bm=bm, out_dtype=w.dtype)
        db = dz.sum(0).astype(b.dtype)
        return dx, dw, db

    f.defvjp(f_fwd, f_bwd)
    return f(x2, w, b)


def plan_matmul(x, w, plan: Optional[TilePlan], bias=None,
                act: Optional[str] = None):
    """x (..., K) @ w (K, N) routed through the block-sparse kernel.

    ``plan=None`` is the dense path.  Rows are zero-padded up to a
    sublane multiple (decode batches are tiny: a handful of slots;
    retrain microbatches are ragged), so compute/bandwidth still scales
    with the live-tile count along K — the dimension pruning actually
    thins.  Differentiable: gradients flow through the custom-VJP
    block-sparse backward kernels (``bsmm_apply``).

    ``bias``/``act`` fuse the bias-add and activation into the kernel's
    flush (``bsmm_apply`` epilogue); the dense fallback applies them
    unfused for bit-compatible semantics.
    """
    if plan is None:
        out = x @ w
        if bias is not None:
            out = out + bias
        return _epilogue(out, act)
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    # a stale or mis-routed plan would otherwise fail far downstream as
    # an opaque Pallas grid/index error — name the disagreement here
    planK = plan.counts_t.shape[0] * plan.tile \
        if plan.counts_t is not None else None
    planN = plan.counts.shape[0] * plan.tile
    if w.shape[-2] != K:
        raise GeometryError("x/w contraction dims disagree",
                            shape=(K, w.shape[-2]), where="plan_matmul")
    if N != planN or (planK is not None and K != planK):
        raise GeometryError(
            f"TilePlan covers ({planK}, {planN}) but the weight is "
            f"({K}, {N}) — plan built from different masks?",
            shape=(K, N), tile=plan.tile, where="plan_matmul")
    M = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(M, K)
    # pad M to a multiple of 8 (f32 sublane); large M tiles at 128
    mp = -M % 8
    Mp = M + mp
    if Mp >= plan.tile:
        mp += -Mp % plan.tile
        Mp = M + mp
        bm = plan.tile
    else:
        bm = Mp
    if mp:
        x2 = jnp.pad(x2, ((0, mp), (0, 0)))
    # padded rows come out as act(bias) garbage; they are sliced off below
    out = bsmm_apply(x2, w, plan, bm=bm, bias=bias, act=act)
    if mp:
        out = out[:M]
    return out.reshape(*lead, N)


def _masked_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref):
    """Dense-grid variant: every tile DMA'd, dead tiles skip the MXU.

    This models LTP's crossbar-UNAWARE sparsity on TPU: bytes still
    move (no bandwidth saved) even when compute is skipped — the
    kernel-level version of the paper's Fig. 2 argument.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.any(m_ref[...] != 0))
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...],
                                w_ref[...] * m_ref[...].astype(w_ref.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def masked_matmul_spec(*, M: int, K: int, N: int, bm: int, bk: int,
                       bn: int, dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of the dense-grid masked matmul.  The MXU skip
    is data-dependent (``jnp.any(mask block)``) so the spec carries no
    host guard — every block is DMA'd, which is exactly the LTP
    crossbar-unaware point this kernel exists to demonstrate."""
    return KernelSpec(
        name="masked_matmul",
        grid=(M // bm, N // bn, K // bk),
        dims=("parallel", "parallel", "arbitrary"),
        inputs=(
            BlockMap("x", (bm, bk), lambda i, j, k: (i, k),
                     (M, K), dtype),
            BlockMap("w", (bk, bn), lambda i, j, k: (k, j),
                     (K, N), dtype),
            BlockMap("mask", (bk, bn), lambda i, j, k: (k, j),
                     (K, N), dtype),
        ),
        outputs=(BlockMap("out", (bm, bn), lambda i, j, k: (i, j),
                          (M, N), dtype),),
        scratch=(ScratchSpec((bm, bn), jnp.float32, "accumulator"),),
        guard=None,
        cell_flops=2.0 * bm * bk * bn,
        notes="dense grid; MXU skip is data-dependent, DMA never skips",
    )


def masked_matmul_pallas(x, w, mask, *, bm: int = MXU_TILE,
                         bk: int = MXU_TILE, bn: int = MXU_TILE,
                         interpret: bool = True):
    """Elementwise-masked matmul with per-tile MXU skip (no DMA skip)."""
    M, K = x.shape
    _, N = w.shape
    if M % bm or K % bk or N % bn:
        raise GeometryError(f"shapes must tile {(bm, bk, bn)}",
                            shape=(M, K, N), where="masked_matmul_pallas")
    spec = masked_matmul_spec(M=M, K=K, N=N, bm=bm, bk=bk, bn=bn,
                              dtype=x.dtype)
    kernel = pl.pallas_call(
        _masked_kernel,
        grid=spec.grid,
        in_specs=spec.pallas_in_specs(),
        out_specs=spec.pallas_out_specs()[0],
        scratch_shapes=spec.pallas_scratch(),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=interpret,
    )
    return kernel(x, w, mask)
