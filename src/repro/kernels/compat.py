"""Pallas version compatibility shims.

The kernels target the current Pallas TPU API; older jax releases ship
the same classes under legacy names (``TPUCompilerParams`` →
``CompilerParams`` rename).  Resolve once here so every kernel module
works across the supported jax range without scattering getattr calls.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")
