"""Declarative Pallas kernel specs — the single source the kernels
build their ``pallas_call`` from AND the static auditor verifies.

Every kernel in this package describes its launch as a ``KernelSpec``:
the grid, the dimension semantics, the scalar-prefetch operands, one
``BlockMap`` per input/output (block shape + the *actual* index-map
callable + the full operand shape/dtype), the VMEM scratch, and a host
mirror of the ``pl.when`` work gate.  The kernel then constructs its
real ``pl.BlockSpec``/scratch list *from the spec* (``pallas_in_specs``
etc.), so the object ``analysis.kernel_audit`` enumerates is byte-for-
byte the object the accelerator executes — there is no second copy of
the index maps to drift.

Index maps are ordinary lambdas over ``(grid ids..., scalar
operands...)``.  Pallas calls them with scalar *refs* during tracing;
the auditor calls them with the concrete numpy scalar operands stored
in ``spec.scalars`` — same code path, two evaluation modes.

This module is deliberately numpy-only at import time (jax/pallas are
imported lazily inside the builder methods) so the analysis layer can
reason about specs without touching device state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import numpy as np

#: scratch roles the auditor knows; accumulator-like roles must be f32
ACCUMULATOR_ROLES = ("accumulator", "softmax_state")


@dataclass(frozen=True)
class BlockMap:
    """One operand's blocking: ``index_map(*grid_ids, *scalars)`` returns
    the block-unit coordinates of the block a grid cell touches."""
    name: str
    block: Tuple[int, ...]          # block shape (elements)
    index_map: Callable[..., Tuple[Any, ...]]
    shape: Tuple[int, ...]          # full operand shape
    dtype: Any                      # anything np.dtype() accepts
    gather: bool = False            # index map reads scalar-prefetch data

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    @property
    def block_bytes(self) -> int:
        return int(np.prod(self.block)) * self.itemsize

    def tile_grid(self) -> Tuple[int, ...]:
        """Operand extent in block units (requires even tiling)."""
        return tuple(s // b for s, b in zip(self.shape, self.block))


@dataclass(frozen=True)
class ScratchSpec:
    """One VMEM scratch buffer and its audit role."""
    shape: Tuple[int, ...]
    dtype: Any
    role: str = "accumulator"       # accumulator | softmax_state | other

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * int(np.dtype(self.dtype).itemsize)


@dataclass(frozen=True)
class KernelSpec:
    """The full launch geometry of one Pallas kernel."""
    name: str
    grid: Tuple[int, ...]
    dims: Tuple[str, ...]           # dimension_semantics per grid axis
    inputs: Tuple[BlockMap, ...]
    outputs: Tuple[BlockMap, ...]
    scratch: Tuple[ScratchSpec, ...] = ()
    # concrete scalar-prefetch operands, in kernel argument order
    scalars: Tuple[np.ndarray, ...] = ()
    # host mirror of the pl.when work gate: guard(*ids, *scalars) -> bool;
    # None means every grid cell does work
    guard: Optional[Callable[..., bool]] = None
    # MXU flops one unguarded grid cell issues (0 = not modelled)
    cell_flops: float = 0.0
    notes: str = field(default="", compare=False)

    # -- builders: the kernels construct their pallas_call from these ----
    def pallas_in_specs(self):
        from jax.experimental import pallas as pl
        return [pl.BlockSpec(bm.block, bm.index_map) for bm in self.inputs]

    def pallas_out_specs(self):
        from jax.experimental import pallas as pl
        return [pl.BlockSpec(bm.block, bm.index_map) for bm in self.outputs]

    def pallas_scratch(self):
        from jax.experimental.pallas import tpu as pltpu
        return [pltpu.VMEM(s.shape, s.dtype) for s in self.scratch]

    @property
    def num_scalar_prefetch(self) -> int:
        return len(self.scalars)

    # -- audit-facing geometry ------------------------------------------
    def parallel_axes(self) -> Tuple[int, ...]:
        return tuple(d for d, s in enumerate(self.dims) if s == "parallel")

    def vmem_breakdown(self) -> dict:
        """Estimated VMEM residency at the planned block shapes.

        Block operands are double-buffered (Pallas pipelines the next
        block's DMA behind the current compute), scratch is single:
        ``2·Σ in + 2·Σ out + Σ scratch`` bytes.
        """
        ins = sum(bm.block_bytes for bm in self.inputs)
        outs = sum(bm.block_bytes for bm in self.outputs)
        scr = sum(s.nbytes for s in self.scratch)
        return {"inputs": 2 * ins, "outputs": 2 * outs, "scratch": scr,
                "total": 2 * ins + 2 * outs + scr}

    def vmem_bytes(self) -> int:
        return self.vmem_breakdown()["total"]


# registry of spec builders, filled by the kernel modules at import time
# (name -> zero-arg callable returning a representative KernelSpec is NOT
# what we store — audit cases need concrete shapes, so kernel_audit owns
# the canonical cases; this registry just names the audited kernels)
AUDITED_KERNELS = (
    "bsmm_fwd", "bsmm_fwd_epilogue", "bsmm_dx", "bsmm_dw",
    "paged_attention_gqa", "paged_attention_mla",
    "flash_attention", "masked_matmul", "tile_stats",
)
