"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.base import MXU_TILE


def expand_tile_mask(tile_mask, bk: int, bn: int, K: int, N: int):
    """(K/bk, N/bn) {0,1} → (K, N) elementwise mask."""
    m = jnp.repeat(jnp.repeat(tile_mask, bk, axis=0), bn, axis=1)
    return m[:K, :N]


def bsmm_ref(x, w, tile_mask, bk: int = MXU_TILE, bn: int = MXU_TILE):
    """Block-sparse matmul oracle: x @ (w ⊙ expand(tile_mask)).

    x: (M, K); w: (K, N); tile_mask: (ceil(K/bk), ceil(N/bn)).
    """
    K, N = w.shape
    m = expand_tile_mask(jnp.asarray(tile_mask, w.dtype), bk, bn, K, N)
    return jnp.dot(x, w * m, preferred_element_type=jnp.float32).astype(x.dtype)


def tile_stats_ref(w, bk: int = MXU_TILE, bn: int = MXU_TILE):
    """Per 128×128 tile: (any-nonzero, sum|w|) — oracle for tile_stats.

    w: (K, N) → (nt_k, nt_n) bool liveness + (nt_k, nt_n) f32 |w| sums.
    """
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    nt_k, nt_n = wp.shape[0] // bk, wp.shape[1] // bn
    tiles = wp.reshape(nt_k, bk, nt_n, bn)
    sums = jnp.sum(jnp.abs(tiles.astype(jnp.float32)), axis=(1, 3))
    live = jnp.any(tiles != 0, axis=(1, 3))
    return live, sums


def masked_matmul_ref(x, w, mask):
    """Elementwise-masked matmul oracle (for the dense-grid variant)."""
    return jnp.dot(x, w * mask.astype(w.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
