"""Flash attention Pallas TPU kernel (causal, GQA-aware).

Online-softmax attention with VMEM-resident running (max, denom, acc)
scratch; grid (B, Hq, q_blocks, k_blocks) with the k dimension
'arbitrary' so the scratch accumulates across k steps.  GQA is handled
in the BlockSpec index map (kv head = q head // group) — grouped keys
are never materialised.  Fully-masked causal blocks are skipped with
``pl.when`` (≈2× fewer MXU passes at long seq).

Used by the 32k-prefill cells on real TPU; validated in interpret mode
against the pure-jnp oracle (`ref.flash_attention_ref` ==
`models.attention.causal_attention` math).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MXU_TILE
from repro.kernels.compat import CompilerParams
from repro.kernels.spec import BlockMap, KernelSpec, ScratchSpec

NEG_INF = -1e30


def flash_attention_spec(*, B: int, S: int, Hq: int, Hkv: int, hd: int,
                         bq: int = MXU_TILE, bk: int = MXU_TILE,
                         causal: bool = True,
                         dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of the flash kernel over the (B, H, S, hd)
    layout: GQA via the ``h // G`` kv index map, causal block skip as
    the host guard."""
    G = Hq // Hkv

    def kv_map(b, h, i, j):
        return (b, h // G, j, 0)

    return KernelSpec(
        name="flash_attention",
        grid=(B, Hq, S // bq, S // bk),
        dims=("parallel", "parallel", "parallel", "arbitrary"),
        inputs=(
            BlockMap("q", (1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0),
                     (B, Hq, S, hd), dtype),
            BlockMap("k", (1, 1, bk, hd), kv_map,
                     (B, Hkv, S, hd), dtype),
            BlockMap("v", (1, 1, bk, hd), kv_map,
                     (B, Hkv, S, hd), dtype),
        ),
        outputs=(BlockMap("out", (1, 1, bq, hd),
                          lambda b, h, i, j: (b, h, i, 0),
                          (B, Hq, S, hd), dtype),),
        scratch=(ScratchSpec((bq, hd), jnp.float32, "accumulator"),
                 ScratchSpec((bq, 1), jnp.float32, "softmax_state"),
                 ScratchSpec((bq, 1), jnp.float32, "softmax_state")),
        guard=(lambda b, h, i, j: bool(j * bk <= i * bq + bq - 1))
        if causal else None,
        cell_flops=4.0 * bq * bk * hd,
        notes="causal fully-masked (i, j) blocks skipped via pl.when",
    )


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # k block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (j * bk <= i * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    bq: int = MXU_TILE, bk: int = MXU_TILE,
                    interpret: bool = True):
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd) → (B, S, Hq, hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(hd)
    # layout: (B, H, S, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    spec = flash_attention_spec(B=B, S=S, Hq=Hq, Hkv=Hkv, hd=hd, bq=bq,
                                bk=bk, causal=causal, dtype=q.dtype)
    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk),
        grid=spec.grid,
        in_specs=spec.pallas_in_specs(),
        out_specs=spec.pallas_out_specs()[0],
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, hd), q.dtype),
        scratch_shapes=spec.pallas_scratch(),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=interpret,
    )
    out = kernel(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
