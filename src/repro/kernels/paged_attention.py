"""Paged decode attention Pallas TPU kernel — live-*block* KV reads.

The serving analogue of the paper's turned-off crossbar: just as
``bsmm`` makes weight traffic scale with live 128×128 tiles, this
kernel makes decode KV traffic scale with *live context* instead of
allocated capacity.  The KV cache is a shared pool of fixed-size blocks
(``MXU_TILE`` tokens each); every sequence owns an indirection row — a
*block table* — listing the physical blocks that hold its context in
logical order.  The kernel walks the table with scalar-prefetched index
maps, so the DMA engine only ever touches blocks the sequence actually
filled:

    grid = (B, NB)                          NB = table width
    q block   (1, Hq, hd)      at (b, 0, 0)
    kv block  (1, T, Hkv, hd)  at (table[b, j], 0, 0, 0)
    out block (1, Hq, dv)      at (b, 0, 0)

Scores/out are fused per block with a streaming (flash) softmax held in
f32 VMEM scratch; blocks past a sequence's live length are masked with
``pl.when`` (their index-map entry points at the scratch block, so the
revolving-window DMA re-reads one already-resident block instead of
streaming dead capacity — the same argument ``bsmm`` makes for dead
K-tiles).

Grouped-query attention is computed per block in grouped form
(``(Hkv, G, hd)`` queries against ``(Hkv, T, hd)`` keys), matching
``models.attention.attend``'s head grouping.  The MLA absorbed form
rides the same kernel: pass ``v_pool=None`` and ``v_dim=r`` and values
are the first ``r`` lanes of the key block (the latent cache stores
``concat(c_kv, k_rope)``), halving MLA pool reads as a bonus.

``paged_attention_ref`` is the exact dense-oracle path: gather the
table rows into a dense cache and run single-pass masked softmax —
the same math ``attend`` does, for oracle tests and debugging.
Conventions mirror ``bsmm``: ``interpret=None`` auto-enables interpret
mode everywhere except a real TPU backend.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MXU_TILE
from repro.kernels.bsmm import GeometryError, default_interpret
from repro.kernels.compat import CompilerParams
from repro.kernels.spec import BlockMap, KernelSpec, ScratchSpec

#: tokens per KV block — one MXU tile edge, like the bsmm tile
BLOCK_TOKENS = MXU_TILE

_NEG = -1e30    # finite mask value (matches models.attention.attend)


class PagedGeometry(NamedTuple):
    """Validated shapes for one paged-attention call."""
    B: int
    Hq: int
    hd: int
    Hkv: int
    T: int          # tokens per block
    NB: int         # table width (logical blocks per sequence)
    P: int          # physical blocks in the pool
    dv: int         # value head dim


def _check_geometry(q, k_pool, v_pool, tables, lengths,
                    v_dim: Optional[int]) -> PagedGeometry:
    if q.ndim != 3:
        raise GeometryError("q must be (B, Hq, hd)", shape=q.shape,
                            where="paged_attention")
    if k_pool.ndim != 4:
        raise GeometryError("k_pool must be (P, T, Hkv, hd)",
                            shape=k_pool.shape, where="paged_attention")
    B, Hq, hd = q.shape
    P, T, Hkv, hdk = k_pool.shape
    if hdk != hd:
        raise GeometryError("q/k head dims disagree", shape=(hd, hdk),
                            where="paged_attention")
    if Hq % Hkv:
        raise GeometryError(f"Hq={Hq} not a multiple of Hkv={Hkv}",
                            where="paged_attention")
    if tables.ndim != 2 or tables.shape[0] != B:
        raise GeometryError("tables must be (B, NB)", shape=tables.shape,
                            where="paged_attention")
    if lengths.shape != (B,):
        raise GeometryError("lengths must be (B,)", shape=lengths.shape,
                            where="paged_attention")
    if v_pool is None:
        if v_dim is None or not (0 < v_dim <= hd):
            raise GeometryError(
                f"v_pool=None needs 0 < v_dim <= hd, got v_dim={v_dim}",
                shape=(hd,), where="paged_attention")
        dv = v_dim
    else:
        if v_pool.shape[:3] != (P, T, Hkv):
            raise GeometryError("k_pool/v_pool pools disagree",
                                shape=v_pool.shape, where="paged_attention")
        dv = v_pool.shape[3]
    return PagedGeometry(B=B, Hq=Hq, hd=hd, Hkv=Hkv, T=T,
                         NB=tables.shape[1], P=P, dv=dv)


def _block_scores(q, k, scale):
    """q (Hq, hd) × k (T, Hkv, hd) → grouped scores (Hq, T) f32."""
    Hq, hd = q.shape
    T, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(Hkv, G, hd)
    kt = k.transpose(1, 0, 2)                       # (Hkv, T, hd)
    s = jax.lax.dot_general(qg, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return s.reshape(Hq, T) * scale                 # (Hkv, G, T) → (Hq, T)


def _block_out(p, v):
    """p (Hq, T) × v (T, Hkv, dv) → (Hq, dv) f32 (grouped)."""
    Hq, T = p.shape
    Hkv, dv = v.shape[1], v.shape[2]
    G = Hq // Hkv
    pg = p.reshape(Hkv, G, T)
    vt = v.transpose(1, 0, 2)                       # (Hkv, T, dv)
    o = jax.lax.dot_general(pg, vt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return o.reshape(Hq, dv)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, v_dim, T):
    """One (sequence b, logical block j) grid cell; v_dim selects the
    fused MLA form (values = first v_dim key lanes)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = len_ref[b]

    @pl.when(j * T < n)
    def _accum():
        q = q_ref[0].astype(jnp.float32)            # (Hq, hd)
        k = k_ref[0].astype(jnp.float32)            # (T, Hkv, hd)
        s = _block_scores(q, k, scale)              # (Hq, T)
        tpos = j * T + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < n, s, _NEG)
        m_prev = m_ref[:, :1]                       # (Hq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                      # (Hq, T)
        corr = jnp.exp(m_prev - m_new)              # (Hq, 1)
        v = k[..., :v_dim]                          # fused MLA values
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = (l_ref[...] * corr
                      + jnp.broadcast_to(p.sum(-1, keepdims=True),
                                         l_ref.shape))
        acc_ref[...] = acc_ref[...] * corr + _block_out(p, v)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


def _paged_kernel_kv(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                     acc_ref, m_ref, l_ref, *, scale, T):
    """Separate-V variant (GQA): same streaming softmax, v from its own
    pool block."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = len_ref[b]

    @pl.when(j * T < n)
    def _accum():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = _block_scores(q, k, scale)
        tpos = j * T + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < n, s, _NEG)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = (l_ref[...] * corr
                      + jnp.broadcast_to(p.sum(-1, keepdims=True),
                                         l_ref.shape))
        acc_ref[...] = acc_ref[...] * corr + _block_out(p, v)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[:, :1]).astype(o_ref.dtype)


def paged_attention_spec(geo: PagedGeometry, tables, lengths, *,
                         fused_v: bool,
                         dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of one paged-attention call: the block-table
    gather in the kv index map, the ``j*T < len`` liveness guard, and
    the f32 streaming-softmax scratch — exactly what the pallas_call
    below executes."""
    # tables/lengths may be tracers (the jitted decode path); keep them
    # as-is — the auditor builds its specs from concrete numpy arrays
    if isinstance(tables, np.ndarray):
        tables = np.asarray(tables, np.int32)
    if isinstance(lengths, np.ndarray):
        lengths = np.asarray(lengths, np.int32)
    T = geo.T
    inputs = [
        BlockMap("q", (1, geo.Hq, geo.hd),
                 lambda b, j, tbl, ln: (b, 0, 0),
                 (geo.B, geo.Hq, geo.hd), dtype),
        BlockMap("k_pool", (1, T, geo.Hkv, geo.hd),
                 lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0),
                 (geo.P, T, geo.Hkv, geo.hd), dtype, gather=True),
    ]
    if not fused_v:
        inputs.append(
            BlockMap("v_pool", (1, T, geo.Hkv, geo.dv),
                     lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0),
                     (geo.P, T, geo.Hkv, geo.dv), dtype, gather=True))
    return KernelSpec(
        name="paged_attention_mla" if fused_v else "paged_attention_gqa",
        grid=(geo.B, geo.NB),
        dims=("parallel", "arbitrary"),
        inputs=tuple(inputs),
        outputs=(BlockMap("out", (1, geo.Hq, geo.dv),
                          lambda b, j, tbl, ln: (b, 0, 0),
                          (geo.B, geo.Hq, geo.dv), dtype),),
        scratch=(ScratchSpec((geo.Hq, geo.dv), jnp.float32,
                             "accumulator"),
                 ScratchSpec((geo.Hq, T), jnp.float32, "softmax_state"),
                 ScratchSpec((geo.Hq, T), jnp.float32, "softmax_state")),
        scalars=(tables, lengths),
        guard=lambda b, j, tbl, ln: bool(j * T < ln[b]),
        cell_flops=2.0 * geo.Hq * T * geo.hd + 2.0 * geo.Hq * T * geo.dv,
        notes="block-table gather; dead entries must point at a valid "
              "pool block (the engine's scratch block 0)",
    )


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    scale: float, v_dim: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Paged decode attention over a block pool.

    q:        (B, Hq, hd) — one query per sequence (decode step)
    k_pool:   (P, T, Hkv, hd) — the shared physical block pool
    v_pool:   like ``k_pool`` (separate dv allowed), or None with
              ``v_dim=r`` for the fused MLA form (v = k[..., :r])
    tables:   (B, NB) int32 — physical block id per logical block.
              Entries past a sequence's live blocks must still be valid
              pool ids (the engine points them at its scratch block).
    lengths:  (B,) int32 — live context length per sequence, **including
              the just-appended token**; must be >= 1 (an all-masked row
              would divide by a zero softmax denominator).

    Returns (B, Hq, dv) in q's dtype.  Exact (streaming softmax in f32);
    the per-block masked softmax matches ``attend``'s ``-1e30`` finite
    masking.  ``interpret=None`` auto-enables interpret mode off-TPU,
    mirroring ``bsmm``.
    """
    geo = _check_geometry(q, k_pool, v_pool, tables, lengths, v_dim)
    if interpret is None:
        interpret = default_interpret()
    fused = v_pool is None
    spec = paged_attention_spec(geo, tables, lengths, fused_v=fused,
                                dtype=q.dtype)
    tables = jnp.asarray(tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    body = functools.partial(_paged_kernel, scale=scale, v_dim=geo.dv,
                             T=geo.T) if fused \
        else functools.partial(_paged_kernel_kv, scale=scale, T=geo.T)
    kernel = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=spec.num_scalar_prefetch,
            grid=spec.grid,
            in_specs=spec.pallas_in_specs(),
            out_specs=spec.pallas_out_specs()[0],
            scratch_shapes=spec.pallas_scratch()),
        out_shape=jax.ShapeDtypeStruct((geo.B, geo.Hq, geo.dv), q.dtype),
        compiler_params=CompilerParams(dimension_semantics=spec.dims),
        interpret=interpret,
    )
    if fused:
        return kernel(tables, lengths, q, k_pool)
    return kernel(tables, lengths, q, k_pool, v_pool)


def paged_gather(pool, tables):
    """Gather table rows into a dense per-sequence cache.

    pool (P, T, ...) × tables (B, NB) → (B, NB*T, ...) — logical token
    order.  The oracle view of the paged state: position ``t`` of
    sequence ``b`` lives at ``pool[tables[b, t // T], t % T]``.
    """
    B, NB = tables.shape
    T = pool.shape[1]
    dense = jnp.asarray(pool)[jnp.asarray(tables, jnp.int32)]
    return dense.reshape(B, NB * T, *pool.shape[2:])


def paged_attention_ref(q, k_pool, v_pool, tables, lengths, *,
                        scale: float, v_dim: Optional[int] = None):
    """Exact dense-oracle path: gather blocks, single-pass masked
    softmax — the same grouped math ``models.attention.attend`` uses."""
    geo = _check_geometry(q, k_pool, v_pool, tables, lengths, v_dim)
    k = paged_gather(k_pool, tables)                 # (B, L, Hkv, hd)
    if v_pool is None:
        v = k[..., :geo.dv]
    else:
        v = paged_gather(v_pool, tables)
    B, L = k.shape[0], k.shape[1]
    G = geo.Hq // geo.Hkv
    qg = q.reshape(B, geo.Hkv, G, geo.hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(L)[None] < jnp.asarray(lengths)[:, None]   # (B, L)
    s = jnp.where(valid[:, None, None], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(B, geo.Hq, geo.dv).astype(q.dtype)
