"""Per-tile statistics Pallas kernel.

Computes, for every 128×128 weight tile, (liveness, Σ|w|) in one pass —
the device-side version of ``core.crossbar.xbar_stats`` used when masks
must be derived on-accelerator (e.g. re-deriving the bsmm tile bitmap
after a checkpoint restore without a host round-trip).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import MXU_TILE
from repro.kernels.spec import BlockMap, KernelSpec


def tile_stats_spec(*, K: int, N: int, bk: int = MXU_TILE,
                    bn: int = MXU_TILE,
                    dtype=jnp.float32) -> KernelSpec:
    """Launch geometry of the per-tile stats kernel: one grid cell per
    (bk, bn) weight tile, two (1, 1) outputs per cell.  VPU-only (no
    MXU), so the spec carries no flop model."""
    return KernelSpec(
        name="tile_stats",
        grid=(K // bk, N // bn),
        dims=("parallel", "parallel"),
        inputs=(BlockMap("w", (bk, bn), lambda i, j: (i, j),
                         (K, N), dtype),),
        outputs=(BlockMap("live", (1, 1), lambda i, j: (i, j),
                          (K // bk, N // bn), jnp.int32),
                 BlockMap("sums", (1, 1), lambda i, j: (i, j),
                          (K // bk, N // bn), jnp.float32)),
        guard=None,
        notes="reduction outputs, no scratch",
    )


def _tile_stats_kernel(w_ref, live_ref, sum_ref):
    blk = w_ref[...].astype(jnp.float32)
    s = jnp.sum(jnp.abs(blk))
    sum_ref[0, 0] = s
    live_ref[0, 0] = (jnp.any(blk != 0)).astype(jnp.int32)


def tile_stats_for_config(w, prune_cfg, *, interpret: bool = True):
    """Tile stats at a ``PruneConfig``'s crossbar geometry.

    The tile extents come from ``prune_cfg.xbar_rows/xbar_cols`` so the
    device-side bitmap agrees with the host-side ``xbar_stats``
    accounting for the same config; ragged edges are zero-padded.
    """
    bk, bn = int(prune_cfg.xbar_rows), int(prune_cfg.xbar_cols)
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    return tile_stats_pallas(w, bk=bk, bn=bn, interpret=interpret)


def tile_stats_pallas(w, *, bk: int = MXU_TILE, bn: int = MXU_TILE,
                      interpret: bool = True):
    """w: (K, N) → (live (Kt, Nt) int32, sums (Kt, Nt) f32)."""
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0, (w.shape, bk, bn)
    spec = tile_stats_spec(K=K, N=N, bk=bk, bn=bn, dtype=w.dtype)
    kernel = pl.pallas_call(
        _tile_stats_kernel,
        grid=spec.grid,
        in_specs=spec.pallas_in_specs(),
        out_specs=spec.pallas_out_specs(),
        out_shape=[jax.ShapeDtypeStruct((K // bk, N // bn), jnp.int32),
                   jax.ShapeDtypeStruct((K // bk, N // bn), jnp.float32)],
        interpret=interpret,
    )
    return kernel(w)
