# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Every Pallas kernel here exports a declarative KernelSpec builder
# (kernels.spec) that its pallas_call is constructed from, so
# analysis.kernel_audit can statically verify the executed launch
# geometry.  This __init__ re-exports only the numpy-only spec layer;
# the kernel modules themselves import jax and are imported directly.
from repro.kernels.spec import (AUDITED_KERNELS, BlockMap, KernelSpec,
                                ScratchSpec)

__all__ = ["AUDITED_KERNELS", "BlockMap", "KernelSpec", "ScratchSpec"]
