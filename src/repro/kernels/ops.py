"""Jitted public wrappers around the Pallas kernels.

``sparse_dense`` is the drop-in replacement for ``x @ w`` once a weight
has been ReaLPruned: it derives the static tile bitmap from the mask
(host-side, one-time) and dispatches the compacted block-sparse kernel.
Falls back to the jnp oracle for shapes that do not tile (tiny smoke
configs) and on platforms without Pallas TPU support.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs.base import MXU_TILE
from repro.kernels import ref
from repro.kernels.bsmm import (make_tile_plan, plan_matmul,
                                tile_bitmap)  # noqa: F401  (re-export)
from repro.kernels.tile_stats import tile_stats_pallas


def tile_density(mask: np.ndarray, bk: int = MXU_TILE,
                 bn: int = MXU_TILE) -> float:
    """Fraction of live tiles — the kernel's compute/bandwidth cost."""
    bm = tile_bitmap(mask, bk, bn)
    return float(bm.mean())


def sparse_dense(x, w, mask: np.ndarray, *, bk: int = MXU_TILE,
                 bn: int = MXU_TILE, interpret: bool = True):
    """x (..., K) @ pruned w (K, N) skipping dead 128×128 tiles.

    mask: host numpy elementwise {0,1} (static — pruning is offline).
    Differentiable: forward and both backward matmuls run block-sparse
    (``bsmm.bsmm_apply``); the explicit ``w * mask`` keeps the weight
    gradient elementwise-exact vs the dense masked oracle.  Ragged M
    (small retrain batches) is zero-padded to a sublane multiple inside
    ``plan_matmul``, which also picks the row blocking — only ragged
    K/N (or rectangular bk≠bn tiles) fall back to the dense oracle.
    """
    K, N = w.shape
    lead = x.shape[:-1]
    plan = (make_tile_plan(mask, tile=bk, interpret=interpret)
            if bk == bn else None)
    if plan is None:
        M = int(np.prod(lead)) if lead else 1
        out = ref.masked_matmul_ref(x.reshape(M, K), w,
                                    jnp.asarray(mask, w.dtype))
        return out.reshape(*lead, N)
    return plan_matmul(x, w * jnp.asarray(mask, w.dtype), plan)


def tile_stats(w, *, bk: int = MXU_TILE, bn: int = MXU_TILE,
               interpret: bool = True):
    """Device-side per-tile (liveness, Σ|w|); pads ragged edges."""
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    return tile_stats_pallas(w, bk=bk, bn=bn, interpret=interpret)
