"""Jitted public wrappers around the Pallas kernels.

``sparse_dense`` is the drop-in replacement for ``x @ w`` once a weight
has been ReaLPruned: it derives the static tile bitmap from the mask
(host-side, one-time) and dispatches the compacted block-sparse kernel.
Falls back to the jnp oracle for shapes that do not tile (tiny smoke
configs) and on platforms without Pallas TPU support.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bsmm import bsmm_pallas, compact_tile_indices
from repro.kernels.tile_stats import tile_stats_pallas


def tile_bitmap(mask: np.ndarray, bk: int = 128, bn: int = 128) -> np.ndarray:
    """Elementwise {0,1} mask (K, N) → tile liveness (⌈K/bk⌉, ⌈N/bn⌉)."""
    m = np.asarray(mask) != 0
    K, N = m.shape
    pk, pn = (-K) % bk, (-N) % bn
    if pk or pn:
        m = np.pad(m, ((0, pk), (0, pn)))
    return m.reshape(m.shape[0] // bk, bk, m.shape[1] // bn, bn) \
            .any(axis=(1, 3)).astype(np.int32)


def tile_density(mask: np.ndarray, bk: int = 128, bn: int = 128) -> float:
    """Fraction of live tiles — the kernel's compute/bandwidth cost."""
    bm = tile_bitmap(mask, bk, bn)
    return float(bm.mean())


def sparse_dense(x, w, mask: np.ndarray, *, bm: int = 128, bk: int = 128,
                 bn: int = 128, interpret: bool = True):
    """x (..., K) @ pruned w (K, N) skipping dead 128×128 tiles.

    mask: host numpy elementwise {0,1} (static — pruning is offline).
    """
    K, N = w.shape
    lead = x.shape[:-1]
    M = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(M, K)
    if M % bm or K % bk or N % bn:
        out = ref.masked_matmul_ref(x2, w, jnp.asarray(mask, w.dtype))
        return out.reshape(*lead, N)
    bmx = tile_bitmap(mask, bk, bn)
    out = bsmm_pallas(x2, w * jnp.asarray(mask, w.dtype), bmx,
                      bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out.reshape(*lead, N)


def tile_stats(w, *, bk: int = 128, bn: int = 128, interpret: bool = True):
    """Device-side per-tile (liveness, Σ|w|); pads ragged edges."""
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    return tile_stats_pallas(w, bk=bk, bn=bn, interpret=interpret)
