"""Learning-rate schedules (pure functions of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_epoch_decay(lr: float, decay: float = 0.95,
                            steps_per_epoch: int = 1):
    """The paper's recipe: LR decreased by 5% after every epoch."""
    def fn(step):
        epoch = step // steps_per_epoch
        return jnp.asarray(lr, jnp.float32) * (decay ** epoch)
    return fn


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_decay(lr, total_steps, final_frac)

    def fn(step):
        warm = lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return jnp.where(step < warmup_steps, warm, cos(step))
    return fn
