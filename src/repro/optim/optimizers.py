"""Optimizers as (init, update) pairs over pytrees — optax-style but
self-contained (no external deps).

``masked`` wraps any optimizer for lottery-ticket training: gradients of
pruned weights are zeroed *before* the inner update and the updated
params are re-masked *after*, so pruned weights stay exactly zero under
momentum/weight-decay and the optimizer state never accumulates for
dead coordinates.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.masks import apply_masks


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (new_params, new_state)


def _tree_zeros_like(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def sgd(lr_fn, momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    """SGD with momentum — the paper's training recipe (LR 0.1, m 0.9)."""

    def init(params):
        return {"mu": _tree_zeros_like(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr = lr_fn(state["step"])

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m + g
            step_dir = g + momentum * m_new if nesterov else m_new
            return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), \
                m_new

        flat = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params),
                "v": _tree_zeros_like(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(state["step"])
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            mh, vh = m_new / bc1, v_new / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(  # noqa: E731
            lambda t: t[i], flat, is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "step": step}

    return Optimizer(init, update)


def with_gradient_clipping(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params):
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


def masked(opt: Optimizer, masks) -> Optimizer:
    """Lottery-ticket wrapper: keep pruned coordinates exactly zero."""

    def init(params):
        return opt.init(params)

    def update(grads, state, params):
        grads = apply_masks(grads, masks)
        new_params, new_state = opt.update(grads, state, params)
        return apply_masks(new_params, masks), new_state

    return Optimizer(init, update)
