from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, masked, sgd, with_gradient_clipping,
)
from repro.optim.schedules import (  # noqa: F401
    constant, cosine_decay, exponential_epoch_decay, warmup_cosine,
)
