"""Group scoring for crossbar-aware pruning (paper §IV.B).

Granularities on the unrolled weight matrix M (R×C), crossbars xr×xc
(``TileGeometry``, default 128×128):

  * ``filter``  — one whole column (conv: one filter IC·K·K; dense: one
                  output unit).  The only granularity that also removes
                  an activation.
  * ``channel`` — conv: the K² rows of one input channel within one
                  column (paper Fig. 3c); dense: the xr-row crossbar
                  segment of one column.  Zeroing it frees a crossbar
                  column.
  * ``index``   — one row restricted to one xc-column crossbar
                  (paper Fig. 3d).  Zeroing it frees a crossbar row.

Group score = mean |w| over the group's weights (paper: "average
weight").  Pruning selects the globally lowest-scoring *alive* groups
across all layers until the requested fraction of remaining weights is
removed — the paper's "lowest p percentile by magnitude, considering
all the filters/channels/… of the CNN".

Baselines reuse the same machinery with their own group shapes:
  * ``ltp``   — every single weight is its own group (unstructured).
  * ``block`` — square b×b blocks (BLK-REW [9] adapted to crossbars).
  * ``cap``   — full xr-row crossbar column segments (CAP [7]): same
                as dense 'channel' for every layer type.

The group shapes themselves live in ``repro.core.strategies`` as a
registry of ``GranularityStrategy`` objects; this module keeps the
selection machinery (``select_global_prune``) and thin compatibility
wrappers dispatching by name.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.strategies import (  # noqa: F401  (re-exported for compat)
    DEFAULT_GEOMETRY, GranularityStrategy, GroupSet, TileGeometry,
    available_strategies, get_strategy, register_strategy,
)

GRANULARITIES = ("filter", "channel", "index")
BASELINE_GRANULARITIES = ("ltp", "block", "cap")


def group_scores(path: str, w: np.ndarray, mask: np.ndarray,
                 granularity: str, conv: bool, block: int = 32,
                 geometry: Optional[TileGeometry] = None) -> GroupSet:
    """Compute per-group scores for one leaf (dispatch by name)."""
    return get_strategy(granularity).score(
        path, w, mask, conv=conv, geom=geometry or DEFAULT_GEOMETRY,
        block=block)


def zero_groups(mask: np.ndarray, gs: GroupSet, kill: np.ndarray
                ) -> np.ndarray:
    """Return a new leaf mask with the ``kill`` groups zeroed.

    ``kill`` has the same shape as ``gs.scores`` (bool).  The zeroing
    geometry comes from ``gs.meta`` — always the one scored with.
    """
    return get_strategy(gs.granularity).zero(mask, gs, kill)


def select_global_prune(group_sets: List[GroupSet], fraction: float,
                        remaining_weights: int) -> Dict[str, np.ndarray]:
    """Pick the lowest-scoring alive groups across all leaves until
    ~``fraction`` of ``remaining_weights`` are covered.

    Returns {path: kill bool array (same shape as that leaf's scores)}.
    """
    scores, sizes, owners = [], [], []
    for gi, gs in enumerate(group_sets):
        flat_alive = gs.alive.reshape(-1)
        flat_scores = gs.scores.reshape(-1)[flat_alive]
        flat_sizes = gs.sizes.reshape(-1)[flat_alive]
        idx = np.nonzero(flat_alive)[0]
        scores.append(flat_scores)
        sizes.append(flat_sizes)
        owners.append(np.stack([np.full(idx.shape, gi), idx], axis=1))
    if not scores:
        return {}
    scores = np.concatenate(scores)
    sizes = np.concatenate(sizes)
    owners = np.concatenate(owners)
    target = fraction * remaining_weights
    order = np.argsort(scores, kind="stable")
    csum = np.cumsum(sizes[order])
    n_kill = int(np.searchsorted(csum, target) + 1)
    n_kill = min(n_kill, len(order))
    chosen = owners[order[:n_kill]]
    kills: Dict[int, List[int]] = {}
    for gi, flat_i in chosen:
        kills.setdefault(int(gi), []).append(int(flat_i))
    out = {}
    for gi, flat_list in kills.items():
        gs = group_sets[gi]
        k = np.zeros(gs.scores.size, bool)
        k[flat_list] = True
        out[gs.path] = k.reshape(gs.scores.shape)
    return out
