"""Group scoring for crossbar-aware pruning (paper §IV.B).

Granularities on the unrolled weight matrix M (R×C), crossbars 128×128:

  * ``filter``  — one whole column (conv: one filter IC·K·K; dense: one
                  output unit).  The only granularity that also removes
                  an activation.
  * ``channel`` — conv: the K² rows of one input channel within one
                  column (paper Fig. 3c); dense: the 128-row crossbar
                  segment of one column.  Zeroing it frees a crossbar
                  column.
  * ``index``   — one row restricted to one 128-column crossbar
                  (paper Fig. 3d).  Zeroing it frees a crossbar row.

Group score = mean |w| over the group's weights (paper: "average
weight").  Pruning selects the globally lowest-scoring *alive* groups
across all layers until the requested fraction of remaining weights is
removed — the paper's "lowest p percentile by magnitude, considering
all the filters/channels/… of the CNN".

Baselines reuse the same machinery with their own group shapes:
  * ``ltp``   — every single weight is its own group (unstructured).
  * ``block`` — square b×b blocks (BLK-REW [9] adapted to crossbars).
  * ``cap``   — full 128-row crossbar column segments (CAP [7]): same
                as dense 'channel' for every layer type.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.crossbar import XBAR_COLS, XBAR_ROWS, leaf_matrices

GRANULARITIES = ("filter", "channel", "index")
BASELINE_GRANULARITIES = ("ltp", "block", "cap")


@dataclass
class GroupSet:
    """Flattened groups of one leaf at one granularity.

    ``ids``    — (n_groups, …) integer array mapping each matrix entry
                 to a group id via ``group_of`` (stored implicitly; we
                 keep per-group row/col slices instead for speed).
    ``scores`` — (n_groups,) mean |w| over group entries (alive mask
                 applied by caller).
    ``sizes``  — (n_groups,) number of weights in each group.
    ``alive``  — (n_groups,) bool: group has any surviving weight.
    """
    path: str
    granularity: str
    scores: np.ndarray
    sizes: np.ndarray
    alive: np.ndarray
    # info needed to zero a group in the leaf's mask
    meta: Dict


def _group_reduce(x: np.ndarray, mask: np.ndarray, axes: Tuple[int, ...]):
    """(mean|x| over alive entries, any(mask), alive count) over ``axes``."""
    absx = np.abs(x) * mask
    cnt = mask.sum(axis=axes)
    scores = absx.sum(axis=axes) / np.maximum(cnt, 1e-9)
    return scores, mask.any(axis=axes), cnt.astype(np.int64)


def _pad_to(x: np.ndarray, r: int, c: int):
    R, C = x.shape[-2:]
    pr, pc = (-R) % r, (-C) % c
    if pr or pc:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
        x = np.pad(x, pad)
    return x


def group_scores(path: str, w: np.ndarray, mask: np.ndarray,
                 granularity: str, conv: bool,
                 block: int = 32) -> GroupSet:
    """Compute per-group scores for one leaf."""
    wm, tag = leaf_matrices(w, conv)
    mm, _ = leaf_matrices(mask, conv)
    B, R, C = wm.shape
    meta = {"tag": tag, "shape": w.shape, "conv": conv, "B": B, "R": R,
            "C": C}
    if granularity == "filter":
        scores, alive, sizes = _group_reduce(wm, mm, (1,))   # (B, C)
    elif granularity == "channel":
        if conv:
            K = w.shape[0]
            ic = w.shape[2]
            wv = wm.reshape(B, ic, K * K, C)
            mv = mm.reshape(B, ic, K * K, C)
            scores, alive, sizes = _group_reduce(wv, mv, (2,))  # (B, ic, C)
            meta["kk"] = K * K
        else:
            wp, mp = _pad_to(wm, XBAR_ROWS, 1), _pad_to(mm, XBAR_ROWS, 1)
            nt = wp.shape[1] // XBAR_ROWS
            wv = wp.reshape(B, nt, XBAR_ROWS, C)
            mv = mp.reshape(B, nt, XBAR_ROWS, C)
            scores, alive, sizes = _group_reduce(wv, mv, (2,))  # (B, nt, C)
            meta["nt"] = nt
    elif granularity == "index":
        wp, mp = _pad_to(wm, 1, XBAR_COLS), _pad_to(mm, 1, XBAR_COLS)
        nt = wp.shape[2] // XBAR_COLS
        wv = wp.reshape(B, R, nt, XBAR_COLS)
        mv = mp.reshape(B, R, nt, XBAR_COLS)
        scores, alive, sizes = _group_reduce(wv, mv, (3,))   # (B, R, nt)
        meta["nt"] = nt
    elif granularity == "ltp":
        scores = np.abs(wm) * mm
        alive = mm.astype(bool)
        sizes = np.ones_like(scores, dtype=np.int64)
    elif granularity == "block":
        wp, mp = _pad_to(wm, block, block), _pad_to(mm, block, block)
        nr, nc = wp.shape[1] // block, wp.shape[2] // block
        wv = wp.reshape(B, nr, block, nc, block)
        mv = mp.reshape(B, nr, block, nc, block)
        scores, alive, sizes = _group_reduce(wv, mv, (2, 4))  # (B, nr, nc)
        meta["nr"], meta["nc"], meta["block"] = nr, nc, block
    elif granularity == "cap":
        return group_scores(path, w, mask, "channel", conv=False)
    else:
        raise ValueError(granularity)
    return GroupSet(path, granularity, scores, sizes, alive.astype(bool),
                    meta)


def zero_groups(mask: np.ndarray, gs: GroupSet, kill: np.ndarray
                ) -> np.ndarray:
    """Return a new leaf mask with the ``kill`` groups zeroed.

    ``kill`` has the same shape as ``gs.scores`` (bool).
    """
    conv = gs.meta["conv"]
    mm, tag = leaf_matrices(mask, conv)
    mm = mm.copy()
    B, R, C = mm.shape
    g = gs.granularity
    if g == "filter":
        mm *= ~kill[:, None, :]                      # (B,1,C)
    elif g == "channel" and conv:
        kk = gs.meta["kk"]
        ic = kill.shape[1]
        mv = mm.reshape(B, ic, kk, C)
        mv *= ~kill[:, :, None, :]
        mm = mv.reshape(B, R, C)
    elif g in ("channel", "cap"):
        nt = gs.meta["nt"]
        mp = _pad_to(mm, XBAR_ROWS, 1)
        mv = mp.reshape(B, nt, XBAR_ROWS, C)
        mv *= ~kill[:, :, None, :]
        mm = mv.reshape(B, nt * XBAR_ROWS, C)[:, :R, :]
    elif g == "index":
        nt = gs.meta["nt"]
        mp = _pad_to(mm, 1, XBAR_COLS)
        mv = mp.reshape(B, R, nt, XBAR_COLS)
        mv *= ~kill[:, :, :, None]
        mm = mv.reshape(B, R, nt * XBAR_COLS)[:, :, :C]
    elif g == "ltp":
        mm *= ~kill
    elif g == "block":
        nr, nc, blk = gs.meta["nr"], gs.meta["nc"], gs.meta["block"]
        mp = _pad_to(mm, blk, blk)
        mv = mp.reshape(B, nr, blk, nc, blk)
        mv *= ~kill[:, :, None, :, None]
        mm = mv.reshape(B, nr * blk, nc * blk)[:, :R, :C]
    else:
        raise ValueError(g)
    from repro.core.crossbar import matrices_to_leaf
    return matrices_to_leaf(mm, gs.meta["shape"], tag)


def select_global_prune(group_sets: List[GroupSet], fraction: float,
                        remaining_weights: int) -> Dict[str, np.ndarray]:
    """Pick the lowest-scoring alive groups across all leaves until
    ~``fraction`` of ``remaining_weights`` are covered.

    Returns {path: kill bool array (same shape as that leaf's scores)}.
    """
    scores, sizes, owners = [], [], []
    for gi, gs in enumerate(group_sets):
        flat_alive = gs.alive.reshape(-1)
        flat_scores = gs.scores.reshape(-1)[flat_alive]
        flat_sizes = gs.sizes.reshape(-1)[flat_alive]
        idx = np.nonzero(flat_alive)[0]
        scores.append(flat_scores)
        sizes.append(flat_sizes)
        owners.append(np.stack([np.full(idx.shape, gi), idx], axis=1))
    if not scores:
        return {}
    scores = np.concatenate(scores)
    sizes = np.concatenate(sizes)
    owners = np.concatenate(owners)
    target = fraction * remaining_weights
    order = np.argsort(scores, kind="stable")
    csum = np.cumsum(sizes[order])
    n_kill = int(np.searchsorted(csum, target) + 1)
    n_kill = min(n_kill, len(order))
    chosen = owners[order[:n_kill]]
    kills: Dict[int, List[int]] = {}
    for gi, flat_i in chosen:
        kills.setdefault(int(gi), []).append(int(flat_i))
    out = {}
    for gi, flat_list in kills.items():
        gs = group_sets[gi]
        k = np.zeros(gs.scores.size, bool)
        k[flat_list] = True
        out[gs.path] = k.reshape(gs.scores.shape)
    return out
