"""Granularity strategies: pluggable group shapes for crossbar pruning.

The paper's granularities (§IV.B) and the baselines (§V.A) all follow
one contract on the unrolled weight matrix M (B, R, C):

  * ``score``  — per-group mean |w| over alive entries, plus the group
    sizes/liveness needed for global percentile selection;
  * ``zero``   — kill a boolean selection of groups in a leaf mask.

Each shape is a ``GranularityStrategy`` registered by name, so new
granularities (e.g. whole-crossbar ``xbar`` pruning) plug into
Algorithm 1 without touching the loop or the selection machinery.

Crossbar geometry is explicit: strategies take a ``TileGeometry``
(built from ``PruneConfig.xbar_rows/xbar_cols``) instead of reading the
module-level 128×128 constants, and record it in ``GroupSet.meta`` so
zeroing always uses the geometry the groups were scored with.

Registered names:
  filter / channel / index   — the paper's coarse→fine schedule
  ltp / block / cap          — the baselines (unstructured / BLK-REW / CAP)
  xbar                       — whole-crossbar tiles (coarsest structure)
  expert                     — whole MoE experts (one (d, d_ff) slice of a
                               stacked expert tensor per group)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.crossbar import (XBAR_COLS, XBAR_ROWS, leaf_matrices,
                                 matrices_to_leaf)


@dataclass(frozen=True)
class TileGeometry:
    """ReRAM crossbar extents == TPU MXU weight-tile extents."""
    rows: int = XBAR_ROWS
    cols: int = XBAR_COLS

    @classmethod
    def from_config(cls, cfg) -> "TileGeometry":
        """Geometry from any config with xbar_rows/xbar_cols (PruneConfig)."""
        return cls(int(cfg.xbar_rows), int(cfg.xbar_cols))

    @property
    def cells(self) -> int:
        return self.rows * self.cols


DEFAULT_GEOMETRY = TileGeometry()


@dataclass
class GroupSet:
    """Flattened groups of one leaf at one granularity.

    ``scores`` — (n_groups, …) mean |w| over group entries (alive mask
                 applied by caller).
    ``sizes``  — same shape: number of surviving weights in each group.
    ``alive``  — same shape, bool: group has any surviving weight.
    ``meta``   — layout info needed to zero a group in the leaf's mask,
                 including the scoring geometry ("xr"/"xc").
    """
    path: str
    granularity: str
    scores: np.ndarray
    sizes: np.ndarray
    alive: np.ndarray
    meta: Dict


def _group_reduce(x: np.ndarray, mask: np.ndarray, axes: Tuple[int, ...]):
    """(mean|x| over alive entries, any(mask), alive count) over ``axes``."""
    absx = np.abs(x) * mask
    cnt = mask.sum(axis=axes)
    scores = absx.sum(axis=axes) / np.maximum(cnt, 1e-9)
    return scores, mask.any(axis=axes), cnt.astype(np.int64)


def _pad_to(x: np.ndarray, r: int, c: int):
    R, C = x.shape[-2:]
    pr, pc = (-R) % r, (-C) % c
    if pr or pc:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
        x = np.pad(x, pad)
    return x


class GranularityStrategy:
    """One group shape: how to score groups and how to zero them."""

    name: str = ""

    def score(self, path: str, w: np.ndarray, mask: np.ndarray, *,
              conv: bool, geom: TileGeometry = DEFAULT_GEOMETRY,
              block: int = 32) -> GroupSet:
        raise NotImplementedError

    def zero(self, mask: np.ndarray, gs: GroupSet,
             kill: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- shared plumbing ---------------------------------------------------
    def _matrices(self, w, mask, conv):
        wm, tag = leaf_matrices(w, conv)
        mm, _ = leaf_matrices(mask, conv)
        return wm, mm, tag

    def _base_meta(self, w, tag, conv, wm, geom) -> Dict:
        B, R, C = wm.shape
        return {"tag": tag, "shape": w.shape, "conv": conv, "B": B,
                "R": R, "C": C, "xr": geom.rows, "xc": geom.cols}

    def _mask_matrix(self, mask, gs):
        mm, tag = leaf_matrices(mask, gs.meta["conv"])
        return mm.copy(), tag

    def _to_leaf(self, mm, gs, tag):
        return matrices_to_leaf(mm, gs.meta["shape"], tag)


_REGISTRY: Dict[str, GranularityStrategy] = {}


def register_strategy(strategy):
    """Register a strategy instance (or class) under its ``name``.

    Usable as a class decorator; later registrations replace earlier
    ones so projects can override a builtin shape.
    """
    inst = strategy() if isinstance(strategy, type) else strategy
    if not inst.name:
        raise ValueError(f"{inst!r} has no name")
    _REGISTRY[inst.name] = inst
    return strategy


def get_strategy(name: str) -> GranularityStrategy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown granularity {name!r}; "
                       f"registered: {available_strategies()}")
    return _REGISTRY[name]


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# The paper's coarse→fine schedule (Algorithm 1 walks these in order);
# recipes and the legacy ``granularities=`` shims both start from it.
PAPER_SCHEDULE: Tuple[str, ...] = ("filter", "channel", "index")


def require_strategies(names) -> Tuple[str, ...]:
    """Validate a granularity schedule eagerly (recipe parse time), so a
    typo fails before any training instead of rounds in."""
    names = tuple(names)
    unknown = [n for n in names if n not in _REGISTRY]
    if unknown:
        raise KeyError(f"unknown granularities {unknown!r}; "
                       f"registered: {available_strategies()}")
    return names


# ---------------------------------------------------------------------------
# The paper's granularities
# ---------------------------------------------------------------------------
@register_strategy
class FilterStrategy(GranularityStrategy):
    """One whole column: a conv filter (IC·K·K) or a dense output unit.

    The only granularity that also removes an activation.
    """
    name = "filter"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        scores, alive, sizes = _group_reduce(wm, mm, (1,))     # (B, C)
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        mm *= ~kill[:, None, :]
        return self._to_leaf(mm, gs, tag)


@register_strategy
class ChannelStrategy(GranularityStrategy):
    """Conv: the K² rows of one input channel within one column (Fig. 3c);
    dense: the xbar-rows crossbar segment of one column.  Zeroing one
    frees a crossbar column."""
    name = "channel"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        B, R, C = wm.shape
        if conv:
            K = w.shape[0]
            ic = w.shape[2]
            wv = wm.reshape(B, ic, K * K, C)
            mv = mm.reshape(B, ic, K * K, C)
            scores, alive, sizes = _group_reduce(wv, mv, (2,))  # (B, ic, C)
            meta["kk"] = K * K
        else:
            wp, mp = (_pad_to(wm, geom.rows, 1), _pad_to(mm, geom.rows, 1))
            nt = wp.shape[1] // geom.rows
            wv = wp.reshape(B, nt, geom.rows, C)
            mv = mp.reshape(B, nt, geom.rows, C)
            scores, alive, sizes = _group_reduce(wv, mv, (2,))  # (B, nt, C)
            meta["nt"] = nt
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        B, R, C = mm.shape
        if gs.meta["conv"]:
            kk = gs.meta["kk"]
            ic = kill.shape[1]
            mv = mm.reshape(B, ic, kk, C)
            mv *= ~kill[:, :, None, :]
            mm = mv.reshape(B, R, C)
        else:
            nt, xr = gs.meta["nt"], gs.meta["xr"]
            mp = _pad_to(mm, xr, 1)
            mv = mp.reshape(B, nt, xr, C)
            mv *= ~kill[:, :, None, :]
            mm = mv.reshape(B, nt * xr, C)[:, :R, :]
        return self._to_leaf(mm, gs, tag)


@register_strategy
class IndexStrategy(GranularityStrategy):
    """One row restricted to one xbar-cols crossbar (Fig. 3d); zeroing
    one frees a crossbar row."""
    name = "index"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        B, R, C = wm.shape
        wp, mp = _pad_to(wm, 1, geom.cols), _pad_to(mm, 1, geom.cols)
        nt = wp.shape[2] // geom.cols
        wv = wp.reshape(B, R, nt, geom.cols)
        mv = mp.reshape(B, R, nt, geom.cols)
        scores, alive, sizes = _group_reduce(wv, mv, (3,))      # (B, R, nt)
        meta["nt"] = nt
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        B, R, C = mm.shape
        nt, xc = gs.meta["nt"], gs.meta["xc"]
        mp = _pad_to(mm, 1, xc)
        mv = mp.reshape(B, R, nt, xc)
        mv *= ~kill[:, :, :, None]
        mm = mv.reshape(B, R, nt * xc)[:, :, :C]
        return self._to_leaf(mm, gs, tag)


# ---------------------------------------------------------------------------
# Baselines (paper §V.A) and the whole-crossbar extension
# ---------------------------------------------------------------------------
@register_strategy
class LTPStrategy(GranularityStrategy):
    """Every single weight is its own group (unstructured LTH)."""
    name = "ltp"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        scores = np.abs(wm) * mm
        alive = mm.astype(bool)
        sizes = np.ones_like(scores, dtype=np.int64)
        return GroupSet(path, self.name, scores, sizes, alive, meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        mm *= ~kill
        return self._to_leaf(mm, gs, tag)


@register_strategy
class BlockStrategy(GranularityStrategy):
    """Square b×b blocks (BLK-REW [9] adapted to crossbars)."""
    name = "block"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        B = wm.shape[0]
        wp, mp = _pad_to(wm, block, block), _pad_to(mm, block, block)
        nr, nc = wp.shape[1] // block, wp.shape[2] // block
        wv = wp.reshape(B, nr, block, nc, block)
        mv = mp.reshape(B, nr, block, nc, block)
        scores, alive, sizes = _group_reduce(wv, mv, (2, 4))    # (B, nr, nc)
        meta["nr"], meta["nc"], meta["block"] = nr, nc, block
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        B, R, C = mm.shape
        nr, nc, blk = gs.meta["nr"], gs.meta["nc"], gs.meta["block"]
        mp = _pad_to(mm, blk, blk)
        mv = mp.reshape(B, nr, blk, nc, blk)
        mv *= ~kill[:, :, None, :, None]
        mm = mv.reshape(B, nr * blk, nc * blk)[:, :R, :C]
        return self._to_leaf(mm, gs, tag)


@register_strategy
class CapStrategy(GranularityStrategy):
    """Full xbar-rows crossbar column segments (CAP [7]): the dense
    'channel' shape for every layer type."""
    name = "cap"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        return get_strategy("channel").score(path, w, mask, conv=False,
                                             geom=geom, block=block)

    def zero(self, mask, gs, kill):  # pragma: no cover - gs says "channel"
        return get_strategy("channel").zero(mask, gs, kill)


@register_strategy
class XbarStrategy(GranularityStrategy):
    """Whole crossbars: one xr×xc tile of the unrolled matrix per group.

    The coarsest crossbar-aligned structure — killing a group turns an
    entire crossbar off (or frees a whole bsmm tile on TPU).  Not part
    of the paper's schedule; demonstrates registry pluggability and is
    useful as an aggressive first pass before 'filter'.
    """
    name = "xbar"

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        B = wm.shape[0]
        wp = _pad_to(wm, geom.rows, geom.cols)
        mp = _pad_to(mm, geom.rows, geom.cols)
        nr, nc = wp.shape[1] // geom.rows, wp.shape[2] // geom.cols
        wv = wp.reshape(B, nr, geom.rows, nc, geom.cols)
        mv = mp.reshape(B, nr, geom.rows, nc, geom.cols)
        scores, alive, sizes = _group_reduce(wv, mv, (2, 4))    # (B, nr, nc)
        meta["nr"], meta["nc"] = nr, nc
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        B, R, C = mm.shape
        nr, nc = gs.meta["nr"], gs.meta["nc"]
        xr, xc = gs.meta["xr"], gs.meta["xc"]
        mp = _pad_to(mm, xr, xc)
        mv = mp.reshape(B, nr, xr, nc, xc)
        mv *= ~kill[:, :, None, :, None]
        mm = mv.reshape(B, nr * xr, nc * xc)[:, :R, :C]
        return self._to_leaf(mm, gs, tag)


@register_strategy
class ExpertStrategy(GranularityStrategy):
    """Whole MoE experts: one (d, d_ff) slice of a stacked expert tensor
    per group (the ROADMAP's MoE expert-level pruning scenario).

    Stacked expert leaves — ``(E, d, d_ff)`` ``up``/``gate``/``down``
    tensors, or their scanned ``(reps, E, d, d_ff)`` forms — unroll to a
    batch of B matrices (``leaf_matrices`` tag 'stack'); each matrix is
    one expert in one layer, and killing a group turns that expert off
    entirely (every crossbar it occupies powers down, and the bsmm
    retrain plan drops all its tiles).  Leaves that are not routed
    expert stacks (attention, dense MLPs, convs, and the always-on
    shared-expert MLP — which processes EVERY token, so it is never a
    unit the router can route around) expose NO alive groups, so
    global percentile selection never touches them — the schedule then
    falls through to finer granularities for the rest of the network.

    Crossbar geometry does not subdivide the group (an expert is the
    unit regardless of tile shape); it is still recorded in ``meta`` for
    the accounting path.
    """
    name = "expert"

    @staticmethod
    def _is_expert_leaf(path: str, tag: str, conv: bool, B: int) -> bool:
        parts = path.lower().split("/")
        # scanned shared-expert MLPs are (reps, d, ff) stacks under
        # .../moe/shared/... — layer repeats, not routed experts
        return tag == "stack" and not conv and B > 1 and \
            "moe" in parts and "shared" not in parts

    def score(self, path, w, mask, *, conv, geom=DEFAULT_GEOMETRY, block=32):
        wm, mm, tag = self._matrices(w, mask, conv)
        meta = self._base_meta(w, tag, conv, wm, geom)
        B = wm.shape[0]
        if not self._is_expert_leaf(path, tag, conv, B):
            zeros = np.zeros((B,))
            return GroupSet(path, self.name, zeros,
                            np.zeros((B,), np.int64),
                            np.zeros((B,), bool), meta)
        scores, alive, sizes = _group_reduce(wm, mm, (1, 2))    # (B,)
        return GroupSet(path, self.name, scores, sizes,
                        alive.astype(bool), meta)

    def zero(self, mask, gs, kill):
        mm, tag = self._mask_matrix(mask, gs)
        mm *= ~kill[:, None, None]
        return self._to_leaf(mm, gs, tag)
