"""Mask pytrees and prunability predicates.

A mask pytree mirrors the parameter pytree: prunable leaves get a
{0,1} array of the same shape; non-prunable leaves get ``None``.

Prunable (paper + standard LTH conventions):
  * CNN: all conv kernels and FC matrices (paths under convs/shortcuts/
    fc/head) — BN scales/biases excluded.
  * LM: every ≥2-D projection matrix (attention, MLP, MoE experts,
    recurrent in/out projections) — embeddings, unembedding, norms,
    per-channel gate vectors, conv1d kernels and routers excluded.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# path substrings excluded from pruning for LM params
_LM_EXCLUDE = ("embed", "unembed", "norm", "router", "lam", "conv",
               "patch_proj", "frame_adapter", "bi", "bf", "bq", "bk", "bv",
               "up_b", "down_b", "bz", "bo")


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def lm_prunable(path: str, leaf) -> bool:
    if leaf.ndim < 2:
        return False
    low = path.lower()
    return not any(tok in low.split("/")[-1] or tok in low
                   for tok in _LM_EXCLUDE)


def cnn_prunable(path: str, leaf) -> bool:
    low = path.lower()
    if "bn" in low or "scale" in low or "bias" in low:
        return False
    if low.endswith("/b"):
        return False
    return leaf.ndim >= 2


def cnn_is_conv(path: str, leaf) -> bool:
    return leaf.ndim == 4


def make_masks(params, prunable: Callable[[str, Any], bool]):
    """Full-ones masks for prunable leaves, None elsewhere."""
    def mk(path, leaf):
        p = path_str(path)
        if prunable(p, leaf):
            return jnp.ones(leaf.shape, jnp.float32)
        return None
    return jax.tree_util.tree_map_with_path(mk, params)


def apply_masks(params, masks):
    """params ⊙ masks (identity where mask is None)."""
    def ap(p, m):
        return p if m is None else p * m.astype(p.dtype)
    return jax.tree.map(ap, params, masks,
                        is_leaf=lambda x: x is None)


def mask_grads(grads, masks):
    """Zero gradients of pruned weights (keeps them pruned under any opt)."""
    return apply_masks(grads, masks)


def sparsity(masks) -> Tuple[int, int]:
    """(pruned_weights, total_prunable_weights)."""
    total = pruned = 0
    for m in jax.tree.leaves(masks):
        if m is None:
            continue
        m = np.asarray(m)
        total += m.size
        pruned += int(m.size - m.sum())
    return pruned, total


def sparsity_fraction(masks) -> float:
    p, t = sparsity(masks)
    return p / max(t, 1)


def flat_mask_items(masks, prunable_paths=None):
    """[(path, np.ndarray mask)] for prunable leaves, stable order."""
    items = []

    def visit(path, leaf):
        if leaf is not None:
            items.append((path_str(path), np.asarray(leaf)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return items


def tree_set(tree, path: str, value):
    """Functionally set a leaf by its path string (host-side, numpy ok)."""
    keys = path.split("/")

    def rec(node, ks):
        k = ks[0]
        if isinstance(node, dict):
            new = dict(node)
            key = k
            new[key] = value if len(ks) == 1 else rec(node[key], ks[1:])
            return new
        if isinstance(node, (list, tuple)):
            idx = int(k)
            items = list(node)
            items[idx] = value if len(ks) == 1 else rec(items[idx], ks[1:])
            return type(node)(items) if not isinstance(node, list) else items
        raise TypeError(f"cannot descend into {type(node)} at {k}")

    return rec(tree, keys)
