"""Mask pytrees and prunability predicates.

A mask pytree mirrors the parameter pytree: prunable leaves get a
{0,1} array of the same shape; non-prunable leaves get ``None``.

Prunable (paper + standard LTH conventions):
  * CNN: all conv kernels and FC matrices (paths under convs/shortcuts/
    fc/head) — BN scales/biases excluded.
  * LM: every ≥2-D projection matrix (attention, MLP, MoE experts,
    recurrent in/out projections) — embeddings, unembedding, norms,
    per-channel gate vectors, conv1d kernels and routers excluded.

Per-family predicates (``family_prunable``) are the registry data the
``repro.api`` adapter layer consumes: each named family (dense / moe /
hybrid / ssm / vlm / audio / cnn) maps to the predicate that knows its
family-specific tensors — stacked ``(E, d, d_ff)`` expert weights,
RG-LRU / xLSTM block-diagonal and recurrent projections, enc-dec
cross-attention — so new model families plug in as data, not as a new
adapter subclass.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# path substrings excluded from pruning for LM params
_LM_EXCLUDE = ("embed", "unembed", "norm", "router", "lam", "conv",
               "patch_proj", "frame_adapter", "bi", "bf", "bq", "bk", "bv",
               "up_b", "down_b", "bz", "bo")


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def lm_prunable(path: str, leaf) -> bool:
    if leaf.ndim < 2:
        return False
    low = path.lower()
    return not any(tok in low.split("/")[-1] or tok in low
                   for tok in _LM_EXCLUDE)


def cnn_prunable(path: str, leaf) -> bool:
    low = path.lower()
    if "bn" in low or "scale" in low or "bias" in low:
        return False
    if low.endswith("/b"):
        return False
    return leaf.ndim >= 2


def cnn_is_conv(path: str, leaf) -> bool:
    return leaf.ndim == 4


def cnn_conv_path(path: str) -> bool:
    """Path-level conv predicate for CNN params (the ``conv_pred``
    adapters and the family registry share)."""
    return "convs" in path or "shortcuts" in path


# ---------------------------------------------------------------------------
# Per-family predicates — the data the api adapter registry hangs off
# each family entry.  They share the LM exclusion list; each documents
# (and is unit-tested for) the family-specific tensors it must reach.
# ---------------------------------------------------------------------------
def moe_prunable(path: str, leaf) -> bool:
    """MoE transformers: dense projections plus the stacked per-expert
    ``up``/``gate``/``down`` tensors ``(E, d, d_ff)`` (and their scanned
    ``(reps, E, d, d_ff)`` forms).  Routers stay dense — killing router
    columns would silently disable experts without freeing crossbars."""
    return lm_prunable(path, leaf)


def recurrent_prunable(path: str, leaf) -> bool:
    """RG-LRU / xLSTM (hybrid + ssm families): in/gate/out projections,
    the block-diagonal per-head recurrence weights ``(H, bs, bs)``, and
    sLSTM input/recurrent matrices.  Temporal conv1d kernels, Λ decay
    vectors, and per-channel gate biases are excluded."""
    return lm_prunable(path, leaf)


def encdec_prunable(path: str, leaf) -> bool:
    """Encoder-decoder (whisper-style): encoder/decoder self-attention,
    MLPs, AND the decoder cross-attention ``xattn`` projections.  The
    frame-adapter stub and embeddings are excluded."""
    return lm_prunable(path, leaf)


_FAMILY_PRUNABLE = {
    "dense": lm_prunable,
    "moe": moe_prunable,
    "hybrid": recurrent_prunable,
    "ssm": recurrent_prunable,
    "vlm": lm_prunable,
    "audio": encdec_prunable,
    "cnn": cnn_prunable,
}


def family_prunable(family: str):
    """The prunability predicate for a registered config family."""
    if family not in _FAMILY_PRUNABLE:
        raise KeyError(f"no prunable predicate for family {family!r}; "
                       f"known: {sorted(_FAMILY_PRUNABLE)}")
    return _FAMILY_PRUNABLE[family]


def make_masks(params, prunable: Callable[[str, Any], bool]):
    """Full-ones masks for prunable leaves, None elsewhere."""
    def mk(path, leaf):
        p = path_str(path)
        if prunable(p, leaf):
            return jnp.ones(leaf.shape, jnp.float32)
        return None
    return jax.tree_util.tree_map_with_path(mk, params)


def apply_masks(params, masks):
    """params ⊙ masks (identity where mask is None)."""
    def ap(p, m):
        return p if m is None else p * m.astype(p.dtype)
    return jax.tree.map(ap, params, masks,
                        is_leaf=lambda x: x is None)


def mask_grads(grads, masks):
    """Zero gradients of pruned weights (keeps them pruned under any opt)."""
    return apply_masks(grads, masks)


def sparsity(masks) -> Tuple[int, int]:
    """(pruned_weights, total_prunable_weights)."""
    total = pruned = 0
    for m in jax.tree.leaves(masks):
        if m is None:
            continue
        m = np.asarray(m)
        total += m.size
        pruned += int(m.size - m.sum())
    return pruned, total


def sparsity_fraction(masks) -> float:
    p, t = sparsity(masks)
    return p / max(t, 1)


def flat_mask_items(masks, prunable_paths=None):
    """[(path, np.ndarray mask)] for prunable leaves, stable order."""
    items = []

    def visit(path, leaf):
        if leaf is not None:
            items.append((path_str(path), np.asarray(leaf)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return items


def tree_set(tree, path: str, value):
    """Functionally set a leaf by its path string (host-side, numpy ok)."""
    keys = path.split("/")

    def rec(node, ks):
        k = ks[0]
        if isinstance(node, dict):
            new = dict(node)
            key = k
            new[key] = value if len(ks) == 1 else rec(node[key], ks[1:])
            return new
        if isinstance(node, (list, tuple)):
            idx = int(k)
            items = list(node)
            items[idx] = value if len(ks) == 1 else rec(items[idx], ks[1:])
            return type(node)(items) if not isinstance(node, list) else items
        raise TypeError(f"cannot descend into {type(node)} at {k}")

    return rec(tree, keys)
