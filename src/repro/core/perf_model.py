"""Deterministic pipelined ReRAM execution model (paper §V.A, Figs 7-8).

Target chip (paper): 256 tiles × 96 crossbars of 128×128 cells @10 MHz.
CNN layers execute in a pipeline (PipeLayer [1]): every layer processes
a different image simultaneously, so throughput is set by the slowest
layer.  A conv layer with output O×O must stream O² windows through its
crossbar grid — one window per crossbar cycle — so its per-image time is
O²/r cycles given r-way weight replication.  Training ≈ 3 passes
(forward, error backward, weight gradient) [1].

Iso-area (Fig. 7): a fixed crossbar budget first stores every layer's
(pruned) weights; the remainder replicates slow layers.  The optimal
continuous waterfill equalises t = O²_l/r_l:
    t* = Σ_l (xb_l · O²_l) / B_compute,   r_l = O²_l / t*.
Pruning shrinks xb_l, freeing budget for replication — exactly the
mechanism the paper credits for its 19.7× mean speedup.

Iso-performance (Fig. 6): replication factors are fixed to the
*unpruned* model's waterfill (equal parallelism ⇒ equal performance);
pruned models then need Σ r_l·xb'_l crossbars.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.crossbar import XBAR_COLS, XBAR_ROWS

# paper / ISAAC [2] constants
XBARS_PER_TILE = 96
N_TILES = 256
TOTAL_XBARS = XBARS_PER_TILE * N_TILES          # 24576
XBAR_FREQ_HZ = 10e6
TRAIN_PASSES = 3.0                              # fwd + err-bwd + wgrad
ACT_CELLS_PER_XBAR = XBAR_ROWS * XBAR_COLS
# ISAAC stores 16-bit fixed-point values in 2-bit cells: 8 cells/weight.
# This is why an unpruned CNN nearly saturates the 24576-crossbar chip
# (paper §V.C: ">80% of the crossbars" for ResNet-18 C11-C17) and why
# pruning frees enough area for ~20× replication speedups.
CELLS_PER_WEIGHT = 8


@dataclass
class LayerPerf:
    name: str
    out_positions: float        # O² (conv windows) or 1 (FC)
    xbars: int                  # crossbars to store this layer's weights
    act_xbars: float = 0.0      # crossbars to store activations


def conv_layer_perf(cfg, xbars_per_layer: Dict[str, int],
                    act_volumes: Optional[Dict[str, float]] = None,
                    cells_per_weight: int = CELLS_PER_WEIGHT,
                    pipelined_training: bool = True,
                    act_cells_per_xbar: float = ACT_CELLS_PER_XBAR
                    ) -> List[LayerPerf]:
    """Build LayerPerf list for a CNNConfig given per-layer crossbar needs.

    ``xbars_per_layer`` counts single-cell-per-weight crossbars (the
    mapping unit of core.crossbar); the 16-bit/2-bit-cell encoding
    multiplies physical crossbars by ``cells_per_weight``.
    ``act_cells_per_xbar`` is the crossbar cell capacity — pass
    ``xbar_rows * xbar_cols`` when using non-default geometry.

    Pipelined training (PipeLayer [1]) keeps layer l's activations
    resident until the backward pass returns to it: in-flight copies ≈
    2·(L − l).  This is what makes an unpruned deep CNN saturate the
    chip (paper §V.C) — and why filter-wise pruning, the only kind that
    removes activations, matters for training.
    """
    size = cfg.image_size
    acts = act_volumes or {}
    L = len(cfg.convs)
    layers = []
    for i, spec in enumerate(cfg.convs):
        if spec.stride > 1:
            size //= spec.stride
        copies = 2 * (L - i) if pipelined_training else 1
        act_xb = np.ceil(acts.get(f"convs/{i}/w", 0.0) * copies
                         * cells_per_weight / act_cells_per_xbar)
        layers.append(LayerPerf(
            f"C{i + 1}", float(size * size),
            xbars_per_layer.get(f"convs/{i}/w", 0) * cells_per_weight,
            act_xb))
        if spec.pool:
            size //= 2
    for j in range(len(cfg.fc) + 1):
        key = f"fc/{j}/w" if j < len(cfg.fc) else "head/w"
        if key in xbars_per_layer:
            layers.append(LayerPerf(key, 1.0,
                                    xbars_per_layer[key] * cells_per_weight,
                                    0.0))
    return layers


@dataclass
class PipelineResult:
    cycles_per_image: float
    replication: List[float]
    storage_xbars: float
    compute_budget: float

    @property
    def time_per_image_s(self) -> float:
        return self.cycles_per_image / XBAR_FREQ_HZ


def waterfill(layers: Sequence[LayerPerf], budget: int = TOTAL_XBARS,
              train: bool = True,
              replication: Optional[Sequence[float]] = None
              ) -> PipelineResult:
    """Pipeline time under a crossbar budget with optimal replication.

    If ``replication`` is given it is used as-is (iso-performance mode);
    otherwise the continuous waterfill above allocates the budget.
    """
    storage = sum(l.xbars + l.act_xbars for l in layers)
    passes = TRAIN_PASSES if train else 1.0
    if replication is None:
        b_compute = max(budget - storage, 1.0)
        # replicas beyond the first copy: budget for (r_l - 1) · xb_l
        num = sum(l.xbars * l.out_positions for l in layers)
        t_star = num / (b_compute + sum(l.xbars for l in layers))
        repl = [max(1.0, l.out_positions / max(t_star, 1e-12))
                for l in layers]
        # respect the budget exactly: scale down if the floor-at-1 pushed over
        cost = sum((r - 1.0) * l.xbars for r, l in zip(repl, layers))
        if cost > b_compute:
            scale = b_compute / cost
            repl = [1.0 + (r - 1.0) * scale for r in repl]
    else:
        repl = list(replication)
    cycles = max(l.out_positions / r for l, r in zip(layers, repl)) * passes
    return PipelineResult(cycles, repl, storage,
                          max(budget - storage, 0.0))


def iso_area_speedup(unpruned: Sequence[LayerPerf],
                     pruned: Sequence[LayerPerf],
                     budget: int = TOTAL_XBARS) -> float:
    """Fig. 7: training speedup of the pruned model, equal crossbar budget."""
    t0 = waterfill(unpruned, budget).cycles_per_image
    t1 = waterfill(pruned, budget).cycles_per_image
    return t0 / t1


def iso_perf_xbars(unpruned: Sequence[LayerPerf],
                   pruned: Sequence[LayerPerf],
                   budget: int = TOTAL_XBARS) -> Dict[str, float]:
    """Fig. 6: crossbars needed by the pruned model at equal parallelism."""
    base = waterfill(unpruned, budget)
    need_unpruned = sum(r * l.xbars + l.act_xbars
                        for r, l in zip(base.replication, unpruned))
    need_pruned = sum(r * l.xbars + l.act_xbars
                      for r, l in zip(base.replication, pruned))
    return {
        "unpruned_xbars": need_unpruned,
        "pruned_xbars": need_pruned,
        "savings": 1.0 - need_pruned / max(need_unpruned, 1e-9),
    }


# ---------------------------------------------------------------------------
# Analytic per-kernel cost model for the TPU Pallas kernels.
#
# These predictors compute, from a plan's *metadata* (live-tile counts,
# sequence lengths) what each kernel should cost under the documented
# "no-elision, guarded-skip" traffic model:
#
#   * passes    — grid cells whose pl.when work gate is open;
#   * flops     — MXU flops those cells issue;
#   * hbm_bytes — every unguarded cell re-streams its input blocks
#                 (no revolving-window elision credit) and every output
#                 tile is written exactly once.
#
# analysis.kernel_audit (rule K306) independently derives the same
# three numbers by exhaustively enumerating the kernel's actual
# grid/index maps/guard from its KernelSpec and compares — so the perf
# model and the kernels cannot silently diverge.  The model is
# deliberately simple and exact under its stated assumptions; it is a
# consistency oracle, not a wall-clock simulator.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class KernelCost:
    """Predicted cost of one kernel launch under the no-elision model."""
    passes: int         # unguarded grid cells
    flops: float        # MXU flops
    hbm_bytes: float    # input-block reads + output-tile writes


def bsmm_fwd_cost(plan, M: int, *, bm: int, dtype_bytes: int = 4,
                  fused: bool = False) -> KernelCost:
    """Forward bsmm: (M/bm) row blocks × the plan's live tiles."""
    t = plan.tile
    Nt = int(plan.counts.shape[0])
    passes = (M // bm) * int(plan.live_tiles)
    flops = passes * 2.0 * bm * t * t
    in_bytes = passes * (bm * t + t * t) * dtype_bytes
    if fused:
        in_bytes += passes * t * dtype_bytes        # (1, bn) bias block
    out_bytes = (M // bm) * Nt * bm * t * dtype_bytes
    return KernelCost(passes, flops, float(in_bytes + out_bytes))


def bsmm_dx_cost(plan, M: int, *, bm: int,
                 dtype_bytes: int = 4) -> KernelCost:
    """dx backward: transposed plan, same live-tile count, (M, K) out."""
    t = plan.tile
    Kt = int(plan.counts_t.shape[0])
    passes = (M // bm) * int(plan.live_tiles)
    flops = passes * 2.0 * bm * t * t
    in_bytes = passes * (bm * t + t * t) * dtype_bytes
    out_bytes = (M // bm) * Kt * bm * t * dtype_bytes
    return KernelCost(passes, flops, float(in_bytes + out_bytes))


def bsmm_dw_cost(plan, M: int, *, bm: int,
                 dtype_bytes: int = 4) -> KernelCost:
    """dw backward: only the L live (t, t) grad tiles are built."""
    t = plan.tile
    L = int(plan.live_tiles)
    passes = L * (M // bm)
    flops = passes * 2.0 * bm * t * t
    in_bytes = passes * 2 * bm * t * dtype_bytes    # x + g blocks
    out_bytes = L * t * t * dtype_bytes
    return KernelCost(passes, flops, float(in_bytes + out_bytes))


def bsmm_train_cost(plan, M: int, *, bm: int, dtype_bytes: int = 4,
                    fused: bool = False) -> Dict[str, KernelCost]:
    """One value_and_grad step: forward + dx + dw kernel costs."""
    return {"fwd": bsmm_fwd_cost(plan, M, bm=bm, dtype_bytes=dtype_bytes,
                                 fused=fused),
            "dx": bsmm_dx_cost(plan, M, bm=bm, dtype_bytes=dtype_bytes),
            "dw": bsmm_dw_cost(plan, M, bm=bm, dtype_bytes=dtype_bytes)}


def paged_decode_cost(lengths, *, nb: int, block_tokens: int,
                      n_q_heads: int, n_kv_heads: int, head_dim: int,
                      v_dim: int, fused_v: bool,
                      dtype_bytes: int = 4) -> KernelCost:
    """Paged decode attention: Σ_b live blocks of each sequence.

    ``lengths`` are live context lengths (≥ 1), ``nb`` the table
    width; a sequence touches ``ceil(len / block_tokens)`` blocks.
    """
    T = block_tokens
    Hq, Hkv, hd, dv = n_q_heads, n_kv_heads, head_dim, v_dim
    passes = sum(min(nb, -(-int(n) // T)) for n in lengths)
    flops = passes * (2.0 * Hq * T * hd + 2.0 * Hq * T * dv)
    kv_block = T * Hkv * hd + (0 if fused_v else T * Hkv * dv)
    in_bytes = passes * (Hq * hd + kv_block) * dtype_bytes
    out_bytes = len(lengths) * Hq * dv * dtype_bytes
    return KernelCost(passes, flops, float(in_bytes + out_bytes))


def flash_cost(*, batch: int, n_q_heads: int, seq: int, head_dim: int,
               bq: int, bk: int, causal: bool,
               dtype_bytes: int = 4) -> KernelCost:
    """Flash attention: causal skips fully-masked (i, j) block pairs."""
    nq, nk = seq // bq, seq // bk
    pairs = sum(1 for i in range(nq) for j in range(nk)
                if not causal or j * bk <= i * bq + bq - 1)
    passes = batch * n_q_heads * pairs
    flops = passes * 4.0 * bq * bk * head_dim
    in_bytes = passes * (bq + 2 * bk) * head_dim * dtype_bytes
    out_bytes = batch * n_q_heads * nq * bq * head_dim * dtype_bytes
    return KernelCost(passes, flops, float(in_bytes + out_bytes))
