"""Crossbar mapping: how weight tensors land on 128×128 ReRAM crossbars.

This reproduces the paper's §IV.A mapping exactly (Fig. 3a):

  * A Conv layer with OC filters of shape (IC, K, K) unrolls to a matrix
    of shape (IC·K·K, OC) — rows indexed by (ic, kx, ky) so one filter
    *channel* is a contiguous K² row block of one column; one *filter*
    is a whole column; one *index* (ic,kx,ky) is a whole row.
  * The matrix is tiled into ⌈R/xr⌉ × ⌈C/xc⌉ crossbars (xr×xc is the
    crossbar geometry — the paper's 128×128 by default; every function
    takes it as a parameter so ``PruneConfig.xbar_rows/xbar_cols``
    flows through the whole stats path).
  * A crossbar row/column can be power-gated or reused only if every
    cell in it (within that crossbar) is zero (Fig. 2).

On TPU the identical geometry is a 128×128 MXU weight tile; the same
functions drive the Pallas block-sparse kernel's tile bitmap, so the
paper's "hardware savings" number and the kernel's skipped-tile count
are computed by one code path.

All functions here are host-side numpy: pruning decisions are a
one-time offline step (paper §V.C) — only mask *application* runs in
JAX.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import MXU_TILE

XBAR_ROWS = MXU_TILE
XBAR_COLS = MXU_TILE


# ---------------------------------------------------------------------------
# Weight-tensor → unrolled-matrix views
# ---------------------------------------------------------------------------
def conv_to_matrix(w: np.ndarray) -> np.ndarray:
    """(K, K, IC, OC) → (IC·K·K, OC) with rows ordered (ic, kx, ky)."""
    K1, K2, IC, OC = w.shape
    return np.transpose(w, (2, 0, 1, 3)).reshape(IC * K1 * K2, OC)


def matrix_to_conv(m: np.ndarray, shape: Tuple[int, ...]) -> np.ndarray:
    K1, K2, IC, OC = shape
    return np.transpose(m.reshape(IC, K1, K2, OC), (1, 2, 0, 3))


def leaf_matrices(w: np.ndarray, conv: bool = False) -> Tuple[np.ndarray, str]:
    """View a prunable leaf as a batch of unrolled matrices.

    Returns (batched matrix of shape (B, R, C), layout tag) where the
    layout tag lets ``matrices_to_leaf`` invert the view.
      * conv (K,K,IC,OC)     → (1, IC·K·K, OC)      tag 'conv'
      * 2D dense (in, out)   → (1, in, out)          tag 'dense'
      * ND stacked (…, in, out) → (prod(…), in, out) tag 'stack'

    ``conv`` must be passed explicitly (the caller knows the model);
    shape heuristics would misclassify stacked per-layer LM params.
    """
    if conv:
        assert w.ndim == 4, w.shape
        return conv_to_matrix(w)[None], "conv"
    if w.ndim == 2:
        return w[None], "dense"
    if w.ndim >= 3:
        lead = int(np.prod(w.shape[:-2]))
        return w.reshape(lead, w.shape[-2], w.shape[-1]), "stack"
    raise ValueError(f"not a prunable leaf shape: {w.shape}")


def matrices_to_leaf(m: np.ndarray, shape: Tuple[int, ...], tag: str
                     ) -> np.ndarray:
    if tag == "conv":
        return matrix_to_conv(m[0], shape)
    if tag == "dense":
        return m[0]
    return m.reshape(shape)


# ---------------------------------------------------------------------------
# Crossbar tiling of one matrix
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class XbarGrid:
    rows: int
    cols: int
    n_row_tiles: int
    n_col_tiles: int

    @property
    def n_xbars(self) -> int:
        return self.n_row_tiles * self.n_col_tiles


def grid_of(matrix_shape: Tuple[int, int], xr: int = XBAR_ROWS,
            xc: int = XBAR_COLS) -> XbarGrid:
    R, C = matrix_shape
    return XbarGrid(R, C, -(-R // xr), -(-C // xc))


def iter_xbars(R: int, C: int, xr: int = XBAR_ROWS, xc: int = XBAR_COLS
               ) -> Iterator[Tuple[int, int, slice, slice]]:
    """Yield (tile_i, tile_j, row_slice, col_slice) of the actual extents."""
    for i in range(-(-R // xr)):
        for j in range(-(-C // xc)):
            yield (i, j, slice(i * xr, min((i + 1) * xr, R)),
                   slice(j * xc, min((j + 1) * xc, C)))


# ---------------------------------------------------------------------------
# Per-crossbar savings accounting (paper Fig. 2 semantics)
# ---------------------------------------------------------------------------
@dataclass
class XbarStats:
    """Savings for one unrolled matrix (counts over actual extents)."""
    total_cells: int = 0
    nonzero_cells: int = 0
    saved_cells: int = 0          # cells in all-zero rows/cols per crossbar
    n_xbars: int = 0
    xbars_fully_free: int = 0     # whole crossbar zero → turn off
    xbars_needed_packed: int = 0  # ceil(live cell area / xbar area) (reuse)
    xbars_needed_strict: int = 0  # crossbars containing any non-zero
    live_area: int = 0            # Σ live_rows × live_cols per crossbar
    xbar_rows: int = XBAR_ROWS    # geometry the stats were computed with
    xbar_cols: int = XBAR_COLS

    def merge(self, o: "XbarStats"):
        if (o.xbar_rows, o.xbar_cols) != (self.xbar_rows, self.xbar_cols):
            raise ValueError(
                f"cannot merge XbarStats computed under different crossbar "
                f"geometries: {self.xbar_rows}x{self.xbar_cols} vs "
                f"{o.xbar_rows}x{o.xbar_cols} — recompute both at one "
                "geometry first")
        for f in ("total_cells", "nonzero_cells", "saved_cells", "n_xbars",
                  "xbars_fully_free", "xbars_needed_strict", "live_area"):
            setattr(self, f, getattr(self, f) + getattr(o, f))
        # packed count recomputed from live_area under this geometry
        self.xbars_needed_packed = -(-self.live_area
                                     // (self.xbar_rows * self.xbar_cols))


def xbar_stats(mask_matrix: np.ndarray, xr: int = XBAR_ROWS,
               xc: int = XBAR_COLS) -> XbarStats:
    """mask_matrix: (R, C) of {0,1} — 1 = weight kept."""
    R, C = mask_matrix.shape
    st = XbarStats(total_cells=R * C,
                   nonzero_cells=int(mask_matrix.sum()),
                   xbar_rows=xr, xbar_cols=xc)
    for _, _, rs, cs in iter_xbars(R, C, xr, xc):
        blk = mask_matrix[rs, cs]
        r_live = int((blk.any(axis=1)).sum())
        c_live = int((blk.any(axis=0)).sum())
        nr, nc = blk.shape
        st.n_xbars += 1
        st.saved_cells += nr * nc - r_live * c_live
        st.live_area += r_live * c_live
        if r_live == 0:
            st.xbars_fully_free += 1
        else:
            st.xbars_needed_strict += 1
    st.xbars_needed_packed = -(-st.live_area // (xr * xc))
    return st


def alive_columns(mask_matrix: np.ndarray) -> np.ndarray:
    """Columns (output units / filters) with any surviving weight."""
    return mask_matrix.any(axis=0)
