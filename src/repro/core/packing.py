"""Physical packing of pruned FFNs — "freed crossbars reused", realised.

The paper's hardware saving comes from *reusing* crossbar rows/columns
freed by structured pruning (Fig. 2/3).  On TPU the exact analogue is
to pack the surviving FFN columns into a dense, narrower matmul: a
filter/channel-pruned (d, ff) `up`/`gate` pair with s% dead columns
becomes (d, ff'), ff' = live columns rounded up to the 128-lane tile,
with `down` rows packed identically.  This converts mask sparsity into
real FLOP/byte/HBM savings for *every* backend — it is what the
``pruned=<frac>`` dry-run variants lower (EXPERIMENTS.md §Perf cells A
and C), and this module produces those packed weights from an actual
pruned checkpoint.

Scan-stacked layers share one ff' (the max live count over the stack,
so no layer loses weights); per-layer column permutations differ.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MXU_TILE

LANE = MXU_TILE


def _live_columns(masks_up: np.ndarray, masks_gate: Optional[np.ndarray],
                  masks_down: np.ndarray) -> np.ndarray:
    """A column is dead iff dead in up AND gate AND the down row. (…, ff)"""
    dead = ~masks_up.any(axis=-2)
    if masks_gate is not None:
        dead &= ~masks_gate.any(axis=-2)
    dead &= ~masks_down.any(axis=-1)
    return ~dead


def packed_width(live: np.ndarray) -> int:
    """Shared ff' for a (possibly stacked) live map (…, ff)."""
    per_layer = live.reshape(-1, live.shape[-1]).sum(axis=-1)
    return max(LANE, int(-(-int(per_layer.max()) // LANE) * LANE))


def _perm_for(live_row: np.ndarray, ffp: int) -> np.ndarray:
    """Column permutation: live columns first, padded with dead ones."""
    live_idx = np.nonzero(live_row)[0]
    dead_idx = np.nonzero(~live_row)[0]
    perm = np.concatenate([live_idx, dead_idx])[:ffp]
    if len(perm) < ffp:      # ff < ffp cannot happen (ffp ≤ ff by clamp)
        perm = np.pad(perm, (0, ffp - len(perm)))
    return perm.astype(np.int32)


def pack_ffn(up, gate, down, m_up, m_gate, m_down
             ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray], jnp.ndarray,
                        int]:
    """Pack one FFN (2-D (d, ff) or stacked (…, d, ff)) to ff' columns.

    Returns (up', gate', down', ff').  Weights are mask-applied before
    packing so dead-but-kept padding columns are exact zeros.
    """
    up_n = np.asarray(up) * np.asarray(m_up)
    gate_n = None if gate is None else np.asarray(gate) * np.asarray(m_gate)
    down_n = np.asarray(down) * np.asarray(m_down)
    live = _live_columns(np.asarray(m_up) != 0,
                         None if m_gate is None else np.asarray(m_gate) != 0,
                         np.asarray(m_down) != 0)
    ffp = min(packed_width(live), up_n.shape[-1])

    lead = up_n.shape[:-2]
    up2 = up_n.reshape(-1, *up_n.shape[-2:])
    down2 = down_n.reshape(-1, *down_n.shape[-2:])
    gate2 = None if gate_n is None else gate_n.reshape(-1, *gate_n.shape[-2:])
    live2 = live.reshape(-1, live.shape[-1])

    ups, gates, downs = [], [], []
    for i in range(up2.shape[0]):
        perm = _perm_for(live2[i], ffp)
        ups.append(up2[i][:, perm])
        if gate2 is not None:
            gates.append(gate2[i][:, perm])
        downs.append(down2[i][perm, :])
    up_p = jnp.asarray(np.stack(ups).reshape(*lead, up_n.shape[-2], ffp))
    down_p = jnp.asarray(
        np.stack(downs).reshape(*lead, ffp, down_n.shape[-1]))
    gate_p = None if gate2 is None else jnp.asarray(
        np.stack(gates).reshape(*lead, gate_n.shape[-2], ffp))
    return up_p, gate_p, down_p, ffp


def pack_lm_params(params, masks, cfg):
    """Pack every dense MLP of a transformer params tree.

    Returns (packed_params, packed_cfg).  Only uniform `mlp` blocks are
    packed (MoE experts pack per-expert the same way via pack_ffn on
    their stacked (E, d, ff) leaves; see dry-run `pruned=` variants).
    """
    import dataclasses
    new_segments = []
    global_ffp = 0
    # first pass: the shared ff' across all layers (scan needs uniformity)
    for seg_p, seg_m in zip(params["segments"], masks["segments"]):
        for p, m in zip(seg_p, seg_m):
            if isinstance(p, dict) and "mlp" in p and m.get("mlp"):
                live = _live_columns(
                    np.asarray(m["mlp"]["up"]) != 0,
                    (np.asarray(m["mlp"]["gate"]) != 0
                     if "gate" in m["mlp"] else None),
                    np.asarray(m["mlp"]["down"]) != 0)
                global_ffp = max(global_ffp, packed_width(live))
    if global_ffp == 0 or global_ffp >= cfg.d_ff:
        return params, cfg
    for seg_p, seg_m in zip(params["segments"], masks["segments"]):
        new_pos = []
        for p, m in zip(seg_p, seg_m):
            if isinstance(p, dict) and "mlp" in p and m.get("mlp"):
                mlp_p = dict(p["mlp"])
                up, gate, down, _ = pack_ffn(
                    mlp_p["up"], mlp_p.get("gate"), mlp_p["down"],
                    m["mlp"]["up"], m["mlp"].get("gate"),
                    m["mlp"]["down"])
                # clamp to the global width (pad with zero columns)
                def fit(w, axis):
                    cur = w.shape[axis]
                    if cur == global_ffp:
                        return w
                    pad = [(0, 0)] * w.ndim
                    pad[axis] = (0, global_ffp - cur)
                    return jnp.pad(w, pad)
                mlp_p["up"] = fit(up, up.ndim - 1)
                if gate is not None:
                    mlp_p["gate"] = fit(gate, gate.ndim - 1)
                mlp_p["down"] = fit(down, down.ndim - 2)
                p = {**p, "mlp": mlp_p}
            new_pos.append(p)
        new_segments.append(new_pos)
    packed = {**params, "segments": new_segments}
    return packed, dataclasses.replace(cfg, d_ff=global_ffp,
                                       name=cfg.name + "-packed")
