"""Fixed-point weight quantization (the ReRAM-native representation).

The paper's platform computes in 16-bit fixed point ([2]'s 2-bit cells
× 8 bit-slices).  On TPU the analogous lever is symmetric per-channel
integer storage with bf16 compute: int8 halves decode weight bandwidth
on top of whatever ReaLPrune removed (§Perf cell A analysis: decode is
weight-read-bound), and composes with packing — quantize *after*
`core.packing` so scales cover only live columns.

Scheme: per-output-channel symmetric, scale = max|w| / qmax; dequantize
fuses into the matmul on TPU (convert+dot).  Masked (pruned) weights
quantize to exact 0 at any scale.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class QTensor(NamedTuple):
    q: jax.Array          # int8/int16 values
    scale: jax.Array      # (..., 1, out) f32 per-output-channel scales

    @property
    def nbytes(self) -> int:
        # scale bytes follow the stored scale dtype (a bf16-scale QTensor
        # used to be over-counted at a hardcoded 4 bytes per entry)
        return (self.q.size * self.q.dtype.itemsize
                + self.scale.size * self.scale.dtype.itemsize)


_QMAX = {jnp.int8: 127.0, jnp.int16: 32767.0}


def quantize(w, bits: int = 8, axis: int = -1) -> QTensor:
    """w: (..., in, out) → QTensor with per-out-channel scales."""
    dtype = jnp.int8 if bits == 8 else jnp.int16
    qmax = _QMAX[dtype]
    wf = jnp.asarray(w, jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(wf / scale), -qmax, qmax).astype(dtype)
    return QTensor(q=q, scale=scale)


def dequantize(qt: QTensor, dtype=jnp.bfloat16):
    return (qt.q.astype(jnp.float32) * qt.scale).astype(dtype)


def qmatmul(x, qt: QTensor):
    """x @ dequant(qt) — the convert fuses into the dot on TPU."""
    w = qt.q.astype(x.dtype)
    return (x @ w) * qt.scale[..., 0, :].astype(x.dtype)


def fake_quantize(w, bits: int = 8):
    """Straight-through fake quantization: forward sees the fixed-point
    value, backward sees identity — the QAT step of a ``quantize``
    recipe stage, so tickets retrain against the ReRAM-native
    representation while gradients stay full-precision.  Masked (pruned)
    weights round-trip to exact 0, so masks survive the fake pass."""
    wq = dequantize(quantize(w, bits), jnp.float32).astype(w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


def fake_quantize_tree(params, predicate, bits: int = 8):
    """STE fake-quantize every leaf where predicate(path, leaf) — jit-safe
    (wraps a training loss: ``loss(fake_quantize_tree(p, pred), batch)``)."""
    from repro.core.masks import path_str

    def f(path, leaf):
        # per-out-channel scales need an (in, out) trailing pair; 1-D
        # leaves (norm gains, biases) stay full precision
        if (leaf is not None and getattr(leaf, "ndim", 0) >= 2
                and predicate(path_str(path), leaf)):
            return fake_quantize(leaf, bits)
        return leaf

    return jax.tree_util.tree_map_with_path(
        f, params, is_leaf=lambda x: x is None)


def quantize_tree(params, predicate, bits: int = 8):
    """Quantize every leaf where predicate(path, leaf); others pass."""
    from repro.core.masks import path_str

    def f(path, leaf):
        if leaf is not None and predicate(path_str(path), leaf):
            return quantize(leaf, bits)
        return leaf

    return jax.tree_util.tree_map_with_path(
        f, params, is_leaf=lambda x: x is None)


def tree_bytes(tree) -> int:
    """Stored bytes of a (possibly quantized) parameter tree."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=lambda x:
                                isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total
