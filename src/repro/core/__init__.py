"""ReaLPrune core: the paper's contribution as a composable library.

Layering (bottom → top):

crossbar.py   — weight→crossbar unroll mapping + tile accounting
                (geometry-parametric: xr×xc, default 128×128)
masks.py      — mask pytrees, prunability predicates
strategies.py — GranularityStrategy registry: filter/channel/index
                (+ltp/block/cap/xbar) group shapes, pluggable by name
scoring.py    — global lowest-percentile group selection + name dispatch
algorithm.py  — prune_step primitive + realprune/lottery_baseline
                compatibility shims over repro.api.PruningSession
lottery.py    — winning-ticket snapshot/rewind/export
hardware.py   — crossbar savings accounting (Figs 2 & 6)
perf_model.py — pipelined ReRAM execution model (Figs 7 & 8)

The user-facing entry point is ``repro.api`` (ModelAdapter +
PruningSession); this package stays framework-light and host-side so
pruning decisions remain a one-time offline effort (paper §V.C).
"""
from repro.core.masks import (  # noqa: F401
    apply_masks, cnn_is_conv, cnn_prunable, lm_prunable, make_masks,
    mask_grads, sparsity, sparsity_fraction,
)
from repro.core.strategies import (  # noqa: F401
    GranularityStrategy, GroupSet, TileGeometry, available_strategies,
    get_strategy, register_strategy,
)
from repro.core.algorithm import (  # noqa: F401
    PruneEvent, PruneResult, lottery_baseline, prune_step, realprune,
)
