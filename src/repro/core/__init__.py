"""ReaLPrune core: the paper's contribution as a composable library.

crossbar.py — weight→crossbar unroll mapping + tile accounting
masks.py    — mask pytrees, prunability predicates
scoring.py  — filter/channel/index (+ltp/block/cap) group scoring
realprune.py— Algorithm 1 (iterative coarse→fine prune + rewind)
lottery.py  — winning-ticket snapshot/rewind/export
hardware.py — crossbar savings accounting (Figs 2 & 6)
perf_model.py — pipelined ReRAM execution model (Figs 7 & 8)
"""
from repro.core.masks import (  # noqa: F401
    apply_masks, cnn_is_conv, cnn_prunable, lm_prunable, make_masks,
    mask_grads, sparsity, sparsity_fraction,
)
from repro.core.algorithm import (  # noqa: F401
    PruneResult, lottery_baseline, prune_step, realprune,
)
