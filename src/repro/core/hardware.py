"""Hardware-savings accounting (paper Figs. 2 & 6).

'Hardware savings' = fraction of ReRAM cells that can be turned off or
reused; a cell qualifies only when its entire crossbar row or column is
zero (Fig. 2).  Crossbar *count* savings additionally assume freed
rows/columns can be repacked with other layers' live weights (the
paper's "reused for other purposes"): needed crossbars = ⌈live area /
crossbar area⌉, where live area per crossbar is live_rows × live_cols.

Training also stores activations (paper §IV.A): only *filter-wise*
pruning (a dead output unit) removes an activation, so activation
savings = fraction of dead output columns, weighted by each layer's
activation volume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import crossbar as xb
from repro.core.masks import path_str


@dataclass
class LayerHW:
    path: str
    stats: xb.XbarStats
    alive_outputs: int
    total_outputs: int
    activation_volume: float = 0.0   # elements per sample (for weighting)


@dataclass
class HWReport:
    layers: List[LayerHW] = field(default_factory=list)

    # ---- weights ----
    @property
    def total_cells(self):
        return sum(l.stats.total_cells for l in self.layers)

    @property
    def nonzero_cells(self):
        return sum(l.stats.nonzero_cells for l in self.layers)

    @property
    def saved_cells(self):
        return sum(l.stats.saved_cells for l in self.layers)

    @property
    def sparsity(self) -> float:
        return 1.0 - self.nonzero_cells / max(self.total_cells, 1)

    @property
    def cell_savings(self) -> float:
        """Paper's 'hardware savings' over weight cells."""
        return self.saved_cells / max(self.total_cells, 1)

    @property
    def xbars_unpruned(self) -> int:
        return sum(l.stats.n_xbars for l in self.layers)

    @property
    def xbars_needed(self) -> int:
        return sum(l.stats.xbars_needed_packed for l in self.layers)

    @property
    def xbars_needed_strict(self) -> int:
        return sum(l.stats.xbars_needed_strict for l in self.layers)

    @property
    def xbar_savings(self) -> float:
        return 1.0 - self.xbars_needed / max(self.xbars_unpruned, 1)

    # ---- activations ----
    @property
    def activation_savings(self) -> float:
        tot = sum(l.activation_volume for l in self.layers)
        if tot == 0:
            return 0.0
        dead = sum(l.activation_volume * (1 - l.alive_outputs
                                          / max(l.total_outputs, 1))
                   for l in self.layers)
        return dead / tot

    def combined_xbar_savings(self, act_cells_per_xbar: float = 16384.0,
                              act_weight: float = 1.0) -> float:
        """Crossbar savings counting weight + activation storage.

        Activations of layer l occupy ⌈volume/16384⌉ crossbars; only
        filter-pruned outputs are removed (paper §V.B: "fewer
        activations are pruned than weights").
        """
        w_base = self.xbars_unpruned
        w_need = self.xbars_needed
        a_base = a_need = 0.0
        for l in self.layers:
            if l.activation_volume <= 0:
                continue
            per_out = l.activation_volume / max(l.total_outputs, 1)
            a_base += np.ceil(l.activation_volume * act_weight
                              / act_cells_per_xbar)
            a_need += np.ceil(per_out * l.alive_outputs * act_weight
                              / act_cells_per_xbar)
        base, need = w_base + a_base, w_need + a_need
        return 1.0 - need / max(base, 1.0)


def analyze_masks(masks, conv_pred: Callable[[str], bool],
                  activation_volumes: Optional[Dict[str, float]] = None,
                  xbar_rows: int = xb.XBAR_ROWS,
                  xbar_cols: int = xb.XBAR_COLS) -> HWReport:
    """Crossbar accounting for every prunable leaf of a mask pytree.

    ``xbar_rows``/``xbar_cols`` set the crossbar geometry for the whole
    stats path (pass ``PruneConfig.xbar_rows/xbar_cols`` to match the
    geometry the masks were pruned with).
    """
    report = HWReport()
    vols = activation_volumes or {}

    def visit(path, leaf):
        if leaf is None:
            return leaf
        p = path_str(path)
        mats, _ = xb.leaf_matrices(np.asarray(leaf), conv_pred(p))
        agg = xb.XbarStats(xbar_rows=xbar_rows, xbar_cols=xbar_cols)
        alive_out = total_out = 0
        for b in range(mats.shape[0]):
            st = xb.xbar_stats(mats[b] != 0, xr=xbar_rows, xc=xbar_cols)
            agg.merge(st)
            alive_out += int(xb.alive_columns(mats[b] != 0).sum())
            total_out += mats[b].shape[1]
        report.layers.append(LayerHW(p, agg, alive_out, total_out,
                                     vols.get(p, 0.0)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return report


def cnn_activation_volumes(cfg) -> Dict[str, float]:
    """Activation elements per sample for each conv layer of a CNNConfig."""
    size = cfg.image_size
    vols = {}
    for i, spec in enumerate(cfg.convs):
        size = size // spec.stride if spec.stride > 1 else size
        vols[f"convs/{i}/w"] = float(size * size * spec.out_channels)
        if spec.pool:
            size //= 2
    return vols
