"""Hardware-savings accounting (paper Figs. 2 & 6).

'Hardware savings' = fraction of ReRAM cells that can be turned off or
reused; a cell qualifies only when its entire crossbar row or column is
zero (Fig. 2).  Crossbar *count* savings additionally assume freed
rows/columns can be repacked with other layers' live weights (the
paper's "reused for other purposes"): needed crossbars = ⌈live area /
crossbar area⌉, where live area per crossbar is live_rows × live_cols.

Training also stores activations (paper §IV.A): only *filter-wise*
pruning (a dead output unit) removes an activation, so activation
savings = fraction of dead output columns, weighted by each layer's
activation volume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import crossbar as xb
from repro.core.masks import path_str


@dataclass
class LayerHW:
    path: str
    stats: xb.XbarStats
    alive_outputs: int
    total_outputs: int
    activation_volume: float = 0.0   # elements per sample (for weighting)
    # per-out-channel quantization scale entries of the RAW leaf
    # (``core.quantize`` reduces over axis=-2, so a (kh,kw,cin,cout)
    # conv carries kh*kw*cout scales, not the cout of its unrolled view)
    scale_entries: int = 0


_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def dtype_bytes(dtype: Optional[str]) -> int:
    """Stored bytes per weight for a config ``dtype`` string (CNN
    configs carry no dtype and store float32)."""
    return _DTYPE_BYTES.get(dtype or "float32", 4)


@dataclass
class HWReport:
    layers: List[LayerHW] = field(default_factory=list)
    # fixed-point width an accepted quantize stage retrained at (None →
    # weights stored full precision); drives the byte accounting below
    quant_bits: Optional[int] = None
    # bytes per unquantized weight (2 for bfloat16 archs, 4 for the
    # float32 CNNs) — pass the config's dtype to analyze_masks
    dtype_bytes: int = 4

    # ---- weights ----
    @property
    def total_cells(self):
        return sum(l.stats.total_cells for l in self.layers)

    @property
    def nonzero_cells(self):
        return sum(l.stats.nonzero_cells for l in self.layers)

    @property
    def saved_cells(self):
        return sum(l.stats.saved_cells for l in self.layers)

    @property
    def sparsity(self) -> float:
        return 1.0 - self.nonzero_cells / max(self.total_cells, 1)

    @property
    def cell_savings(self) -> float:
        """Paper's 'hardware savings' over weight cells."""
        return self.saved_cells / max(self.total_cells, 1)

    @property
    def xbars_unpruned(self) -> int:
        return sum(l.stats.n_xbars for l in self.layers)

    @property
    def xbars_needed(self) -> int:
        return sum(l.stats.xbars_needed_packed for l in self.layers)

    @property
    def xbars_needed_strict(self) -> int:
        return sum(l.stats.xbars_needed_strict for l in self.layers)

    @property
    def xbar_savings(self) -> float:
        return 1.0 - self.xbars_needed / max(self.xbars_unpruned, 1)

    # ---- storage bytes (compose with packing, no double-count) ----
    def weight_bytes(self, bits: Optional[int] = None,
                     dtype_bytes: Optional[int] = None) -> Dict[str, float]:
        """Stored weight bytes: dense, pruned+packed, and (when a
        quantize stage ran) quantized+packed.

        Packing keeps only live cells, so pruned bytes count
        ``nonzero_cells`` — the quantized figure applies ``bits`` to
        those SAME live cells (plus one float32 scale per live
        per-out-channel scale entry), so pruning and quantization
        savings compose instead of double-counting.  ``bits`` defaults
        to the report's ``quant_bits``; ``dtype_bytes`` to the report's
        storage dtype (bfloat16 archs store 2 bytes per weight).
        """
        bits = self.quant_bits if bits is None else bits
        db = self.dtype_bytes if dtype_bytes is None else dtype_bytes
        out = {
            "dense_bytes": float(self.total_cells * db),
            "pruned_bytes": float(self.nonzero_cells * db),
            "dtype_bytes": db,
            "quant_bits": bits,
            "quantized_bytes": None,
        }
        if bits is not None:
            # scales for live output columns only (packing drops dead
            # ones, and a dead conv channel drops all kh*kw of its
            # scales with it); scales themselves are float32
            alive_scales = sum(
                l.scale_entries * l.alive_outputs / max(l.total_outputs, 1)
                for l in self.layers)
            out["quantized_bytes"] = float(
                self.nonzero_cells * bits / 8 + alive_scales * 4)
        return out

    # ---- activations ----
    @property
    def activation_savings(self) -> float:
        tot = sum(l.activation_volume for l in self.layers)
        if tot == 0:
            return 0.0
        dead = sum(l.activation_volume * (1 - l.alive_outputs
                                          / max(l.total_outputs, 1))
                   for l in self.layers)
        return dead / tot

    def combined_xbar_savings(self, act_cells_per_xbar: float = 16384.0,
                              act_weight: float = 1.0) -> float:
        """Crossbar savings counting weight + activation storage.

        Activations of layer l occupy ⌈volume/16384⌉ crossbars; only
        filter-pruned outputs are removed (paper §V.B: "fewer
        activations are pruned than weights").
        """
        w_base = self.xbars_unpruned
        w_need = self.xbars_needed
        a_base = a_need = 0.0
        for l in self.layers:
            if l.activation_volume <= 0:
                continue
            per_out = l.activation_volume / max(l.total_outputs, 1)
            a_base += np.ceil(l.activation_volume * act_weight
                              / act_cells_per_xbar)
            a_need += np.ceil(per_out * l.alive_outputs * act_weight
                              / act_cells_per_xbar)
        base, need = w_base + a_base, w_need + a_need
        return 1.0 - need / max(base, 1.0)


def analyze_masks(masks, conv_pred: Callable[[str], bool],
                  activation_volumes: Optional[Dict[str, float]] = None,
                  xbar_rows: int = xb.XBAR_ROWS,
                  xbar_cols: int = xb.XBAR_COLS,
                  quant_bits: Optional[int] = None,
                  dtype: Optional[str] = None) -> HWReport:
    """Crossbar accounting for every prunable leaf of a mask pytree.

    ``xbar_rows``/``xbar_cols`` set the crossbar geometry for the whole
    stats path (pass ``PruneConfig.xbar_rows/xbar_cols`` to match the
    geometry the masks were pruned with).  ``quant_bits`` records the
    fixed-point width of an accepted quantize stage and ``dtype`` the
    config's storage dtype, so ``HWReport.weight_bytes`` reports real
    quantized vs stored bytes (a bfloat16 arch stores 2 bytes/weight).
    """
    report = HWReport(quant_bits=quant_bits,
                      dtype_bytes=dtype_bytes(dtype))
    vols = activation_volumes or {}

    def visit(path, leaf):
        if leaf is None:
            return leaf
        p = path_str(path)
        raw = np.asarray(leaf)
        mats, _ = xb.leaf_matrices(raw, conv_pred(p))
        agg = xb.XbarStats(xbar_rows=xbar_rows, xbar_cols=xbar_cols)
        alive_out = total_out = 0
        for b in range(mats.shape[0]):
            st = xb.xbar_stats(mats[b] != 0, xr=xbar_rows, xc=xbar_cols)
            agg.merge(st)
            alive_out += int(xb.alive_columns(mats[b] != 0).sum())
            total_out += mats[b].shape[1]
        scales = raw.size // raw.shape[-2] if raw.ndim >= 2 else 0
        report.layers.append(LayerHW(p, agg, alive_out, total_out,
                                     vols.get(p, 0.0),
                                     scale_entries=scales))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return report


def cnn_activation_volumes(cfg) -> Dict[str, float]:
    """Activation elements per sample for each conv layer of a CNNConfig."""
    size = cfg.image_size
    vols = {}
    for i, spec in enumerate(cfg.convs):
        size = size // spec.stride if spec.stride > 1 else size
        vols[f"convs/{i}/w"] = float(size * size * spec.out_channels)
        if spec.pool:
            size //= 2
    return vols
