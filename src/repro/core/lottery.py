"""Lottery-ticket utilities: rewind snapshots and winning-ticket export.

The winning ticket is (w_initial, masks).  ``export_ticket`` /
``import_ticket`` serialise it with numpy so a ticket pruned once can be
"made available publicly ... and reused for training any number of
times" (paper §V.C).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import apply_masks, path_str


def snapshot(params):
    """Host-side copy of w_initial (t = 0)."""
    return jax.tree.map(lambda x: np.asarray(x).copy(), params)


def rewind(w_init, masks):
    """Winning-ticket weights: w_initial ⊙ mask."""
    return apply_masks(jax.tree.map(jnp.asarray, w_init), masks)


def export_ticket(path: str, w_init, masks, meta: Optional[dict] = None):
    """Serialise (w_init, masks) plus optional JSON metadata.

    ``meta`` (e.g. the resolved prune recipe, quantization bits) is
    embedded in ``ticket.json`` so a ticket is reproducible from its
    checkpoint alone — ``ticket_meta`` reads it back.
    """
    os.makedirs(path, exist_ok=True)
    flat = {}

    def visit(prefix, tree, store):
        def f(p, leaf):
            if leaf is not None:
                store[f"{prefix}:{path_str(p)}"] = np.asarray(leaf)
            return leaf
        jax.tree_util.tree_map_with_path(f, tree,
                                         is_leaf=lambda x: x is None)

    visit("w", w_init, flat)
    visit("m", masks, flat)
    np.savez_compressed(os.path.join(path, "ticket.npz"), **flat)
    treedef = jax.tree_util.tree_structure(
        masks, is_leaf=lambda x: x is None)
    with open(os.path.join(path, "ticket.json"), "w") as f:
        json.dump({"treedef": str(treedef), "meta": meta or {}}, f)


def ticket_meta(path: str) -> dict:
    """Metadata embedded at export time ({} for pre-metadata tickets)."""
    fname = os.path.join(path, "ticket.json")
    if not os.path.exists(fname):
        return {}
    with open(fname) as f:
        return json.load(f).get("meta", {}) or {}


def import_ticket(path: str, params_template, masks_template):
    """Load a ticket into pytrees shaped like the given templates."""
    data = np.load(os.path.join(path, "ticket.npz"))

    def load(prefix, template):
        def f(p, leaf):
            key = f"{prefix}:{path_str(p)}"
            if leaf is None:
                return None
            return jnp.asarray(data[key]) if key in data else leaf
        return jax.tree_util.tree_map_with_path(
            f, template, is_leaf=lambda x: x is None)

    return load("w", params_template), load("m", masks_template)
