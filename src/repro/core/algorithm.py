"""ReaLPrune — Algorithm 1 of the paper.

    Input : model, pruning percentage p
    Output: pruned model (masks + rewound weights)
    1: w ← w_initial
    2: while itr < MAX_ITER and no accuracy drop:
    3:     Train for E epochs
    4:     Prune(p) by crossbar structure + weight magnitude
    5:     if new_accuracy < baseline_accuracy:
    6:         undo last pruning step
    7:         switch to finer pruning strategy
    8:     reinitialize remaining weights with w_initial
    return pruned model

The loop itself lives in ``repro.api.session.PruningSession`` (which
adds streaming events, checkpoint/resume, and ticket handoff);
``realprune`` / ``lottery_baseline`` here are thin compatibility shims
that wrap caller-supplied ``train_fn``/``eval_fn`` closures in a
``FunctionAdapter`` and run a session.  ``prune_step`` — one
crossbar-aware prune at a named granularity — remains the shared
primitive.  Pruning decisions run host-side (numpy) — pruning is a
one-time offline effort (paper §V.C); training/eval run in JAX.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig
from repro.core import masks as masks_lib
from repro.core import scoring
from repro.core.masks import path_str, sparsity_fraction
from repro.core.strategies import TileGeometry

log = logging.getLogger("realprune")


@dataclass
class PruneEvent:
    iteration: int
    granularity: str
    sparsity_before: float
    sparsity_after: float
    accuracy: float
    accepted: bool
    # recipe-interpreter provenance: which stage of the prune program
    # produced this event ("" / 0 / "prune" for legacy flat schedules)
    stage: str = ""
    stage_idx: int = 0
    kind: str = "prune"              # prune | quantize | ablate
    # data-parallel retrain comm accounting (mask-aware gradient
    # compression): fraction of grad coordinates shipped per exchange
    # and the resulting bytes on the wire per step (0 when the adapter
    # retrains without a compressor)
    comm_sent_fraction: float = 0.0
    comm_bytes_per_step: int = 0


@dataclass
class PruneResult:
    masks: dict
    params: dict                     # rewound to w_init ⊙ mask
    history: List[PruneEvent] = field(default_factory=list)
    # resolved recipe dict the session ran (embedded in exported tickets)
    recipe: Optional[dict] = None

    @property
    def sparsity(self) -> float:
        return sparsity_fraction(self.masks)

    def stage_events(self, stage_idx: int) -> List[PruneEvent]:
        return [e for e in self.history if e.stage_idx == stage_idx]

    @property
    def ablation(self) -> List[PruneEvent]:
        """The schedule-ablation table rows (events from ablate stages)."""
        return [e for e in self.history if e.kind == "ablate"]


def _leaf_items(params, masks, prunable_conv: Callable[[str], bool]):
    """[(path, np weight, np mask, is_conv)] for prunable leaves."""
    flat_p = {}

    def visit(path, leaf):
        flat_p[path_str(path)] = np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    items = []

    def visit_m(path, leaf):
        if leaf is not None:
            p = path_str(path)
            items.append((p, flat_p[p], np.asarray(leaf), prunable_conv(p)))
        return leaf

    jax.tree_util.tree_map_with_path(visit_m, masks,
                                     is_leaf=lambda x: x is None)
    return items


def prune_step(params, masks, granularity: str, fraction: float,
               conv_pred: Callable[[str], bool], block: int = 32,
               geometry: Optional[TileGeometry] = None):
    """One crossbar-aware prune of ``fraction`` of remaining weights."""
    items = _leaf_items(params, masks, conv_pred)
    group_sets = [scoring.group_scores(p, w, m, granularity, conv,
                                       block=block, geometry=geometry)
                  for (p, w, m, conv) in items]
    remaining = sum(int(m.sum()) for (_, _, m, _) in items)
    kills = scoring.select_global_prune(group_sets, fraction, remaining)
    gs_by_path = {gs.path: gs for gs in group_sets}
    new_masks = masks
    for path, kill in kills.items():
        gs = gs_by_path[path]
        old = np.asarray(
            _get_by_path(masks, path))
        new_leaf = scoring.zero_groups(old, gs, kill)
        new_masks = masks_lib.tree_set(new_masks, path,
                                       jnp.asarray(new_leaf, jnp.float32))
    return new_masks


def _get_by_path(tree, path: str):
    node = tree
    for k in path.split("/"):
        if isinstance(node, dict):
            node = node[k]
        else:
            node = node[int(k)]
    return node


def realprune(
    *,
    init_params,
    train_fn: Callable,            # (params, masks) -> trained params
    eval_fn: Callable,             # (params, masks) -> accuracy (float)
    prunable: Callable,            # (path, leaf) -> bool
    conv_pred: Callable,           # (path) -> bool: leaf is a conv kernel
    cfg: PruneConfig,
    baseline_accuracy: Optional[float] = None,
    granularities: Optional[Sequence[str]] = None,
) -> PruneResult:
    """Run Algorithm 1 and return the sparsest no-accuracy-drop model.

    Compatibility shim over ``repro.api.PruningSession`` — prefer the
    session API (adapters, events, checkpoint/resume) in new code.
    """
    from repro.api.adapters import FunctionAdapter
    from repro.api.session import PruningSession

    adapter = FunctionAdapter(params=init_params, train_fn=train_fn,
                              eval_fn=eval_fn, prunable=prunable,
                              conv_pred=conv_pred)
    return PruningSession(adapter, cfg, granularities=granularities,
                          baseline_accuracy=baseline_accuracy).run()


def lottery_baseline(*, init_params, train_fn, eval_fn, prunable, conv_pred,
                     cfg: PruneConfig, method: str,
                     baseline_accuracy: Optional[float] = None) -> PruneResult:
    """Iterative single-granularity baselines: LTP / Block / CAP.

    Same loop as Algorithm 1 but with one granularity and no
    coarse-to-fine switch (the paper's baselines, §V.A: 25% of the
    remaining weights pruned per iteration, iterated to the sparsest
    no-accuracy-drop model).
    """
    gran = {"ltp": "ltp", "block": "block", "cap": "cap"}[method]
    return realprune(init_params=init_params, train_fn=train_fn,
                     eval_fn=eval_fn, prunable=prunable, conv_pred=conv_pred,
                     cfg=cfg, baseline_accuracy=baseline_accuracy,
                     granularities=[gran])
