"""ReaLPrune — Algorithm 1 of the paper.

    Input : model, pruning percentage p
    Output: pruned model (masks + rewound weights)
    1: w ← w_initial
    2: while itr < MAX_ITER and no accuracy drop:
    3:     Train for E epochs
    4:     Prune(p) by crossbar structure + weight magnitude
    5:     if new_accuracy < baseline_accuracy:
    6:         undo last pruning step
    7:         switch to finer pruning strategy
    8:     reinitialize remaining weights with w_initial
    return pruned model

The engine is model-agnostic: callers supply ``train_fn`` and
``eval_fn`` closures plus a prunability predicate.  Pruning decisions
run host-side (numpy) — pruning is a one-time offline effort (paper
§V.C); training/eval run in JAX.
"""
from __future__ import annotations

import dataclasses
import logging
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig
from repro.core import masks as masks_lib
from repro.core import scoring
from repro.core.masks import apply_masks, path_str, sparsity_fraction

log = logging.getLogger("realprune")


@dataclass
class PruneEvent:
    iteration: int
    granularity: str
    sparsity_before: float
    sparsity_after: float
    accuracy: float
    accepted: bool


@dataclass
class PruneResult:
    masks: dict
    params: dict                     # rewound to w_init ⊙ mask
    history: List[PruneEvent] = field(default_factory=list)

    @property
    def sparsity(self) -> float:
        return sparsity_fraction(self.masks)


def _leaf_items(params, masks, prunable_conv: Callable[[str], bool]):
    """[(path, np weight, np mask, is_conv)] for prunable leaves."""
    flat_p = {}

    def visit(path, leaf):
        flat_p[path_str(path)] = np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    items = []

    def visit_m(path, leaf):
        if leaf is not None:
            p = path_str(path)
            items.append((p, flat_p[p], np.asarray(leaf), prunable_conv(p)))
        return leaf

    jax.tree_util.tree_map_with_path(visit_m, masks,
                                     is_leaf=lambda x: x is None)
    return items


def prune_step(params, masks, granularity: str, fraction: float,
               conv_pred: Callable[[str], bool], block: int = 32):
    """One crossbar-aware prune of ``fraction`` of remaining weights."""
    items = _leaf_items(params, masks, conv_pred)
    group_sets = [scoring.group_scores(p, w, m, granularity, conv)
                  for (p, w, m, conv) in items]
    remaining = sum(int(m.sum()) for (_, _, m, _) in items)
    kills = scoring.select_global_prune(group_sets, fraction, remaining)
    gs_by_path = {gs.path: gs for gs in group_sets}
    new_masks = masks
    for path, kill in kills.items():
        gs = gs_by_path[path]
        old = np.asarray(
            _get_by_path(masks, path))
        new_leaf = scoring.zero_groups(old, gs, kill)
        new_masks = masks_lib.tree_set(new_masks, path,
                                       jnp.asarray(new_leaf, jnp.float32))
    return new_masks


def _get_by_path(tree, path: str):
    node = tree
    for k in path.split("/"):
        if isinstance(node, dict):
            node = node[k]
        else:
            node = node[int(k)]
    return node


def realprune(
    *,
    init_params,
    train_fn: Callable,            # (params, masks) -> trained params
    eval_fn: Callable,             # (params, masks) -> accuracy (float)
    prunable: Callable,            # (path, leaf) -> bool
    conv_pred: Callable,           # (path) -> bool: leaf is a conv kernel
    cfg: PruneConfig,
    baseline_accuracy: Optional[float] = None,
    granularities: Optional[Sequence[str]] = None,
) -> PruneResult:
    """Run Algorithm 1 and return the sparsest no-accuracy-drop model."""
    w_init = jax.tree.map(lambda x: x, init_params)     # t=0 snapshot
    masks = masks_lib.make_masks(init_params, prunable)
    grans = list(granularities or cfg.granularities)
    g_idx = 0
    history: List[PruneEvent] = []

    if baseline_accuracy is None:
        trained = train_fn(w_init, masks)
        baseline_accuracy = float(eval_fn(trained, masks))
        log.info("baseline accuracy: %.4f", baseline_accuracy)

    params = w_init
    best = (masks, 0.0)
    itr = 0
    while itr < cfg.max_iters and g_idx < len(grans):
        itr += 1
        trained = train_fn(params, masks)                       # line 3
        cand = prune_step(trained, masks, grans[g_idx],          # line 4
                          cfg.prune_fraction, conv_pred)
        cand_params = apply_masks(trained, cand)
        acc = float(eval_fn(cand_params, cand))                  # line 5
        s_before = sparsity_fraction(masks)
        s_after = sparsity_fraction(cand)
        ok = acc >= baseline_accuracy - cfg.accuracy_tolerance
        history.append(PruneEvent(itr, grans[g_idx], s_before, s_after,
                                  acc, ok))
        log.info("iter %d [%s] sparsity %.3f->%.3f acc %.4f (%s)", itr,
                 grans[g_idx], s_before, s_after, acc,
                 "keep" if ok else "undo")
        if ok:
            masks = cand
            if s_after > best[1]:
                best = (cand, s_after)
        else:
            g_idx += 1                                           # lines 6-7
        params = apply_masks(w_init, masks)                      # line 8
    final_params = apply_masks(w_init, masks)
    return PruneResult(masks=masks, params=final_params, history=history)


def lottery_baseline(*, init_params, train_fn, eval_fn, prunable, conv_pred,
                     cfg: PruneConfig, method: str,
                     baseline_accuracy: Optional[float] = None) -> PruneResult:
    """Iterative single-granularity baselines: LTP / Block / CAP.

    Same loop as Algorithm 1 but with one granularity and no
    coarse-to-fine switch (the paper's baselines, §V.A: 25% of the
    remaining weights pruned per iteration, iterated to the sparsest
    no-accuracy-drop model).
    """
    gran = {"ltp": "ltp", "block": "block", "cap": "cap"}[method]
    return realprune(init_params=init_params, train_fn=train_fn,
                     eval_fn=eval_fn, prunable=prunable, conv_pred=conv_pred,
                     cfg=cfg, baseline_accuracy=baseline_accuracy,
                     granularities=[gran])
