"""Sparsity statistics over mask pytrees."""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.core.masks import path_str


def per_leaf_sparsity(masks) -> Dict[str, float]:
    out = {}

    def visit(path, leaf):
        if leaf is not None:
            m = np.asarray(leaf)
            out[path_str(path)] = 1.0 - float(m.sum()) / m.size
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return out


def summary(masks) -> Dict[str, float]:
    total = nz = 0
    for m in jax.tree.leaves(masks):
        if m is None:
            continue
        m = np.asarray(m)
        total += m.size
        nz += float(m.sum())
    return {
        "prunable_weights": total,
        "nonzero_weights": nz,
        "sparsity": 1.0 - nz / max(total, 1),
        "remaining_fraction": nz / max(total, 1),
    }
