"""Serving control plane: structured admission, streaming, deadlines,
ticket manager verification, and zero-drain hot-swap equivalence."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core import lottery
from repro.core.masks import apply_masks, lm_prunable
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.models import transformer as tfm
from repro.serve import (Request, ServeEngine, ServeFrontend,
                         SubmitRejected, TicketError, TicketManager,
                         TicketMismatch)

CAP = 96


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks_a = structured_prune(params, [("filter", 0.2)],
                               prunable=lm_prunable, cfg=PruneConfig())
    masks_b = structured_prune(params, [("xbar", 0.4), ("filter", 0.3)],
                               prunable=lm_prunable, cfg=PruneConfig())
    return cfg, params, masks_a, masks_b


@pytest.fixture(scope="module")
def tickets(setup, tmp_path_factory):
    """Two exported tickets (different prune rates) + templates."""
    cfg, params, masks_a, masks_b = setup
    root = tmp_path_factory.mktemp("tickets")
    meta = {"arch": cfg.name, "recipe": {"name": "paper"},
            "quantize_bits": None}
    lottery.export_ticket(str(root / "a"), lottery.snapshot(params),
                          masks_a, meta=meta)
    lottery.export_ticket(str(root / "b"), lottery.snapshot(params),
                          masks_b, meta=meta)
    return root


def _engine(cfg, params, masks=None, slots=4, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=slots,
                       capacity=CAP, masks=masks, **kw)


def _manager(cfg, params, **kw):
    return TicketManager(cfg=cfg, params_template=params,
                         prunable=lm_prunable, prefill_fn=tfm.prefill,
                         decode_fn=tfm.decode_step, probe_tokens=6, **kw)


def _reqs(n, budget=6):
    return [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    max_new_tokens=budget) for i in range(n)]


# ---------------------------------------------------------------------------
# structured admission rejection
# ---------------------------------------------------------------------------
def test_submit_rejections_carry_machine_readable_reasons(setup):
    cfg, params, *_ = setup
    eng = _engine(cfg, params, queue_limit=1)
    with pytest.raises(SubmitRejected) as e:
        eng.submit(Request(uid=0, prompt=np.zeros((0,), np.int32)))
    assert e.value.reason == "empty_prompt" and not e.value.retryable
    with pytest.raises(SubmitRejected) as e:
        eng.submit(Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=0))
    assert e.value.reason == "bad_budget"
    with pytest.raises(SubmitRejected) as e:
        # paged admission stretches the static limit to max_context
        eng.submit(Request(uid=2,
                           prompt=np.arange(eng.max_context,
                                            dtype=np.int32) % 64,
                           max_new_tokens=4))
    assert e.value.reason == "oversize"
    eng.submit(Request(uid=3, prompt=np.arange(1, 8, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(SubmitRejected) as e:       # bounded intake queue
        eng.submit(Request(uid=4, prompt=np.arange(1, 8, dtype=np.int32),
                           max_new_tokens=2))
    assert e.value.reason == "capacity" and e.value.retryable
    eng.set_health(False, "wedged decode loop")
    with pytest.raises(SubmitRejected) as e:
        eng.submit(Request(uid=5, prompt=np.arange(1, 8, dtype=np.int32)))
    assert e.value.reason == "unhealthy"
    # rejections never entered the queue
    assert len(eng.queue) == 1


def test_frontend_parks_only_capacity_and_drains_fifo(setup):
    """Capacity rejections park in the bounded wait queue and drain in
    submission order; structural rejections re-raise immediately."""
    cfg, params, *_ = setup
    eng = _engine(cfg, params, slots=1, queue_limit=1)
    fe = ServeFrontend(eng, max_queue=3)
    handles = [fe.submit(request=r) for r in _reqs(4, budget=3)]
    assert [h.status for h in handles] == \
        ["queued", "waiting", "waiting", "waiting"]
    # structural rejection raises even while capacity requests wait
    with pytest.raises(SubmitRejected) as e:
        fe.submit(np.zeros((0,), np.int32))
    assert e.value.reason == "empty_prompt"
    # the wait queue itself is bounded: overflow re-raises capacity
    with pytest.raises(SubmitRejected) as e:
        fe.submit(np.arange(1, 8, dtype=np.int32), uid=9)
    assert e.value.reason == "capacity"
    fe.drain()
    assert [r.uid for r in fe.finished] == [0, 1, 2, 3]   # FIFO
    assert all(len(r.tokens) == 3 and r.status == "done"
               for r in fe.finished)


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------
def test_stream_handle_yields_each_token_once(setup):
    cfg, params, *_ = setup
    eng = _engine(cfg, params, slots=2)
    fe = ServeFrontend(eng)
    seen = []
    h = fe.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=5,
                  on_token=seen.append)
    streamed = list(h)
    assert len(streamed) == 5
    assert streamed == h.request.tokens == seen
    assert h.status == "done"
    # streaming matches a plain batch run of the same request
    eng2 = _engine(cfg, params, slots=2)
    eng2.submit(Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=5))
    assert eng2.run()[0].tokens == streamed


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_expiry_frees_slot_and_later_requests_unaffected(setup):
    cfg, params, *_ = setup
    t = {"now": 0.0}
    eng = _engine(cfg, params, slots=1, clock=lambda: t["now"])
    fe = ServeFrontend(eng)
    doomed = fe.submit(np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=50, deadline_s=5.0)
    fe.pump(2)
    assert doomed.status == "active" and 0 < len(doomed.tokens) < 50
    t["now"] = 10.0                      # past the deadline mid-decode
    fe.pump(1)
    assert doomed.status == "expired" and doomed.request.done
    assert eng.report.deadline_misses == 1
    # the slot is free again: a later request decodes to completion and
    # matches a run on a fresh engine (no contamination)
    after = fe.submit(np.arange(2, 10, dtype=np.int32), uid=7,
                      max_new_tokens=4)
    fe.drain()
    assert after.status == "done" and len(after.tokens) == 4
    eng2 = _engine(cfg, params, slots=1)
    eng2.submit(Request(uid=7, prompt=np.arange(2, 10, dtype=np.int32),
                        max_new_tokens=4))
    assert eng2.run()[0].tokens == after.request.tokens


def test_deadline_expiry_in_wait_queue_counts_as_miss(setup):
    cfg, params, *_ = setup
    t = {"now": 0.0}
    eng = _engine(cfg, params, slots=1, queue_limit=1,
                  clock=lambda: t["now"])
    fe = ServeFrontend(eng)
    fe.submit(request=Request(uid=0,
                              prompt=np.arange(1, 9, dtype=np.int32),
                              max_new_tokens=3))
    waiting = fe.submit(np.arange(1, 9, dtype=np.int32), uid=1,
                        max_new_tokens=3, deadline_s=2.0)
    assert waiting.status == "waiting"
    t["now"] = 5.0
    fe.drain()
    assert waiting.status == "expired" and waiting.tokens == []
    assert eng.report.deadline_misses == 1
    assert [r.uid for r in fe.finished if r.status == "done"] == [0]


# ---------------------------------------------------------------------------
# zero-drain hot-swap (the acceptance-criterion demo, as a test)
# ---------------------------------------------------------------------------
def test_hot_swap_zero_drain_equivalence(setup):
    """With requests in flight, swap(ticket_b): in-flight outputs are
    bit-identical to the no-swap oracle, the next admission decodes
    under ticket B's tile plans, and the skipped-tile stats differ
    between the two tickets."""
    cfg, params, masks_a, masks_b = setup
    pa, pb = apply_masks(params, masks_a), apply_masks(params, masks_b)

    oracle_eng = _engine(cfg, pa, masks=masks_a)
    for r in _reqs(4, budget=8):
        oracle_eng.submit(r)
    oracle = {r.uid: list(r.tokens) for r in oracle_eng.run()}
    skip_a = oracle_eng.report.skipped_tile_fraction

    eng = _engine(cfg, pa, masks=masks_a)
    for r in _reqs(4, budget=8):
        eng.submit(r)
    for _ in range(3):
        eng.step()                        # all four requests mid-decode
    gid = eng.swap(pb, masks=masks_b)
    probe = Request(uid=99, prompt=np.arange(2, 10, dtype=np.int32),
                    max_new_tokens=6)
    eng.submit(probe)
    done = {r.uid: r for r in eng.run()}

    # in-flight requests: bit-identical to the swap-free oracle
    for uid, toks in oracle.items():
        assert done[uid].generation == 0
        assert done[uid].tokens == toks
    # the post-swap admission ran on ticket B's generation and matches
    # a request served on a B-only engine
    assert probe.generation == gid
    solo = _engine(cfg, pb, masks=masks_b)
    solo.submit(Request(uid=99, prompt=np.arange(2, 10, dtype=np.int32),
                        max_new_tokens=6))
    assert solo.run()[0].tokens == probe.tokens
    # observable proof the plans changed: skipped-tile stats differ
    rep = eng.report
    assert rep.swaps == 1
    assert rep.skipped_tile_fraction != skip_a
    assert rep.skipped_tile_fraction == \
        solo.report.skipped_tile_fraction


def test_rollback_restores_previous_generation(setup):
    cfg, params, masks_a, masks_b = setup
    pa, pb = apply_masks(params, masks_a), apply_masks(params, masks_b)
    eng = _engine(cfg, pa, masks=masks_a)
    before = eng.smoke_decode(np.arange(1, 9, dtype=np.int32), 4)
    gid = eng.swap(pb, masks=masks_b)
    eng.rollback(gid)
    assert eng.current_generation == 0
    assert eng.report.swaps == 0
    assert eng.smoke_decode(np.arange(1, 9, dtype=np.int32), 4) == before
    # a generation that served traffic cannot be rolled back
    gid = eng.swap(pb, masks=masks_b)
    eng.submit(Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=2))
    eng.step()
    with pytest.raises(RuntimeError, match="served"):
        eng.rollback(gid)


# ---------------------------------------------------------------------------
# ticket manager
# ---------------------------------------------------------------------------
def test_manager_registers_fingerprints_and_swaps_verified(setup,
                                                           tickets):
    cfg, params, *_ = setup
    mgr = _manager(cfg, params)
    rec_a = mgr.register("a", str(tickets / "a"))
    rec_b = mgr.register("b", str(tickets / "b"))
    assert len(rec_a.fingerprint) == 6
    assert rec_a.recipe_name == "paper"
    assert rec_a.fingerprint != rec_b.fingerprint

    eng = mgr.make_engine("a", batch_slots=2, capacity=CAP)
    for r in _reqs(2, budget=6):
        eng.submit(r)
    eng.step()                            # traffic in flight
    ev = mgr.swap(eng, "b")
    assert ev.accepted and ev.reason == "ok"
    assert mgr.active == "b"
    assert eng.current_generation == ev.gid
    eng.run()
    assert all(len(r.tokens) == 6 for r in eng._finished)


def test_manager_rejects_arch_recipe_and_shape_mismatch(setup, tickets,
                                                        tmp_path):
    cfg, params, masks_a, _ = setup
    # arch mismatch: metadata names a different architecture
    lottery.export_ticket(str(tmp_path / "other"),
                          lottery.snapshot(params), masks_a,
                          meta={"arch": "some-other-arch",
                                "recipe": {"name": "paper"}})
    mgr = _manager(cfg, params)
    with pytest.raises(TicketError) as e:
        mgr.register("other", str(tmp_path / "other"))
    assert e.value.reason == "arch_mismatch"
    # recipe mismatch: deployment pinned to another recipe name
    strict = _manager(cfg, params, expect_recipe="paper-quant")
    with pytest.raises(TicketError) as e:
        strict.register("a", str(tickets / "a"))
    assert e.value.reason == "recipe_mismatch"
    # shape mismatch: corrupt one stored mask's shape
    import shutil
    shutil.copytree(str(tickets / "a"), str(tmp_path / "bad"))
    data = dict(np.load(str(tmp_path / "bad" / "ticket.npz")))
    key = next(k for k in data if k.startswith("m:"))
    data[key] = data[key][..., :-1]
    np.savez_compressed(str(tmp_path / "bad" / "ticket.npz"), **data)
    with pytest.raises(TicketMismatch) as e:
        mgr.register("bad", str(tmp_path / "bad"))
    assert e.value.reason == "shape_mismatch"
    # swap of an unregistered name is refused
    mgr.register("a", str(tickets / "a"))
    eng = mgr.make_engine("a", batch_slots=2, capacity=CAP)
    with pytest.raises(TicketError) as e:
        mgr.swap(eng, "nope")
    assert e.value.reason == "unknown_ticket"


def test_manager_rolls_back_on_fingerprint_mismatch(setup, tickets):
    """A candidate whose live smoke-decode disagrees with its recorded
    fingerprint is rolled back; in-flight traffic still matches the
    no-swap oracle afterwards."""
    cfg, params, masks_a, _ = setup
    pa = apply_masks(params, masks_a)
    oracle_eng = _engine(cfg, pa, masks=masks_a)
    for r in _reqs(2, budget=6):
        oracle_eng.submit(r)
    oracle = {r.uid: list(r.tokens) for r in oracle_eng.run()}

    mgr = _manager(cfg, params)
    mgr.register("a", str(tickets / "a"))
    rec_b = mgr.register("b", str(tickets / "b"))
    rec_b.fingerprint = tuple(t + 1 for t in rec_b.fingerprint)  # corrupt

    eng = mgr.make_engine("a", batch_slots=2, capacity=CAP)
    for r in _reqs(2, budget=6):
        eng.submit(r)
    eng.step()
    ev = mgr.swap(eng, "b")
    assert not ev.accepted and "rolled back" in ev.reason
    assert ev.observed != ev.expected
    assert mgr.active == "a"
    assert eng.current_generation == 0    # generation discarded
    assert eng.report.swaps == 0
    done = {r.uid: r.tokens for r in eng.run()}
    assert done == oracle


# ---------------------------------------------------------------------------
# heartbeat → health gate
# ---------------------------------------------------------------------------
def test_stale_heartbeat_closes_admission_and_recovers(setup, tmp_path):
    cfg, params, *_ = setup
    hb = HeartbeatMonitor(str(tmp_path / "hb"), deadline_s=0.05)
    eng = _engine(cfg, params, slots=2, heartbeat=hb)
    fe = ServeFrontend(eng)
    fe.submit(np.arange(1, 9, dtype=np.int32), max_new_tokens=2)
    fe.drain()                            # engine ticked → beat written
    assert hb.age("engine") is not None
    time.sleep(0.12)                      # decode loop "wedges"
    with pytest.raises(SubmitRejected) as e:
        fe.submit(np.arange(1, 9, dtype=np.int32), uid=5)
    assert e.value.reason == "unhealthy"
    assert not eng.health.healthy
    eng.step()                            # loop resumes → fresh beat
    h = fe.submit(np.arange(1, 9, dtype=np.int32), uid=6,
                  max_new_tokens=3)
    assert eng.health.healthy             # gate reopened automatically
    fe.drain()
    assert h.status == "done" and len(h.tokens) == 3


# ---------------------------------------------------------------------------
# encdec (audio) serve lane
# ---------------------------------------------------------------------------
def test_encdec_frames_lane_matches_full_forward_greedy():
    from repro.api.adapters import EncDecAdapter
    from repro.models import encdec

    cfg = scaled_down(get_arch("whisper-tiny"), dtype="float32")
    adapter = EncDecAdapter(cfg)
    params = adapter.init_params(jax.random.PRNGKey(0))
    prefill_fn, decode_fn = adapter.serve_fns()
    eng = ServeEngine(params=params, cfg=cfg, prefill_fn=prefill_fn,
                      decode_fn=decode_fn, batch_slots=2, capacity=32)
    reqs = [Request(uid=i, prompt=np.arange(1 + i, 5 + i, dtype=np.int32),
                    max_new_tokens=4, frames=adapter.serve_frames(i))
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    got = {r.uid: r.tokens for r in eng.run()}
    assert all(len(t) == 4 for t in got.values())
    # KV-cached engine decode == token-by-token full forward
    for i in range(3):
        frames = adapter.serve_frames(i)[None]
        ctx = list(np.arange(1 + i, 5 + i, dtype=np.int32))
        toks = []
        for _ in range(4):
            lg, _ = encdec.forward(
                params, cfg,
                {"frames": jnp.asarray(frames),
                 "tokens": jnp.asarray(np.asarray(ctx, np.int32)[None])})
            nxt = int(jnp.argmax(lg[0, -1]))
            toks.append(nxt)
            ctx.append(nxt)
        assert got[i] == toks


def test_registry_audio_family_serves():
    from repro.api.registry import make_adapter, resolve_config
    _, spec = resolve_config("whisper-tiny")
    assert spec.serves
    adapter = make_adapter("whisper-tiny", scale="tiny")
    prefill_fn, decode_fn = adapter.serve_fns()
    assert callable(prefill_fn) and callable(decode_fn)


# ---------------------------------------------------------------------------
# latency metrics
# ---------------------------------------------------------------------------
def test_report_latency_percentiles_populated(setup):
    cfg, params, *_ = setup
    eng = _engine(cfg, params, slots=2)
    fe = ServeFrontend(eng)
    for r in _reqs(4, budget=4):
        fe.submit(request=r)
    fe.drain()
    rep = eng.report
    assert rep.requests == 4
    assert rep.ttft_p95 >= rep.ttft_p50 > 0
    assert rep.tps_p95 >= rep.tps_p50 > 0
    assert rep.deadline_misses == 0 and rep.swaps == 0
    for r in fe.finished:
        assert r.ttft is not None and r.ttft > 0
