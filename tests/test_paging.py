"""Paged KV cache: block-pool discipline, paged-attention kernel vs its
dense oracle, and paged-vs-dense serve-engine oracles (ragged batches,
hot-swap mid-stream, long-prompt admission).

The ``hypothesis`` property test soft-skips when the optional dev extra
is absent (mirroring ``test_property.py``); a deterministic randomized
lifecycle test covers the same pool discipline in the bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import verify_block_pool
from repro.configs import get_arch, scaled_down
from repro.kernels.paged_attention import (BLOCK_TOKENS, paged_attention,
                                           paged_attention_ref, paged_gather)
from repro.models import transformer as tfm
from repro.serve import BlockPool, PoolError, Request, ServeEngine
from repro.serve.engine import _default_buckets
from repro.serve.paging import blocks_needed

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

CAP = 48     # engine capacity chosen < BLOCK_TOKENS so paging is load-bearing


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, slots=3, capacity=CAP, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=slots,
                       capacity=capacity, **kw)


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    return {r.uid: r.tokens for r in done}


def _ragged_requests(cfg, n=7, seed=1, max_new=6):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=rng.randint(4, 14)
                                       ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


# ---------------------------------------------------------------------------
# BlockPool discipline
# ---------------------------------------------------------------------------
def _assert_pool_clean(pool):
    pool.check()
    findings = verify_block_pool(pool, where="test")
    assert not findings, [str(f) for f in findings]


def test_pool_reserve_alloc_release_roundtrip():
    pool = BlockPool(8)
    assert pool.available == 7          # block 0 is scratch
    pool.reserve(1, 3)
    assert pool.available == 4 and pool.outstanding == 3
    a, b = pool.alloc(1), pool.alloc(1)
    assert a != b and 0 not in (a, b)
    assert pool.owned(1) == (a, b)      # logical allocation order
    assert pool.live == 2 and pool.peak == 2
    _assert_pool_clean(pool)
    freed = pool.release(1)
    assert freed == (a, b)
    assert pool.live == 0 and pool.available == 7 and pool.outstanding == 0
    _assert_pool_clean(pool)


def test_pool_rejects_misuse():
    pool = BlockPool(4)
    with pytest.raises(PoolError, match="cannot reserve"):
        pool.reserve(1, 4)              # only 3 non-scratch blocks
    pool.reserve(1, 1)
    with pytest.raises(PoolError, match="already admitted"):
        pool.reserve(1, 1)
    with pytest.raises(PoolError, match="not admitted"):
        pool.alloc(9)
    pool.alloc(1)
    with pytest.raises(PoolError, match="exhausted"):
        pool.alloc(1)                   # reservation was 1 block
    with pytest.raises(PoolError, match="not admitted"):
        pool.release(9)
    with pytest.raises(ValueError, match="positive"):
        pool.reserve(2, 0)


def test_pool_reservations_guarantee_allocs():
    """Two half-admitted requests can never strand each other: once a
    reservation fits, every alloc it covers must succeed."""
    pool = BlockPool(5)                 # 4 usable blocks
    pool.reserve(1, 2)
    pool.reserve(2, 2)
    assert not pool.can_reserve(1)      # fully reserved
    # interleave the draw-downs; none may raise
    pool.alloc(1)
    pool.alloc(2)
    pool.alloc(2)
    pool.alloc(1)
    assert pool.live == 4
    _assert_pool_clean(pool)


def _pool_lifecycle(ops, num_blocks):
    """Replay (kind, uid, n) ops against a BlockPool, checking balance
    after every step; returns how many ops were admissible."""
    pool = BlockPool(num_blocks)
    admitted = 0
    for kind, uid, n in ops:
        if kind == "reserve":
            if uid in pool._owned or not pool.can_reserve(n):
                continue
            pool.reserve(uid, n)
        elif kind == "alloc":
            if pool._reserved.get(uid, 0) <= 0:
                continue
            pid = pool.alloc(uid)
            assert pid not in pool.reserved_ids
        else:
            if uid not in pool._owned:
                continue
            pool.release(uid)
        admitted += 1
        _assert_pool_clean(pool)
        assert pool.live + len(pool._free) + len(pool.reserved_ids) \
            == pool.num_blocks
    return admitted


def test_pool_randomized_lifecycle():
    """Deterministic random op soup — always runs, even without the
    hypothesis extra."""
    rng = np.random.RandomState(0)
    for trial in range(20):
        num_blocks = int(rng.randint(2, 12))
        ops = [(("reserve", "alloc", "release")[rng.randint(3)],
                int(rng.randint(4)), int(rng.randint(1, 4)))
               for _ in range(60)]
        assert _pool_lifecycle(ops, num_blocks) > 0 or num_blocks == 2


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        num_blocks=st.integers(min_value=2, max_value=16),
        ops=st.lists(
            st.tuples(st.sampled_from(["reserve", "alloc", "release"]),
                      st.integers(min_value=0, max_value=5),
                      st.integers(min_value=1, max_value=5)),
            max_size=80),
    )
    def test_pool_property_no_leak_no_double_alloc(num_blocks, ops):
        """Any admissible op sequence keeps the pool balanced: every
        block tracked exactly once, reservations never exceed free."""
        _pool_lifecycle(ops, num_blocks)
else:       # keep the suite honest about what it skipped
    @pytest.mark.skip(reason="hypothesis dev extra not installed")
    def test_pool_property_no_leak_no_double_alloc():
        pass


def test_blocks_needed_is_ceil_div():
    assert blocks_needed(1, 128) == 1
    assert blocks_needed(128, 128) == 1
    assert blocks_needed(129, 128) == 2
    assert blocks_needed(256, 128) == 2


# ---------------------------------------------------------------------------
# _default_buckets: never compile a prefill no request can reach
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("limit", [8, 48, 64, 96, 127, 128, 129, 512, 1920])
def test_default_buckets_capped_at_admissible_prefill(limit):
    buckets = _default_buckets(limit)
    assert buckets == sorted(buckets)
    # max_new_tokens >= 1 → longest admissible prompt is limit - 1
    assert buckets[-1] == max(limit - 1, 1)
    assert all(b <= limit - 1 for b in buckets) or limit <= 2


def test_engine_buckets_cover_paged_max_context(setup):
    """A paged engine's buckets stretch to max_context (kv_blocks-driven),
    not the dense per-slot capacity."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2, kv_blocks=5)
    assert eng.paged and eng.max_context == 4 * BLOCK_TOKENS
    assert eng._buckets[-1] == eng.max_context - 1


# ---------------------------------------------------------------------------
# paged-attention kernel vs exact dense oracle
# ---------------------------------------------------------------------------
def _pool_setup(rng, B, Hq, Hkv, hd, dv, T, NB, P):
    q = jnp.asarray(rng.standard_normal((B, Hq, hd)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((P, T, Hkv, hd)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((P, T, Hkv, dv)), jnp.float32)
    # distinct live blocks per sequence, dead tail on scratch block 0
    perm = rng.permutation(P - 1)[:B * NB].reshape(B, NB) + 1
    lengths = rng.integers(1, NB * T + 1, size=B)
    tables = np.zeros((B, NB), np.int32)
    for b in range(B):
        nb = blocks_needed(int(lengths[b]), T)
        tables[b, :nb] = perm[b, :nb]
    return q, k_pool, v_pool, jnp.asarray(tables), \
        jnp.asarray(lengths, jnp.int32)


def test_paged_kernel_matches_ref_gqa():
    rng = np.random.default_rng(0)
    T = BLOCK_TOKENS
    q, k_pool, v_pool, tables, lengths = _pool_setup(
        rng, B=3, Hq=4, Hkv=2, hd=16, dv=16, T=T, NB=3, P=10)
    scale = 16 ** -0.5
    got = paged_attention(q, k_pool, v_pool, tables, lengths, scale=scale)
    want = paged_attention_ref(q, k_pool, v_pool, tables, lengths,
                               scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_ref_mla_fused_v():
    """v_pool=None + v_dim: values are the first v_dim key lanes (the
    absorbed-MLA layout, one pool read per block)."""
    rng = np.random.default_rng(1)
    T = BLOCK_TOKENS
    q, k_pool, _, tables, lengths = _pool_setup(
        rng, B=2, Hq=3, Hkv=1, hd=24, dv=24, T=T, NB=2, P=6)
    scale = 24 ** -0.5
    got = paged_attention(q, k_pool, None, tables, lengths,
                          scale=scale, v_dim=16)
    want = paged_attention_ref(q, k_pool, None, tables, lengths,
                               scale=scale, v_dim=16)
    assert got.shape == (2, 3, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_ignores_dead_block_contents():
    """Entries past the live length point at scratch; poisoning every
    dead block (including scratch) must not change the output."""
    rng = np.random.default_rng(2)
    T = BLOCK_TOKENS
    q, k_pool, v_pool, tables, lengths = _pool_setup(
        rng, B=2, Hq=2, Hkv=2, hd=8, dv=8, T=T, NB=3, P=8)
    lengths = jnp.asarray([T + 5, 3], jnp.int32)    # 2 and 1 live blocks
    tables = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)
    scale = 8 ** -0.5
    base = paged_attention(q, k_pool, v_pool, tables, lengths, scale=scale)
    live = {1, 2, 3}
    poison = np.asarray(k_pool).copy()
    poisonv = np.asarray(v_pool).copy()
    for p in range(8):
        if p not in live:
            poison[p] = 1e4
            poisonv[p] = 1e4
    got = paged_attention(q, jnp.asarray(poison), jnp.asarray(poisonv),
                          tables, lengths, scale=scale)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_paged_gather_logical_order():
    pool = jnp.arange(4 * 2 * 1 * 1, dtype=jnp.float32).reshape(4, 2, 1, 1)
    tables = jnp.asarray([[3, 1]], jnp.int32)
    dense = paged_gather(pool, tables)
    assert dense.shape == (1, 4, 1, 1)
    np.testing.assert_array_equal(
        np.asarray(dense).ravel(), [6., 7., 2., 3.])


# ---------------------------------------------------------------------------
# serve engine: paged vs dense oracles
# ---------------------------------------------------------------------------
def test_paged_engine_matches_dense_greedy(setup):
    """Ragged batch over 3 slots: greedy tokens identical paged vs dense,
    and the report's pool accounting balances after drain."""
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    dense = _run(_engine(cfg, params, paged=False),
                 [Request(uid=r.uid, prompt=r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens) for r in reqs])
    eng = _engine(cfg, params)
    assert eng.paged
    paged = _run(eng, reqs)
    assert dense == paged
    rep = eng.report
    assert rep.paged and rep.kv_blocks_live == 0 and rep.kv_blocks_peak >= 3
    assert rep.kv_bytes_per_token > 0
    for gen in eng.generations:
        _assert_pool_clean(gen.pool)


def test_paged_hot_swap_mid_stream(setup):
    """Hot-swap mid-decode: requests admitted pre-swap stay bit-identical
    to the no-swap run; post-swap requests land on the new generation."""
    cfg, params = setup
    reqs = _ragged_requests(cfg, n=4)
    baseline = _run(_engine(cfg, params, slots=2),
                    [Request(uid=r.uid, prompt=r.prompt.copy(),
                             max_new_tokens=r.max_new_tokens) for r in reqs])

    params2 = tfm.init_params(jax.random.PRNGKey(7), cfg)
    eng = _engine(cfg, params, slots=2)
    assert eng.paged
    for r in reqs:
        eng.submit(r)
    steps = 0
    while not eng.idle:
        eng.step()
        steps += 1
        if steps == 2:
            eng.swap(params2)
    done = {r.uid: (r.tokens, r.generation) for r in eng._finished}
    assert len(done) == len(reqs)
    for uid, (toks, gid) in done.items():
        if gid == 0:
            assert toks == baseline[uid]
    assert any(gid == 1 for _, gid in done.values()), \
        "no request decoded on the swapped-in generation"
    for gen in eng.generations:
        _assert_pool_clean(gen.pool)


def test_long_prompt_admitted_past_dense_capacity(setup):
    """prompt + budget > capacity completes on an idle paged engine and
    matches a big-capacity dense oracle (the tentpole's acceptance)."""
    cfg, params = setup
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, cfg.vocab_size, size=60).astype(np.int32)
    eng = _engine(cfg, params, slots=2)
    assert eng.paged and 60 + 8 > eng.capacity <= eng.max_context
    got = _run(eng, [Request(uid=0, prompt=prompt.copy(),
                             max_new_tokens=8)])
    assert len(got[0]) == 8
    assert eng.report.kv_blocks_peak >= blocks_needed(60 + 8, BLOCK_TOKENS)

    oracle = _run(_engine(cfg, params, slots=2, capacity=128, paged=False),
                  [Request(uid=0, prompt=prompt.copy(), max_new_tokens=8)])
    assert got == oracle
