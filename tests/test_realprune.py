"""Algorithm 1 state machine — scripted train/eval, fully deterministic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PruneConfig
from repro.core import algorithm as alg
from repro.core.masks import make_masks, sparsity_fraction


def _params(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(3, 3, 4, 8), jnp.float32),
            "b": jnp.asarray(r.randn(256, 128), jnp.float32)}


PRUNABLE = lambda p, l: l.ndim >= 2      # noqa: E731
CONV = lambda p: p == "a"                # noqa: E731


def test_accepts_until_accuracy_drops_then_switches():
    calls = {"train": 0, "evals": []}

    def train_fn(params, masks):
        calls["train"] += 1
        return params

    # accept twice at filter granularity, then always fail
    def eval_fn(params, masks):
        s = sparsity_fraction(masks)
        acc = 1.0 if s < 0.45 else 0.5
        calls["evals"].append((s, acc))
        return acc

    cfg = PruneConfig(prune_fraction=0.25, max_iters=20)
    res = alg.realprune(init_params=_params(), train_fn=train_fn,
                        eval_fn=eval_fn, prunable=PRUNABLE, conv_pred=CONV,
                        cfg=cfg, baseline_accuracy=1.0)
    # sparsity after accepted iterations stays below the 0.45 acc cliff
    assert 0.3 < res.sparsity < 0.45
    grans = [e.granularity for e in res.history]
    assert grans[0] == "filter"
    assert "channel" in grans and "index" in grans    # switched twice
    undone = [e for e in res.history if not e.accepted]
    assert len(undone) == 3                            # one per granularity


def test_rewind_returns_initial_weights():
    params = _params()

    def train_fn(p, masks):
        return jax.tree.map(lambda x: x + 100.0, p)   # training moves far

    def eval_fn(p, masks):
        return 1.0

    cfg = PruneConfig(prune_fraction=0.2, max_iters=2)
    res = alg.realprune(init_params=params, train_fn=train_fn,
                        eval_fn=eval_fn, prunable=PRUNABLE, conv_pred=CONV,
                        cfg=cfg, baseline_accuracy=0.0)
    # surviving weights equal the t=0 initialisation (lottery rewind)
    m = res.masks["b"]
    np.testing.assert_allclose(np.asarray(res.params["b"]),
                               np.asarray(params["b"] * m))
    assert res.sparsity > 0.3


def test_max_iters_bound():
    cfg = PruneConfig(prune_fraction=0.1, max_iters=3)
    res = alg.realprune(init_params=_params(),
                        train_fn=lambda p, m: p,
                        eval_fn=lambda p, m: 1.0,
                        prunable=PRUNABLE, conv_pred=CONV, cfg=cfg,
                        baseline_accuracy=0.0)
    assert len(res.history) == 3


def test_masks_monotone_nonincreasing():
    masks_seen = []

    def eval_fn(p, m):
        masks_seen.append(jax.tree.map(
            lambda x: None if x is None else np.asarray(x), m,
            is_leaf=lambda x: x is None))
        return 1.0

    cfg = PruneConfig(prune_fraction=0.3, max_iters=4)
    alg.realprune(init_params=_params(), train_fn=lambda p, m: p,
                  eval_fn=eval_fn, prunable=PRUNABLE, conv_pred=CONV,
                  cfg=cfg, baseline_accuracy=0.0)
    for prev, cur in zip(masks_seen, masks_seen[1:]):
        for a, b in zip(jax.tree.leaves(prev), jax.tree.leaves(cur)):
            assert (b <= a).all()      # pruned weights never resurrect


def test_baseline_methods_single_granularity():
    for method in ("ltp", "block", "cap"):
        res = alg.lottery_baseline(
            init_params=_params(), train_fn=lambda p, m: p,
            eval_fn=lambda p, m: 1.0, prunable=PRUNABLE, conv_pred=CONV,
            cfg=PruneConfig(prune_fraction=0.25, max_iters=3),
            method=method, baseline_accuracy=0.0)
        assert res.sparsity > 0.4, method
        assert all(e.granularity == {"ltp": "ltp", "block": "block",
                                     "cap": "cap"}[method]
                   for e in res.history)
