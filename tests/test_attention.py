"""Attention layers vs naive references; cache continuity."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MLAConfig
from repro.models import attention as A

B, S, Hq, Hkv, hd, d = 2, 128, 8, 2, 16, 64


def naive(q, k, v, window=None):
    G = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(q.shape[-1])
    qp, kp = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    m = kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)


@pytest.fixture(scope="module")
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, hd)),
            jax.random.normal(ks[1], (B, S, Hkv, hd)),
            jax.random.normal(ks[2], (B, S, Hkv, hd)))


@pytest.mark.parametrize("block_q", [16, 32, 128])
def test_causal_blockwise_exact(qkv, block_q):
    q, k, v = qkv
    np.testing.assert_allclose(
        np.asarray(A.causal_attention(q, k, v, block_q=block_q)),
        np.asarray(naive(q, k, v)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 32, 64])
def test_sliding_window_exact(qkv, window):
    q, k, v = qkv
    np.testing.assert_allclose(
        np.asarray(A.sliding_window_attention(q, k, v, window=window)),
        np.asarray(naive(q, k, v, window=window)), rtol=2e-5, atol=2e-5)


def test_gqa_prefill_decode_continuity():
    rng = jax.random.PRNGKey(1)
    p = A.gqa_init(rng, d, Hq, Hkv, hd)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S + 2, d)) * 0.1
    kw = dict(n_heads=Hq, n_kv_heads=Hkv, head_dim=hd, rope_theta=1e4)
    full = A.gqa_forward(p, x, **kw)
    out, cache = A.gqa_make_cache(p, x[:, :S], capacity=S + 8, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :S]),
                               rtol=2e-4, atol=2e-4)
    d1, cache = A.gqa_decode(p, cache, x[:, S:S + 1], **kw)
    d2, cache = A.gqa_decode(p, cache, x[:, S + 1:S + 2], **kw)
    np.testing.assert_allclose(np.asarray(d1[:, 0]), np.asarray(full[:, S]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(d2[:, 0]),
                               np.asarray(full[:, S + 1]),
                               rtol=2e-4, atol=2e-4)


def test_gqa_valid_len_prefill_and_per_slot_decode():
    """Right-padded batched prefill (valid_len) + vector-index decode ==
    each request prefilled/decoded alone (continuous-batching math)."""
    rng = jax.random.PRNGKey(2)
    p = A.gqa_init(rng, d, Hq, Hkv, hd)
    kw = dict(n_heads=Hq, n_kv_heads=Hkv, head_dim=hd, rope_theta=1e4)
    lens = [5, 9]
    Smax, cap = 9, 16
    x = jax.random.normal(jax.random.PRNGKey(6), (2, Smax, d)) * 0.1
    x = x * (jnp.arange(Smax)[None, :, None]
             < jnp.asarray(lens)[:, None, None])      # zero the padding
    x_new = jax.random.normal(jax.random.PRNGKey(7), (2, 1, d)) * 0.1

    _, cache = A.gqa_make_cache(p, x, capacity=cap,
                                valid_len=jnp.asarray(lens), **kw)
    assert cache.index.shape == (2,)
    db, cache2 = A.gqa_decode(p, cache, x_new, **kw)
    assert cache2.index.tolist() == [6, 10]

    for i, n in enumerate(lens):
        _, ci = A.gqa_make_cache(p, x[i:i + 1, :n], capacity=cap, **kw)
        di, _ = A.gqa_decode(p, ci, x_new[i:i + 1], **kw)
        np.testing.assert_allclose(np.asarray(db[i]), np.asarray(di[0]),
                                   rtol=2e-5, atol=2e-5)


def test_gqa_valid_len_rejects_windowed_prefill():
    p = A.gqa_init(jax.random.PRNGKey(0), d, Hq, Hkv, hd)
    x = jnp.zeros((1, 8, d))
    with pytest.raises(ValueError, match="valid_len"):
        A.gqa_make_cache(p, x, capacity=16, window=4,
                         valid_len=jnp.asarray([4]), n_heads=Hq,
                         n_kv_heads=Hkv, head_dim=hd, rope_theta=1e4)


def test_local_ring_buffer_decode():
    """Decode with a window-sized ring cache matches full local attention.

    Prefill 96 tokens (3 windows), decode token 96; reference = local
    attention over a longer (128) sequence — position 96 is causal so
    the padding tail cannot affect it.
    """
    window, S_pre = 32, 96
    rng = jax.random.PRNGKey(2)
    p = A.gqa_init(rng, d, Hq, Hkv, hd)
    kw = dict(n_heads=Hq, n_kv_heads=Hkv, head_dim=hd, rope_theta=1e4)
    x = jax.random.normal(jax.random.PRNGKey(6), (B, 128, d)) * 0.1
    full = A.gqa_forward(p, x, window=window, **kw)
    _, cache = A.gqa_make_cache(p, x[:, :S_pre], capacity=window,
                                window=window, **kw)
    dec, _ = A.gqa_decode(p, cache, x[:, S_pre:S_pre + 1], window=window,
                          **kw)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, S_pre]),
                               rtol=2e-4, atol=2e-4)


def test_mla_forward_prefill_decode():
    mla = MLAConfig(q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    rng = jax.random.PRNGKey(3)
    pm = A.mla_init(rng, d, 4, mla)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S + 1, d)) * 0.1
    full = A.mla_forward(pm, x, n_heads=4, mla=mla, rope_theta=1e4)
    out, cm = A.mla_make_cache(pm, x[:, :S], n_heads=4, mla=mla,
                               rope_theta=1e4, capacity=S + 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, :S]),
                               rtol=2e-4, atol=2e-4)
    dec, _ = A.mla_decode(pm, cm, x[:, S:S + 1], n_heads=4, mla=mla,
                          rope_theta=1e4)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_mla_cache_is_compressed():
    """The decode cache stores kv_lora+rope floats/token, not H·(dn+dv)."""
    mla = MLAConfig(q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    spec = A.mla_cache_spec(batch=2, capacity=64, mla=mla,
                            dtype=jnp.bfloat16)
    per_token = spec.c_kv.shape[-1] + spec.k_rope.shape[-1]
    assert per_token == 20                 # vs 4 heads × (12+8) = 80 expanded
