"""Strategy registry: every registered granularity round-trips
score→zero with the sparsity invariants, and non-default crossbar
geometry changes the whole accounting path (no hardcoded 128s)."""
import numpy as np
import pytest

from repro.configs import PruneConfig
from repro.core import scoring
from repro.core import strategies as strat
from repro.core.crossbar import xbar_stats
from repro.core.hardware import analyze_masks


def _leaves():
    r = np.random.RandomState(0)
    return [
        ("conv", r.randn(3, 3, 8, 16).astype(np.float32), True),
        ("fc", r.randn(130, 70).astype(np.float32), False),
    ]


# 'expert' only forms groups on stacked MoE tensors — it gets its own
# roundtrip below on an expert-shaped leaf
_TILING = [n for n in strat.available_strategies() if n != "expert"]


@pytest.mark.parametrize("name", _TILING)
def test_registry_roundtrip_score_zero(name):
    """score → kill the lowest quarter of groups → zero: exactly the
    selected groups die, nothing resurrects, sizes account for the
    removed weights."""
    for path, w, conv in _leaves():
        mask = np.ones_like(w)
        gs = scoring.group_scores(path, w, mask, name, conv=conv)
        assert gs.scores.shape == gs.sizes.shape == gs.alive.shape
        assert gs.alive.all()
        assert int(gs.sizes.sum()) == w.size       # groups tile the leaf
        flat = np.argsort(gs.scores.reshape(-1), kind="stable")
        n_kill = max(1, flat.size // 4)
        kill = np.zeros(gs.scores.size, bool)
        kill[flat[:n_kill]] = True
        kill = kill.reshape(gs.scores.shape)
        new = scoring.zero_groups(mask, gs, kill)
        assert new.shape == mask.shape
        assert ((new == 0) | (new == 1)).all()
        assert (new <= mask).all()                  # monotone
        removed = mask.sum() - new.sum()
        assert removed == gs.sizes[kill].sum()
        # re-scoring marks exactly the killed groups dead
        gs2 = scoring.group_scores(path, w, new, name, conv=conv)
        assert not gs2.alive[kill].any()
        assert gs2.alive[~kill].all()


def test_get_strategy_unknown_name():
    with pytest.raises(KeyError):
        strat.get_strategy("no-such-granularity")


# ---------------------------------------------------------------------------
# 'expert' granularity: whole MoE experts, nothing else
# ---------------------------------------------------------------------------
def _expert_leaf(E=6, d=16, ff=8, seed=5):
    r = np.random.RandomState(seed)
    return r.randn(E, d, ff).astype(np.float32)


def test_expert_strategy_roundtrip_on_expert_stack():
    w = _expert_leaf()
    mask = np.ones_like(w)
    path = "segments/0/1/moe/up"
    gs = scoring.group_scores(path, w, mask, "expert", conv=False)
    assert gs.scores.shape == (w.shape[0],)        # one group per expert
    assert gs.alive.all()
    assert int(gs.sizes.sum()) == w.size
    kill = np.zeros(w.shape[0], bool)
    kill[[1, 4]] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new.shape == mask.shape
    assert new[1].sum() == 0 and new[4].sum() == 0  # whole experts dead
    assert new[0].all() and new[2].all() and new[3].all() and new[5].all()
    gs2 = scoring.group_scores(path, w, new, "expert", conv=False)
    assert not gs2.alive[kill].any()
    assert gs2.alive[~kill].all()


def test_expert_strategy_handles_scanned_expert_stack():
    """(reps, E, d, ff) scanned MoE tensors: one group per expert per
    layer, killed slice-exact."""
    r = np.random.RandomState(6)
    w = r.randn(3, 4, 8, 8).astype(np.float32)
    mask = np.ones_like(w)
    gs = scoring.group_scores("segments/1/0/moe/gate", w, mask, "expert",
                              conv=False)
    assert gs.scores.shape == (12,)
    kill = np.zeros(12, bool)
    kill[5] = True                                  # layer 1, expert 1
    new = scoring.zero_groups(mask, gs, kill)
    assert new[1, 1].sum() == 0
    assert new.sum() == mask.size - 8 * 8


def test_expert_strategy_ignores_non_expert_leaves():
    """Attention/conv/dense leaves expose no alive groups, so global
    selection can never kill them at the 'expert' granularity."""
    for path, w, conv in _leaves():
        gs = scoring.group_scores(path, w, mask=np.ones_like(w),
                                  granularity="expert", conv=conv)
        assert not gs.alive.any()
    # a stacked NON-moe leaf (scanned attention) is also ignored
    w = np.random.RandomState(7).randn(3, 16, 16).astype(np.float32)
    gs = scoring.group_scores("segments/0/0/attn/wq", w, np.ones_like(w),
                              "expert", conv=False)
    assert not gs.alive.any()
    # ...and so is the scanned SHARED-expert MLP: (reps, d, ff) stacks
    # under moe/shared are layer repeats of an always-on MLP, not
    # routed experts the router can route around
    gs = scoring.group_scores("segments/0/1/moe/shared/up", w,
                              np.ones_like(w), "expert", conv=False)
    assert not gs.alive.any()


def test_expert_granularity_through_prune_step():
    import jax.numpy as jnp

    from repro.core.algorithm import prune_step
    from repro.core.masks import make_masks

    params = {
        "segments": [[{"attn": {"wq": jnp.asarray(
            np.random.RandomState(8).randn(32, 32), jnp.float32)},
            "moe": {"up": jnp.asarray(_expert_leaf(), jnp.float32)}}]],
    }
    masks = make_masks(params, lambda p, l: True)
    new = prune_step(params, masks, "expert", 0.2, lambda p: False)
    up = np.asarray(new["segments"][0][0]["moe"]["up"])
    wq = np.asarray(new["segments"][0][0]["attn"]["wq"])
    dead_experts = int((up.reshape(up.shape[0], -1).sum(axis=1) == 0).sum())
    assert dead_experts >= 1
    assert wq.all()                                 # attention untouched


def test_register_custom_strategy_plugs_into_prune_step():
    import jax.numpy as jnp

    from repro.core.algorithm import prune_step
    from repro.core.masks import make_masks, sparsity_fraction

    class EveryOtherColumn(strat.GranularityStrategy):
        """Toy shape: groups = column pairs."""
        name = "colpair"

        def score(self, path, w, mask, *, conv,
                  geom=strat.DEFAULT_GEOMETRY, block=32):
            return strat.get_strategy("filter").score(
                path, w, mask, conv=conv, geom=geom, block=block)

        def zero(self, mask, gs, kill):
            return strat.get_strategy("filter").zero(mask, gs, kill)

    strat.register_strategy(EveryOtherColumn())
    try:
        assert "colpair" in strat.available_strategies()
        params = {"w": jnp.asarray(np.random.RandomState(1)
                                   .randn(64, 32), jnp.float32)}
        masks = make_masks(params, lambda p, l: True)
        new = prune_step(params, masks, "colpair", 0.25, lambda p: False)
        assert 0.2 <= sparsity_fraction(new) <= 0.35
    finally:
        strat._REGISTRY.pop("colpair", None)


# ---------------------------------------------------------------------------
# Non-default geometry: PruneConfig(xbar_rows=64, xbar_cols=64) must
# change crossbar accounting everywhere on the stats path.
# ---------------------------------------------------------------------------
def test_geometry_from_config():
    geom = strat.TileGeometry.from_config(
        PruneConfig(xbar_rows=64, xbar_cols=64))
    assert (geom.rows, geom.cols, geom.cells) == (64, 64, 4096)


def test_xbar_stats_geometry_changes_accounting():
    m = np.ones((128, 128), bool)
    m[64:, :] = False                     # bottom half dead
    st128 = xbar_stats(m)                 # one 128×128 crossbar
    st64 = xbar_stats(m, xr=64, xc=64)    # four 64×64 crossbars
    assert st128.n_xbars == 1 and st128.xbars_fully_free == 0
    assert st64.n_xbars == 4 and st64.xbars_fully_free == 2
    assert st64.xbars_needed_packed == 2  # live area = 2 full 64×64 tiles
    assert st128.xbars_needed_packed == 1


def test_analyze_masks_64_geometry():
    m = np.ones((128, 128), np.float32)
    m[64:, :] = 0.0
    masks = {"w": m}
    rep128 = analyze_masks(masks, lambda p: False)
    rep64 = analyze_masks(masks, lambda p: False,
                          xbar_rows=64, xbar_cols=64)
    assert rep128.xbars_unpruned == 1
    assert rep64.xbars_unpruned == 4
    assert rep64.xbars_needed == 2        # packed under 64×64 geometry
    # merged aggregate recomputes packed count with the 64×64 cell area
    assert rep64.layers[0].stats.xbar_rows == 64


def test_channel_and_index_respect_geometry():
    r = np.random.RandomState(3)
    w = r.randn(256, 256).astype(np.float32)
    mask = np.ones_like(w)
    geom = strat.TileGeometry.from_config(
        PruneConfig(xbar_rows=64, xbar_cols=64))
    gs = scoring.group_scores("p", w, mask, "channel", conv=False,
                              geometry=geom)
    assert gs.scores.shape == (1, 4, 256)          # 256/64 row tiles
    kill = np.zeros_like(gs.scores, bool)
    kill[0, 1, 5] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[64:128, 5].sum() == 0               # 64-row segment died
    assert new[:64, 5].all() and new[128:, 5].all()

    gs = scoring.group_scores("p", w, mask, "index", conv=False,
                              geometry=geom)
    assert gs.scores.shape == (1, 256, 4)          # 256/64 col tiles
    kill = np.zeros_like(gs.scores, bool)
    kill[0, 10, 2] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[10, 128:192].sum() == 0
    assert new[10, :128].all() and new[10, 192:].all()


def test_xbar_strategy_kills_whole_tiles():
    r = np.random.RandomState(4)
    w = r.randn(128, 128).astype(np.float32)
    mask = np.ones_like(w)
    geom = strat.TileGeometry(64, 64)
    gs = scoring.group_scores("p", w, mask, "xbar", conv=False,
                              geometry=geom)
    assert gs.scores.shape == (1, 2, 2)
    kill = np.zeros((1, 2, 2), bool)
    kill[0, 0, 1] = True
    new = scoring.zero_groups(mask, gs, kill)
    assert new[:64, 64:].sum() == 0
    assert new.sum() == mask.size - 64 * 64
    # the killed tile is exactly one fully-free crossbar at this geometry
    st = xbar_stats(new != 0, xr=64, xc=64)
    assert st.xbars_fully_free == 1


def test_tile_stats_kernel_follows_config_geometry():
    import jax.numpy as jnp

    from repro.kernels.tile_stats import tile_stats_for_config

    w = np.ones((128, 130), np.float32)
    w[:64, :64] = 0.0
    live, sums = tile_stats_for_config(
        jnp.asarray(w), PruneConfig(xbar_rows=64, xbar_cols=64))
    assert live.shape == (2, 3)                    # 128/64 × ceil(130/64)
    assert int(np.asarray(live)[0, 0]) == 0
    assert np.asarray(live)[1].all()
