"""Fleet router: least-loaded dispatch, failover drain, flap
re-admission, all-or-nothing fleet hot-swap, and accounting (P116)."""
import jax
import numpy as np
import pytest

from repro.analysis import verify_fleet
from repro.api import structured_prune
from repro.configs import PruneConfig, get_arch, scaled_down
from repro.core import lottery
from repro.core.masks import lm_prunable
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.models import transformer as tfm
from repro.serve import ServeEngine, TicketManager
from repro.serve.fleet import FleetRouter
from repro.serve.manager import SwapEvent, TicketManager as _TM

CAP = 96


@pytest.fixture(scope="module")
def setup():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = structured_prune(params, [("filter", 0.2)],
                             prunable=lm_prunable, cfg=PruneConfig())
    return cfg, params, masks


def _engine(cfg, params, slots=2, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=slots,
                       capacity=CAP, **kw)


def _prompt(i):
    return np.arange(1 + i, 9 + i, dtype=np.int32)


def _submit_all(router, n, budget=8):
    return [router.submit(_prompt(i), uid=i, max_new_tokens=budget)
            for i in range(n)]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------
def test_least_loaded_dispatch_balances(setup):
    cfg, params, _ = setup
    router = FleetRouter([_engine(cfg, params) for _ in range(2)])
    recs = _submit_all(router, 4)
    assert [r.engine for r in recs] == [0, 1, 0, 1]
    done = router.drain()
    assert {r.uid for r in done} == {0, 1, 2, 3}
    assert all(len(r.tokens) == 8 for r in done)
    assert verify_fleet(router) == []


def test_single_engine_fleet_matches_plain_engine(setup):
    cfg, params, _ = setup
    router = FleetRouter([_engine(cfg, params)])
    _submit_all(router, 2)
    fleet_tokens = {r.uid: list(r.tokens) for r in router.drain()}

    eng = _engine(cfg, params)
    solo = {i: list(eng.smoke_decode(_prompt(i), 8)) for i in range(2)}
    assert fleet_tokens == solo


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------
def test_failover_oracle_matches_never_failed_fleet(setup):
    cfg, params, _ = setup
    n_req = 6

    killed = FleetRouter([_engine(cfg, params) for _ in range(2)])
    _submit_all(killed, n_req)
    killed.pump(3)                        # engine 0 is mid-decode now
    moved = killed.kill(0)
    assert moved and any(r.tokens for r in moved), \
        "kill must catch in-flight requests with tokens already emitted"
    killed.drain()

    clean = FleetRouter([_engine(cfg, params) for _ in range(2)])
    _submit_all(clean, n_req)
    clean.drain()

    got = {r.uid: list(r.tokens) for r in killed.finished}
    want = {r.uid: list(r.tokens) for r in clean.finished}
    assert got == want                     # zero loss, zero dup, bit-exact
    assert len(killed.finished) == n_req
    assert killed.report.failovers == 1
    assert killed.report.redispatched == len(moved)
    assert all(r.redispatches == 1 for r in moved)
    assert killed.live == {1}
    assert verify_fleet(killed) == []
    assert verify_fleet(clean) == []


def test_heartbeat_failover_and_flap_readmission(setup, tmp_path):
    cfg, params, _ = setup
    t = [0.0]
    clock = lambda: t[0]
    monitor = HeartbeatMonitor(root=str(tmp_path / "hb"), deadline_s=5.0,
                               clock=clock)
    engines = [_engine(cfg, params, clock=clock) for _ in range(2)]
    router = FleetRouter(engines, monitor=monitor)
    _submit_all(router, 4, budget=12)
    router.pump(1)                        # both engines beat at t=0

    t[0] = 6.0                            # engine0 wedges; engine1 beats
    monitor.beat("engine1")
    router.pump(1)
    assert router.live == {1}
    assert router.report.failovers == 1

    t[0] = 7.0                            # engine0's beats resume
    monitor.beat("engine0")
    router.pump(1)                        # flap re-admission
    assert router.live == {0, 1}
    rec = router.submit(_prompt(9), uid=9, max_new_tokens=4)
    assert rec.engine == 0                # re-admitted engine takes load

    router.drain()
    assert {r.uid for r in router.finished} == {0, 1, 2, 3, 9}
    assert all(r.status == "done" for r in router.finished)
    assert verify_fleet(router) == []


# ---------------------------------------------------------------------------
# fleet hot-swap
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ticket(setup, tmp_path_factory):
    cfg, params, masks = setup
    root = tmp_path_factory.mktemp("fleet_tickets")
    meta = {"arch": cfg.name, "recipe": {"name": "paper"},
            "quantize_bits": None}
    lottery.export_ticket(str(root / "a"), lottery.snapshot(params),
                          masks, meta=meta)
    return str(root / "a")


def _manager(cfg, params):
    return TicketManager(cfg=cfg, params_template=params,
                         prunable=lm_prunable, prefill_fn=tfm.prefill,
                         decode_fn=tfm.decode_step, probe_tokens=6)


def test_fleet_swap_all_or_nothing_accepts(setup, ticket):
    cfg, params, _ = setup
    mgr = _manager(cfg, params)
    mgr.register("a", ticket)
    router = FleetRouter([_engine(cfg, params) for _ in range(2)])

    ev = mgr.swap(router, "a")
    assert ev.accepted and ev.rolled_back == 0
    assert [e.engine for e in ev.events] == [0, 1]
    assert all(e.accepted for e in ev.events)
    assert mgr.active == "a"
    for fe in router.frontends:
        assert len(fe.engine.generations) == 2

    # traffic lands on the swapped-in generation everywhere
    recs = _submit_all(router, 2, budget=4)
    router.drain()
    assert all(r.status == "done" for r in recs)
    assert verify_fleet(router) == []


def test_fleet_swap_rolls_back_every_engine_on_late_failure(
        setup, ticket, monkeypatch):
    cfg, params, _ = setup
    mgr = _manager(cfg, params)
    mgr.register("a", ticket)
    router = FleetRouter([_engine(cfg, params) for _ in range(2)])

    orig = _TM._swap_engine

    def flaky(self, engine, name, rec, engine_idx=None):
        ev = orig(self, engine, name, rec, engine_idx=engine_idx)
        if engine_idx == 1 and ev.accepted:
            engine.rollback(ev.gid)       # the shim owns its own undo
            return SwapEvent(ticket=name, gid=ev.gid, accepted=False,
                             reason="injected verification failure",
                             engine=engine_idx)
        return ev

    monkeypatch.setattr(_TM, "_swap_engine", flaky)
    ev = mgr.swap(router, "a")
    assert not ev.accepted
    assert ev.rolled_back == 1            # engine 0 was already committed
    assert "rolled back" in ev.reason
    assert mgr.active is None
    for fe in router.frontends:           # fleet never splits: old ticket
        assert len(fe.engine.generations) == 1

    recs = _submit_all(router, 2, budget=4)
    router.drain()
    assert all(r.status == "done" for r in recs)
    assert verify_fleet(router) == []


# ---------------------------------------------------------------------------
# reporting + overhead
# ---------------------------------------------------------------------------
def test_report_merges_logical_records_and_overhead_is_small(setup):
    cfg, params, _ = setup
    router = FleetRouter([_engine(cfg, params) for _ in range(2)])
    _submit_all(router, 4)
    router.drain()
    rep = router.report
    assert rep.requests == 4 == len(router.finished)
    assert rep.tokens_generated == 32
    assert rep.tokens_generated == sum(p.tokens_generated
                                       for p in rep.per_engine)
    assert rep.ttft_p50 > 0 and rep.ttft_p95 >= rep.ttft_p50
    assert rep.tokens_per_s > 0
    # router bookkeeping must be dwarfed by the engine steps it fronts
    assert 0 < router.dispatch_s < router.step_s
