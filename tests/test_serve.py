"""Serving engine: batched prefill/decode, greedy consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, scaled_down
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=4,
                       capacity=96), cfg, params


def test_serves_all_requests(engine):
    eng, cfg, params = engine
    rng = np.random.RandomState(0)
    for i in range(10):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, 100, 8).astype(np.int32),
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 10
    assert all(len(r.tokens) == 6 for r in done)
    assert all(r.done for r in done)


def test_greedy_matches_forward_argmax(engine):
    """First generated token == argmax of the forward logits."""
    eng, cfg, params = engine
    prompt = np.arange(1, 13, dtype=np.int32)
    eng.submit(Request(uid=99, prompt=prompt, max_new_tokens=2))
    done = eng.run()
    req = [r for r in done if r.uid == 99][0]
    logits, _ = tfm.forward(params, cfg,
                            {"tokens": jnp.asarray(prompt[None])})
    want = int(jnp.argmax(logits[0, -1]))
    assert req.tokens[0] == want


def _mk_engine(cfg, params, temperature, seed=0, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=2,
                       capacity=96, temperature=temperature,
                       sample_seed=seed, **kw)


def _stream(eng, prompt, n=8):
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n))
    return eng.run()[0].tokens


def test_temperature_alone_turns_sampling_on(engine):
    """temperature>0 samples (≠ greedy stream) without a greedy flag;
    a fixed seed fixes the stream."""
    _, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    greedy_toks = _stream(_mk_engine(cfg, params, temperature=0.0), prompt)
    s1 = _stream(_mk_engine(cfg, params, temperature=1.5), prompt)
    s2 = _stream(_mk_engine(cfg, params, temperature=1.5), prompt)
    assert s1 == s2                       # same seed → same stream
    assert s1 != greedy_toks              # it actually sampled
    assert len(s1) == 8


def test_explicit_greedy_wins_over_temperature(engine):
    _, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    forced = _stream(_mk_engine(cfg, params, temperature=1.5, greedy=True),
                     prompt, n=3)
    greedy_toks = _stream(_mk_engine(cfg, params, temperature=0.0), prompt,
                          n=3)
    assert forced == greedy_toks


def test_eos_stops_generation(engine):
    eng, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    logits, _ = tfm.forward(params, cfg,
                            {"tokens": jnp.asarray(prompt[None])})
    eos = int(jnp.argmax(logits[0, -1]))   # first generated token = EOS
    eng.submit(Request(uid=7, prompt=prompt, max_new_tokens=10,
                       eos_id=eos))
    done = eng.run()
    req = [r for r in done if r.uid == 7][0]
    assert req.tokens[0] == eos
    assert len(req.tokens) <= 2
