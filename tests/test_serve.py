"""Serving engine: batched prefill/decode, greedy consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, scaled_down
from repro.models import transformer as tfm
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=4,
                       capacity=96), cfg, params


def test_serves_all_requests(engine):
    eng, cfg, params = engine
    rng = np.random.RandomState(0)
    for i in range(10):
        eng.submit(Request(uid=i,
                           prompt=rng.randint(0, 100, 8).astype(np.int32),
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 10
    assert all(len(r.tokens) == 6 for r in done)
    assert all(r.done for r in done)


def test_greedy_matches_forward_argmax(engine):
    """First generated token == argmax of the forward logits."""
    eng, cfg, params = engine
    prompt = np.arange(1, 13, dtype=np.int32)
    eng.submit(Request(uid=99, prompt=prompt, max_new_tokens=2))
    done = eng.run()
    req = [r for r in done if r.uid == 99][0]
    logits, _ = tfm.forward(params, cfg,
                            {"tokens": jnp.asarray(prompt[None])})
    want = int(jnp.argmax(logits[0, -1]))
    assert req.tokens[0] == want


def _mk_engine(cfg, params, temperature, seed=0, **kw):
    return ServeEngine(params=params, cfg=cfg, prefill_fn=tfm.prefill,
                       decode_fn=tfm.decode_step, batch_slots=2,
                       capacity=96, temperature=temperature,
                       sample_seed=seed, **kw)


def _stream(eng, prompt, n=8):
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n))
    return eng.run()[0].tokens


def test_temperature_alone_turns_sampling_on(engine):
    """temperature>0 samples (≠ greedy stream) without a greedy flag;
    a fixed seed fixes the stream."""
    _, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    greedy_toks = _stream(_mk_engine(cfg, params, temperature=0.0), prompt)
    s1 = _stream(_mk_engine(cfg, params, temperature=1.5), prompt)
    s2 = _stream(_mk_engine(cfg, params, temperature=1.5), prompt)
    assert s1 == s2                       # same seed → same stream
    assert s1 != greedy_toks              # it actually sampled
    assert len(s1) == 8


def test_explicit_greedy_wins_over_temperature(engine):
    _, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    forced = _stream(_mk_engine(cfg, params, temperature=1.5, greedy=True),
                     prompt, n=3)
    greedy_toks = _stream(_mk_engine(cfg, params, temperature=0.0), prompt,
                          n=3)
    assert forced == greedy_toks


def test_eos_stops_generation(engine):
    eng, cfg, params = engine
    prompt = np.arange(1, 9, dtype=np.int32)
    logits, _ = tfm.forward(params, cfg,
                            {"tokens": jnp.asarray(prompt[None])})
    eos = int(jnp.argmax(logits[0, -1]))   # first generated token = EOS
    eng.submit(Request(uid=7, prompt=prompt, max_new_tokens=10,
                       eos_id=eos))
    done = eng.run()
    req = [r for r in done if r.uid == 7][0]
    assert req.tokens[0] == eos
    assert len(req.tokens) <= 2


def test_prefill_plan_matches_dense_prefill():
    """A pruned ticket's TilePlans now route prefill projections too:
    block-sparse prefill must be EXACT vs dense prefill on masked
    params (pruned weights are exact zeros, so skipping dead tiles
    changes nothing)."""
    from repro.api import structured_prune
    from repro.core.masks import apply_masks, lm_prunable
    from repro.models.plans import build_decode_plan

    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = structured_prune(params, [("xbar", 0.4), ("filter", 0.2)],
                             prunable=lm_prunable)
    masked = apply_masks(params, masks)
    plan, stats = build_decode_plan(masks, interpret=True)
    assert plan is not None and stats.routed > 0
    batch = {"tokens": jnp.asarray(
        np.arange(1, 13, dtype=np.int32)[None])}
    dense_logits, dense_caches = tfm.prefill(masked, cfg, batch,
                                             capacity=32)
    bs_logits, bs_caches = tfm.prefill(masked, cfg, batch, capacity=32,
                                       plan=plan)
    np.testing.assert_allclose(np.asarray(bs_logits),
                               np.asarray(dense_logits),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(dense_caches),
                    jax.tree.leaves(bs_caches)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    # masked (valid_len) prefill routes through the same plan
    toks = np.zeros((1, 16), np.int32)
    toks[0, :12] = np.arange(1, 13)
    vl = jnp.asarray([12], jnp.int32)
    d_logits, _ = tfm.prefill(masked, cfg,
                              {"tokens": jnp.asarray(toks)},
                              capacity=32, valid_len=vl)
    p_logits, _ = tfm.prefill(masked, cfg,
                              {"tokens": jnp.asarray(toks)},
                              capacity=32, valid_len=vl, plan=plan)
    np.testing.assert_allclose(np.asarray(p_logits),
                               np.asarray(d_logits),
                               rtol=1e-5, atol=1e-5)
