"""Family-keyed adapter registry: make_adapter resolution, per-family
prunable predicates as registry data, ServeUnsupported, and the MoE
block-sparse plan path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CNNAdapter, EncDecAdapter, LMAdapter, ServeUnsupported,
                       available_families, get_family, list_adaptable,
                       make_adapter)
from repro.configs import get_arch, get_cnn, list_archs, list_cnns
from repro.core.masks import (encdec_prunable, family_prunable, make_masks,
                              moe_prunable, path_str, recurrent_prunable)


def test_every_registered_name_is_adaptable():
    names = list_adaptable()
    assert set(names) == set(list_archs()) | set(list_cnns())
    assert len(names) >= 14


def test_family_coverage():
    fams = {get_arch(a).family for a in list_archs()}
    fams |= {get_cnn(c).family for c in list_cnns()}
    assert fams <= set(available_families())


@pytest.mark.parametrize("name,cls", [
    ("yi-6b", LMAdapter), ("deepseek-v3-671b", LMAdapter),
    ("recurrentgemma-2b", LMAdapter), ("xlstm-125m", LMAdapter),
    ("phi-3-vision-4.2b", LMAdapter),
    ("whisper-tiny", EncDecAdapter), ("vgg16", CNNAdapter),
])
def test_make_adapter_resolves_family_class(name, cls):
    adapter = make_adapter(name, scale="tiny")
    assert isinstance(adapter, cls)
    spec = get_family(adapter.family)
    assert adapter.prunable_pred is spec.prunable
    assert adapter.granularities == spec.granularities


def test_make_adapter_unknown_name():
    with pytest.raises(KeyError, match="unknown arch"):
        make_adapter("no-such-arch")


def test_make_adapter_rejects_unknown_scale():
    with pytest.raises(ValueError, match="unknown scale"):
        make_adapter("vgg11", scale="medium")


def test_make_adapter_accepts_config_instance():
    """A pre-scaled config instance passes through unscaled but still
    gets the family data attached (examples rely on this)."""
    from repro.configs import scaled_down
    cfg = scaled_down(get_arch("yi-6b"), n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, head_dim=16, vocab_size=64,
                      dtype="float32")
    adapter = make_adapter(cfg, steps=2, batch_size=2, seq_len=8)
    assert adapter.cfg is cfg
    assert adapter.family == "dense"
    assert adapter.prunable_pred is family_prunable("dense")


def test_moe_family_granularities_lead_with_expert():
    assert get_family("moe").granularities[0] == "expert"
    assert get_family("dense").granularities is None


# ---------------------------------------------------------------------------
# Per-family prunable predicates: the registry data reaches the
# family-specific tensors and skips the family-specific exclusions.
# ---------------------------------------------------------------------------
def _mask_paths(params, pred):
    masks = make_masks(params, pred)
    covered, skipped = set(), set()

    def visit(path, leaf):
        (covered if leaf is not None else skipped).add(path_str(path))
        return leaf

    jax.tree_util.tree_map_with_path(visit, masks,
                                     is_leaf=lambda x: x is None)
    return covered, skipped


def _has(paths, token):
    return any(token in p for p in paths)


def test_moe_prunable_reaches_expert_stacks_not_router():
    from repro.configs import scaled_down
    from repro.models import transformer as tfm
    cfg = scaled_down(get_arch("llama4-maverick-400b-a17b"), n_layers=2,
                      block_pattern=None, dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    covered, skipped = _mask_paths(params, moe_prunable)
    assert _has(covered, "moe/up") and _has(covered, "moe/down")
    assert _has(covered, "moe/shared/up")
    assert not _has(covered, "router")
    assert _has(skipped, "router")
    assert not _has(covered, "embed")


def test_recurrent_prunable_reaches_blockdiag_not_conv_or_lam():
    from repro.configs import scaled_down
    from repro.models import transformer as tfm
    cfg = scaled_down(get_arch("recurrentgemma-2b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    covered, skipped = _mask_paths(params, recurrent_prunable)
    assert _has(covered, "rnn/w_in") and _has(covered, "rnn/w_out")
    assert _has(covered, "rnn/rg/w") and _has(covered, "rnn/ig/w")
    assert not _has(covered, "rnn/conv")
    assert not _has(covered, "lam")


def test_recurrent_prunable_covers_xlstm_cells():
    from repro.configs import scaled_down
    from repro.models import transformer as tfm
    cfg = scaled_down(get_arch("xlstm-125m"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    covered, _ = _mask_paths(params, recurrent_prunable)
    assert _has(covered, "cell/wq/w")               # mLSTM block-diag
    assert _has(covered, "cell/ri/w")               # sLSTM recurrence
    assert _has(covered, "rnn/up") and _has(covered, "rnn/down")
    assert not _has(covered, "bi") and not _has(covered, "bf")


def test_encdec_prunable_reaches_cross_attention_not_frontend():
    from repro.configs import scaled_down
    from repro.models import encdec
    cfg = scaled_down(get_arch("whisper-tiny"), dtype="float32")
    params = encdec.init_params(jax.random.PRNGKey(0), cfg)
    covered, skipped = _mask_paths(params, encdec_prunable)
    assert _has(covered, "xattn/wq") and _has(covered, "xattn/wo")
    assert _has(covered, "enc/attn/wq") and _has(covered, "dec/mlp/up")
    assert not _has(covered, "frame_adapter")
    assert _has(skipped, "frame_adapter")
    assert not _has(covered, "embed")


def test_family_prunable_unknown_family():
    with pytest.raises(KeyError):
        family_prunable("hologram")


# ---------------------------------------------------------------------------
# ServeUnsupported: structured, CLI-catchable
# ---------------------------------------------------------------------------
def test_serve_unsupported_is_structured():
    adapter = make_adapter("vgg11", scale="tiny")
    with pytest.raises(ServeUnsupported) as ei:
        adapter.serve_fns()
    assert ei.value.family == "cnn"
    assert "vgg11" in ei.value.arch
    assert ei.value.reason
    assert isinstance(ei.value, NotImplementedError)  # back-compat


def test_encdec_serves_through_frames_lane():
    adapter = make_adapter("whisper-tiny", scale="tiny")
    prefill_fn, decode_fn = adapter.serve_fns()
    assert callable(prefill_fn) and callable(decode_fn)
    frames = adapter.serve_frames(uid=3)
    assert frames.shape == (adapter.cfg.encoder_seq_len,
                            adapter.cfg.d_model)
    # deterministic per uid so engine outputs are reproducible
    assert (frames == adapter.serve_frames(uid=3)).all()


def test_lm_adapter_still_serves():
    adapter = make_adapter("llama3.2-3b", scale="tiny")
    prefill_fn, decode_fn = adapter.serve_fns()
    assert callable(prefill_fn) and callable(decode_fn)


# ---------------------------------------------------------------------------
# MoE block-sparse plan path: per-expert matmuls run through ONE plan
# unioned over the expert axis, matching the dense forward exactly.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_moe_plan_matches_dense_forward():
    from repro.configs import scaled_down
    from repro.core.algorithm import prune_step
    from repro.core.masks import apply_masks
    from repro.models import transformer as tfm
    from repro.models.plans import build_decode_plan

    base = get_arch("llama4-maverick-400b-a17b")
    cfg = scaled_down(base, dtype="float32")
    # 128-divisible expert width so the expert tensors tile
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, d_ff_expert=128,
                                     d_ff_shared=128))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = make_masks(params, moe_prunable)
    masks = prune_step(params, masks, "expert", 0.3, lambda p: False)
    masks = prune_step(params, masks, "xbar", 0.2, lambda p: False)
    pruned = apply_masks(params, masks)
    plan, stats = build_decode_plan(masks, interpret=True)
    moe_routed = [l for l in stats.by_layer if ".moe" in l[0]]
    assert moe_routed, "expert tensors must be routed"
    assert any(".moe.shared" in l[0] for l in stats.by_layer)
    assert stats.live_tiles < stats.total_tiles

    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l_dense, _ = tfm.loss_fn(pruned, cfg, batch)
    l_plan, _ = tfm.loss_fn(pruned, cfg, batch, plan=plan)
    np.testing.assert_allclose(float(l_plan), float(l_dense), rtol=1e-5)
