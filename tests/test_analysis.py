"""Sparsity lint: every rule code proven by a seeded defect.

Each test plants one specific defect — a bad recipe program, a
corrupted TilePlan, a closure that bypasses the block-sparse route —
and asserts the analyzer reports exactly that rule code.  A final
coverage check asserts the suite exercises every registered code, so a
new rule cannot land without its defect test.
"""
import copy
import json
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (RULES, Finding, Report, audit_closure,
                            collect_covered, lint_arch, lint_recipe,
                            verify_decode_plan, verify_engine,
                            verify_mask_accounting, verify_tile_plan,
                            verify_xbar_stats)
from repro.analysis.jaxpr_audit import audit_hlo_text, unambiguous_covered
from repro.api.recipes import Recipe, prune_stage, quantize_stage
from repro.core.crossbar import xbar_stats
from repro.kernels.bsmm import make_tile_plan
from repro.models.plans import PlanStats, build_decode_plan

# codes asserted by the tests below; the coverage test at the bottom
# demands this set equals the registry
TESTED = set()


def codes_of(findings):
    return {f.code for f in findings}


def assert_code(findings, code, severity=None):
    TESTED.add(code)
    got = codes_of(findings)
    assert code in got, f"expected {code} in {got}: {findings}"
    if severity:
        assert any(f.severity == severity for f in findings
                   if f.code == code)


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mask():
    rng = np.random.default_rng(0)
    m = (rng.random((256, 384)) < 0.4).astype(np.float32)
    m[:128, :128] = 0          # one dead tile
    m[128:, 256:] = 0          # another
    return m


@pytest.fixture(scope="module")
def plan(mask):
    return make_tile_plan(mask, tile=128, interpret=True)


@pytest.fixture(scope="module")
def lm_masks(mask):
    rng = np.random.default_rng(1)
    m2 = (rng.random((384, 256)) < 0.5).astype(np.float32)
    m2[:128, :] = 0
    return {"segments": [[{"mlp": {"up": mask, "down": m2}}]]}


# ---------------------------------------------------------------------------
# recipe linter: R001-R009
# ---------------------------------------------------------------------------
GRANS = ("filter", "channel", "index")


def test_r001_unresolvable_recipe():
    assert_code(lint_recipe("no-such-recipe-xyz"), "R001", "error")


def test_r002_unknown_granularity():
    r = Recipe(name="r", stages=(prune_stage("expert", rate=0.2),))
    assert_code(lint_recipe(r, allowed_granularities=GRANS, family="cnn"),
                "R002", "error")


def test_r003_non_monotonic_target():
    r = Recipe(name="r", stages=(
        prune_stage("filter", rate=0.3, target_sparsity=0.9),
        prune_stage("index", rate=0.3, target_sparsity=0.5),
    ))
    assert_code(lint_recipe(r), "R003", "error")


def test_r004_zero_retrain_budget():
    r = Recipe(name="r", stages=(
        prune_stage("filter", rate=0.3, retrain_steps=0),))
    assert_code(lint_recipe(r), "R004", "error")


def test_r005_quantize_before_prune():
    r = Recipe(name="r", stages=(
        quantize_stage(8), prune_stage("filter", rate=0.3)))
    assert_code(lint_recipe(r), "R005", "warning")


def test_r006_prune_after_quantize():
    r = Recipe(name="r", stages=(
        prune_stage("filter", rate=0.3), quantize_stage(8),
        prune_stage("index", rate=0.3)))
    assert_code(lint_recipe(r), "R006", "warning")


def test_r007_unreachable_target():
    # 2 rounds at 10% reach at most 19% — 0.99 is fiction
    r = Recipe(name="r", stages=(
        prune_stage("filter", rate=0.1, max_rounds=2,
                    target_sparsity=0.99),))
    assert_code(lint_recipe(r), "R007", "warning")


def test_r008_duplicate_stage_names():
    r = Recipe(name="r", stages=(
        prune_stage("filter", rate=0.3), prune_stage("filter", rate=0.2)))
    assert_code(lint_recipe(r), "R008", "warning")


def test_r009_no_prune_stage():
    r = Recipe(name="r", stages=(quantize_stage(8),))
    assert_code(lint_recipe(r), "R009", "warning")


def test_shipped_recipes_clean_of_errors():
    for name in ("cnn-full", "dense-full", "moe-full"):
        findings = lint_recipe(
            name, allowed_granularities=GRANS + ("expert",))
        assert not [f for f in findings if f.severity == "error"], findings


# ---------------------------------------------------------------------------
# invariant verifier: P101-P112
# ---------------------------------------------------------------------------
def test_healthy_plan_verifies_clean(plan, mask):
    assert verify_tile_plan(plan, mask) == []
    assert verify_tile_plan(plan) == []      # structure-only mode


def test_p101_out_of_bounds_index(plan, mask):
    bad = plan._replace(idx=np.full_like(np.asarray(plan.idx), 99))
    assert_code(verify_tile_plan(bad, mask), "P101", "error")


def test_p102_counts_disagree(plan, mask):
    counts = np.asarray(plan.counts).copy()
    counts[0] = max(0, counts[0] - 1)
    assert_code(verify_tile_plan(plan._replace(counts=counts), mask),
                "P102", "error")


def test_p103_live_set_disagrees(plan, mask):
    idx = np.asarray(plan.idx).copy()
    # swap a live row index for a dead one in the column with slack
    j = int(np.argmin(np.asarray(plan.counts)))
    c = int(np.asarray(plan.counts)[j])
    assert 0 < c < idx.shape[1] or c > 0
    dead = (set(range(idx.shape[1])) -
            set(int(v) for v in idx[j, :c]))
    idx[j, 0] = sorted(dead)[0]
    assert_code(verify_tile_plan(plan._replace(idx=idx), mask),
                "P103", "error")


def test_p104_cap_below_densest_column(plan, mask):
    cap = int(np.asarray(plan.counts).max()) - 1
    bad = plan._replace(idx=np.asarray(plan.idx)[:, :cap], kmax=cap)
    assert_code(verify_tile_plan(bad, mask), "P104", "error")


def test_p105_transpose_mismatch(plan, mask):
    counts_t = np.asarray(plan.counts_t).copy()
    counts_t[0] += 1
    assert_code(verify_tile_plan(plan._replace(counts_t=counts_t), mask),
                "P105", "error")


def test_p106_flat_coords_disagree(plan, mask):
    kk = np.asarray(plan.kk).copy()
    nn = np.asarray(plan.nn).copy()
    kk[0], nn[0] = 0, 0          # (0,0) is a dead tile in the fixture
    assert_code(verify_tile_plan(plan._replace(kk=kk, nn=nn), mask),
                "P106", "error")


def test_p107_tile_accounting(plan, mask):
    assert_code(verify_tile_plan(
        plan._replace(live_tiles=plan.live_tiles + 1), mask),
        "P107", "error")


def test_p108_geometry_mismatch(plan):
    wrong = np.ones((128, 384), np.float32)
    assert_code(verify_tile_plan(plan, wrong), "P108", "error")


def test_p109_decode_plan_drift(lm_masks):
    plan, stats = build_decode_plan(lm_masks, interpret=True)
    assert verify_decode_plan(lm_masks, plan, stats) == []
    # missing entry: the projection silently runs dense
    missing = copy.deepcopy(plan)
    del missing[0][0]["mlp"]["up"]
    assert_code(verify_decode_plan(lm_masks, missing), "P109", "error")
    # stale entry: plan leaf from different masks
    stale = copy.deepcopy(plan)
    stale[0][0]["mlp"]["up"] = stale[0][0]["mlp"]["down"]
    assert_code(verify_decode_plan(lm_masks, stale), "P109", "error")


def test_p110_planstats_totals(lm_masks):
    plan, stats = build_decode_plan(lm_masks, interpret=True)
    bad = PlanStats(routed=stats.routed,
                    live_tiles=stats.live_tiles + 1,
                    total_tiles=stats.total_tiles)
    assert_code(verify_decode_plan(lm_masks, plan, bad), "P110", "error")


def test_p111_xbar_stats(mask):
    st = xbar_stats(mask != 0, 128, 128)
    assert verify_xbar_stats(st, mask) == []
    st.nonzero_cells += 3
    assert_code(verify_xbar_stats(st, mask), "P111", "error")


def test_mask_accounting_walks_pytree(mask):
    rng = np.random.default_rng(2)
    masks = {"convs": [{"w": (rng.random((3, 3, 8, 16)) < 0.5)
                        .astype(np.float32)}],
             "fc": {"w": mask}, "b": None}
    out = verify_mask_accounting(masks, lambda p: p.startswith("convs"),
                                 rows=128, cols=128)
    assert out == []


def test_p112_engine_consistency(lm_masks):
    plan, stats = build_decode_plan(lm_masks, interpret=True)
    g0 = SimpleNamespace(gid=0, masks=None, plan=None, plan_stats=None)
    dup = SimpleNamespace(gid=0, masks=None, plan=None, plan_stats=None)
    eng = SimpleNamespace(generations=(g0, dup), report=None)
    assert_code(verify_engine(eng), "P112", "error")
    # plan without masks
    orphan = SimpleNamespace(gid=1, masks=None, plan=plan,
                             plan_stats=stats)
    eng2 = SimpleNamespace(
        generations=(g0, orphan),
        report=SimpleNamespace(
            skipped_tile_fraction=stats.skipped_tile_fraction))
    assert_code(verify_engine(eng2), "P112", "error")
    # stale plan inside a generation surfaces as P112 too
    stale = copy.deepcopy(plan)
    stale[0][0]["mlp"]["up"] = stale[0][0]["mlp"]["down"]
    bad_gen = SimpleNamespace(gid=2, masks=lm_masks, plan=stale,
                              plan_stats=stats)
    eng3 = SimpleNamespace(
        generations=(bad_gen,),
        report=SimpleNamespace(
            skipped_tile_fraction=stats.skipped_tile_fraction))
    assert_code(verify_engine(eng3), "P112", "error")


# ---------------------------------------------------------------------------
# paged KV invariants: P113-P115
# ---------------------------------------------------------------------------
def test_p115_block_pool_accounting():
    from repro.analysis import verify_block_pool
    from repro.serve import BlockPool
    pool = BlockPool(6)
    pool.reserve(1, 2)
    pool.alloc(1)
    assert verify_block_pool(pool) == []
    # seeded defect: a block tracked as both free and owned
    pool._owned[1].append(pool._free[-1])
    assert_code(verify_block_pool(pool), "P115", "error")
    # seeded defect: a block leaks out of the accounting entirely
    pool2 = BlockPool(6)
    pool2._free.pop()
    assert_code(verify_block_pool(pool2), "P115", "error")


def test_p113_block_table_consistency():
    from repro.analysis import verify_block_tables
    from repro.serve import BlockPool
    T = 128
    pool = BlockPool(8)
    pool.reserve(7, 3)
    b0, b1 = pool.alloc(7), pool.alloc(7)
    tables = np.zeros((2, 4), np.int32)
    tables[0, :2] = [b0, b1]
    lens = np.array([T + 5, 0], np.int32)
    nbs = np.array([2, 0], np.int64)
    uids = [7, None]
    kw = dict(block_tokens=T)
    assert verify_block_tables(pool, tables, lens, nbs, uids, **kw) == []
    # logical order broken vs pool ownership
    bad = tables.copy()
    bad[0, :2] = [b1, b0]
    assert_code(verify_block_tables(pool, bad, lens, nbs, uids, **kw),
                "P113", "error")
    # block count disagrees with the token count
    short = lens.copy()
    short[0] = 5                      # 5 tokens need 1 block, slot holds 2
    assert_code(verify_block_tables(pool, tables, short, nbs, uids, **kw),
                "P113", "error")
    # inactive slot with leftover state
    stale = lens.copy()
    stale[1] = 4
    assert_code(verify_block_tables(pool, tables, stale, nbs, uids, **kw),
                "P113", "error")
    # dead tail entry off the scratch block
    tail = tables.copy()
    tail[0, 3] = 5
    assert_code(verify_block_tables(pool, tail, lens, nbs, uids, **kw),
                "P113", "error")


def test_p114_paged_reconstruction():
    from repro.analysis import verify_paged_reconstruction
    from repro.models import attention as attn
    rng = np.random.default_rng(3)
    T = attn.BLOCK_TOKENS
    H, d, S = 2, 4, T + 3
    k = jnp.asarray(rng.random((1, S, H, d)), jnp.float32)
    v = jnp.asarray(rng.random((1, S, H, d)), jnp.float32)
    dense = [[attn.KVCache(k, v, jnp.asarray(S, jnp.int32))]]
    empty = attn.PagedKVCache(jnp.zeros((4, T, H, d), jnp.float32),
                              jnp.zeros((4, T, H, d), jnp.float32))
    blocks = jnp.asarray([1, 2], jnp.int32)
    adopted = [[attn.gqa_paged_adopt(empty, dense[0][0], blocks)]]
    assert verify_paged_reconstruction(adopted, dense, blocks, S) == []
    # seeded defect: gathering in the wrong logical order
    assert_code(verify_paged_reconstruction(adopted, dense, [2, 1], S),
                "P114", "error")


def test_p116_fleet_accounting():
    from repro.analysis import verify_fleet
    from repro.serve import FleetRecord, FleetReport

    def rec(uid, toks, status="done"):
        r = FleetRecord(uid=uid, prompt=np.zeros(2, np.int32),
                        max_new_tokens=4, seq=uid)
        r.tokens = list(toks)
        r.status = status
        return r

    def router(finished, records, per, tokens):
        return SimpleNamespace(
            finished=finished, records=records, rejected=[], idle=True,
            live=set(), frontends=[],
            report=FleetReport(engines=len(per), live_engines=len(per),
                               requests=len(finished),
                               tokens_generated=tokens, per_engine=per))

    a, b = rec(0, [1, 2]), rec(1, [3])
    per = [SimpleNamespace(tokens_generated=2, requests=1),
           SimpleNamespace(tokens_generated=1, requests=1)]
    healthy = router([a, b], {0: a, 1: b}, per, 3)
    assert verify_fleet(healthy) == []
    # seeded defect: one uid finished twice across engines
    assert_code(verify_fleet(router([a, a, b], {0: a, 1: b}, per, 3)),
                "P116", "error")
    # seeded defect: a submitted request vanished (idle but never done)
    lost = rec(2, [], status="running")
    assert_code(
        verify_fleet(router([a, b], {0: a, 1: b, 2: lost}, per, 3)),
        "P116", "error")
    # seeded defect: merged token total disagrees with per-engine sums
    inflated = [SimpleNamespace(tokens_generated=2, requests=1),
                SimpleNamespace(tokens_generated=2, requests=1)]
    assert_code(verify_fleet(router([a, b], {0: a, 1: b}, inflated, 3)),
                "P116", "error")


def test_p116_live_fleet_clean():
    """A real two-engine fleet drained to idle verifies clean."""
    from repro.analysis import verify_fleet
    from repro.api.registry import make_adapter
    from repro.serve import FleetRouter, ServeEngine

    ad = make_adapter("llama3.2-3b", scale="tiny")
    params = ad.init_params(jax.random.PRNGKey(0))
    prefill_fn, decode_fn = ad.serve_fns()

    def eng():
        return ServeEngine(params=params, cfg=ad.cfg,
                           prefill_fn=prefill_fn, decode_fn=decode_fn,
                           batch_slots=2, capacity=48)

    router = FleetRouter([eng(), eng()])
    rng = np.random.RandomState(0)
    for i in range(4):
        router.submit(rng.randint(1, ad.cfg.vocab_size, 5)
                      .astype(np.int32), uid=i, max_new_tokens=4)
    router.drain()
    assert verify_fleet(router) == []
    TESTED.add("P116")


# ---------------------------------------------------------------------------
# jaxpr auditor: J201-J208
# ---------------------------------------------------------------------------
def test_j201_dense_dot_on_covered_shape(plan, mask):
    covered = collect_covered({"mlp": {"up": plan}})
    assert (256, 384) in covered
    w = jnp.asarray(mask)

    @jax.jit
    def dense_fn(x):
        return x @ w             # plan covers (256, 384): routing miss

    x = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    findings = audit_closure(dense_fn, [x], covered=covered)
    assert_code(findings, "J201", "error")


def test_routed_closure_is_clean(plan, mask):
    from repro.kernels.bsmm import plan_matmul
    covered = collect_covered({"mlp": {"up": plan}})
    w = jnp.asarray(mask)

    @jax.jit
    def routed(x):
        return plan_matmul(x, w, plan)

    x = jax.ShapeDtypeStruct((4, 256), jnp.float32)
    assert audit_closure(routed, [x], covered=covered) == []


def test_j202_f64_promotion():
    from jax.experimental import enable_x64
    with enable_x64():
        @jax.jit
        def f(x):
            return x.astype(jnp.float64) * 2.0
        findings = audit_closure(
            f, [jax.ShapeDtypeStruct((4,), jnp.float32)])
    assert_code(findings, "J202", "warning")


def test_j203_host_callback():
    @jax.jit
    def f(x):
        jax.debug.print("v={v}", v=x.sum())
        return x
    findings = audit_closure(f, [jax.ShapeDtypeStruct((4,), jnp.float32)])
    assert_code(findings, "J203", "warning")


def test_j204_unjitted_closure():
    findings = audit_closure(
        lambda x: x * 2, [jax.ShapeDtypeStruct((4,), jnp.float32)])
    assert_code(findings, "J204", "warning")


def test_j205_no_pallas_call_at_all(plan):
    covered = collect_covered({"up": plan})

    @jax.jit
    def elementwise(x):
        return x * 2 + 1         # no matmul, no pallas: routing is off

    findings = audit_closure(
        elementwise, [jax.ShapeDtypeStruct((4, 256), jnp.float32)],
        covered=covered)
    assert_code(findings, "J205", "error")
    assert "J201" not in codes_of(findings)


def test_j206_j207_hlo_cross_check():
    text = ("%ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups={}\n"
            "%p = f64[8]{0} add(f64[8]{0} %a, f64[8]{0} %b)\n")
    findings = audit_hlo_text(text)
    assert_code(findings, "J206", "warning")
    assert_code(findings, "J207", "info")


def test_audit_compiled_clean():
    from repro.analysis import audit_compiled
    out = audit_compiled(lambda x: x * 2, [jnp.ones((4,), jnp.float32)])
    assert out == []


def test_j208_sharding_placement():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.analysis import audit_engine_sharding

    w = jnp.zeros((4, 4), jnp.float32)
    # >1-device mesh, params without any NamedSharding: error
    eng = SimpleNamespace(
        mesh=SimpleNamespace(size=2),
        generations=[SimpleNamespace(gid=0, params={"w": w})])
    assert_code(audit_engine_sharding(eng), "J208", "error")
    # NamedShardings present but all fully replicated: warning
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("model",))
    wr = jax.device_put(w, NamedSharding(mesh1, P()))
    eng2 = SimpleNamespace(
        mesh=SimpleNamespace(size=2),
        generations=[SimpleNamespace(gid=1, params={"w": wr})])
    assert_code(audit_engine_sharding(eng2), "J208", "warning")
    # 1-device mesh (or no mesh): nothing to place, silent
    eng3 = SimpleNamespace(mesh=mesh1, generations=eng.generations)
    assert audit_engine_sharding(eng3) == []
    assert audit_engine_sharding(SimpleNamespace(mesh=None)) == []


def test_unambiguous_covered_drops_shape_collisions(plan):
    plan_tree = {"up": plan}
    routed_only = {"w": jnp.zeros((256, 384), jnp.float32)}
    assert (256, 384) in unambiguous_covered(plan_tree, routed_only)
    # a second, non-routed weight of the same shape makes it ambiguous
    collided = {"w": jnp.zeros((256, 384), jnp.float32),
                "other": jnp.zeros((256, 384), jnp.float32)}
    assert unambiguous_covered(plan_tree, collided) == {}


# ---------------------------------------------------------------------------
# findings model + driver + CLI
# ---------------------------------------------------------------------------
def test_finding_rejects_unregistered_code():
    with pytest.raises(ValueError):
        Finding("error", "X999", "here", "nope")
    with pytest.raises(ValueError):
        Finding("fatal", "P101", "here", "nope")


def test_report_accounting():
    r = Report()
    r.add(Finding("error", "P101", "a", "m"))
    r.add(Finding("warning", "R005", "b", "m"))
    assert not r.ok and len(r.errors) == 1 and len(r.warnings) == 1
    assert r.by_code("P101")[0].where == "a"
    loaded = json.loads(r.to_json())
    assert loaded["summary"]["error"] == 1
    assert loaded["findings"][0]["code"] == "P101"


def test_lint_arch_cnn_smoke():
    rep = lint_arch("vgg11")
    assert rep.ok, rep.findings


@pytest.mark.slow
def test_lint_arch_serving_smoke():
    # full pipeline incl. ServeEngine hot-swap + P112 verification
    rep = lint_arch("llama3.2-3b")
    assert rep.ok, rep.findings


def test_cli_lint(capsys):
    from repro.api.cli import main
    assert main(["lint", "--arch", "vgg11", "--json"]) == 0
    out = json.loads(capsys.readouterr().out.strip())
    assert out["arch"] == "vgg11" and out["summary"]["ok"]


def test_cli_lint_fails_on_error_findings(monkeypatch):
    from repro.api import cli as cli_mod
    import repro.analysis as analysis_mod

    def bad_lint(name, **kw):
        r = Report()
        r.add(Finding("error", "P101", f"{name}/x", "seeded"))
        return r

    monkeypatch.setattr(analysis_mod, "lint_arch", bad_lint)
    assert cli_mod.main(["lint", "--arch", "vgg11", "--json"]) == 1


# keep last: every registered R/P/J rule code must have a defect test
# above (K3xx codes are exercised by tests/test_kernel_audit.py, whose
# own coverage test closes the other half; tests/test_rules_meta.py
# asserts the two halves tile the registry exactly)
def test_every_rule_code_is_exercised():
    expected = {c for c in RULES if not c.startswith("K")}
    assert TESTED == expected, \
        f"untested rule codes: {sorted(expected - TESTED)}"
