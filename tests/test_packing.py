"""Physical FFN packing: pruned model == packed model, fewer FLOPs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, scaled_down
from repro.core import algorithm as alg
from repro.core.masks import apply_masks, lm_prunable, make_masks
from repro.core.packing import pack_ffn, pack_lm_params
from repro.models import transformer as tfm


def test_pack_ffn_exact_on_2d():
    rng = np.random.RandomState(0)
    d, ff = 32, 512
    up = rng.randn(d, ff).astype(np.float32)
    gate = rng.randn(d, ff).astype(np.float32)
    down = rng.randn(ff, d).astype(np.float32)
    m = np.ones((d, ff), np.float32)
    dead = rng.choice(ff, size=400, replace=False)
    m[:, dead] = 0.0
    md = np.ones((ff, d), np.float32)
    md[dead, :] = 0.0
    up_p, gate_p, down_p, ffp = pack_ffn(up, gate, down, m, m, md)
    assert ffp == 128                 # 112 live → rounded to one lane tile
    x = rng.randn(4, d).astype(np.float32)
    h_ref = (jax.nn.silu(x @ (gate * m)) * (x @ (up * m))) @ (down * md)
    h_pack = (jax.nn.silu(x @ gate_p) * (x @ up_p)) @ down_p
    np.testing.assert_allclose(np.asarray(h_pack), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_pack_lm_preserves_logits():
    cfg = scaled_down(get_arch("yi-6b"), dtype="float32", d_ff=512)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = make_masks(params, lm_prunable)
    # filter-prune the MLPs hard so most columns die
    for _ in range(4):
        masks = alg.prune_step(params, masks, "filter", 0.4,
                               lambda p: False)
    pruned = apply_masks(params, masks)
    batch = {"tokens": jnp.arange(64).reshape(2, 32) % 100}
    logits_ref, _ = tfm.forward(pruned, cfg, batch)
    packed, cfg_p = pack_lm_params(pruned, masks, cfg)
    assert cfg_p.d_ff < cfg.d_ff
    logits_pack, _ = tfm.forward(packed, cfg_p, batch)
    np.testing.assert_allclose(np.asarray(logits_pack),
                               np.asarray(logits_ref),
                               rtol=2e-3, atol=2e-3)


def test_pack_noop_when_dense():
    cfg = scaled_down(get_arch("yi-6b"), dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    masks = make_masks(params, lm_prunable)
    packed, cfg_p = pack_lm_params(params, masks, cfg)
    assert cfg_p.d_ff == cfg.d_ff
