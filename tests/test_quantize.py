"""Fixed-point quantization: round-trip error, masked zeros, bandwidth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import (QTensor, dequantize, qmatmul, quantize,
                                 quantize_tree, tree_bytes)


def test_roundtrip_error_bounded():
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    for bits, tol in ((8, 1e-2), (16, 1e-4)):
        qt = quantize(w, bits)
        back = dequantize(qt, jnp.float32)
        err = np.abs(np.asarray(back) - np.asarray(w)).max()
        scale_max = float(np.asarray(qt.scale).max())
        assert err <= scale_max * 0.5 + 1e-9
        assert err < tol * np.abs(np.asarray(w)).max() * 2


def test_pruned_weights_stay_zero():
    rng = np.random.RandomState(1)
    w = rng.randn(32, 16).astype(np.float32)
    w[:, :8] = 0.0                     # filter-pruned columns
    qt = quantize(jnp.asarray(w), 8)
    assert (np.asarray(qt.q)[:, :8] == 0).all()
    assert (np.asarray(dequantize(qt, jnp.float32))[:, :8] == 0).all()


def test_qmatmul_close_to_dense():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(4, 64), jnp.float32)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    qt = quantize(w, 8)
    out = qmatmul(x, qt)
    ref = x @ w
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.02


def test_int16_matches_reram_precision():
    """16-bit fixed point (the paper's ReRAM precision) is ~lossless
    for bf16-scale weights."""
    rng = np.random.RandomState(3)
    w = jnp.asarray(rng.randn(128, 64) * 0.02, jnp.float32)
    qt = quantize(w, 16)
    back = dequantize(qt, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w),
                               rtol=5e-4, atol=5e-6)


def test_tree_quantize_and_bytes():
    rng = np.random.RandomState(4)
    params = {"attn": {"wq": jnp.asarray(rng.randn(64, 64), jnp.float32)},
              "norm": {"scale": jnp.ones((64,), jnp.float32)}}
    dense_bytes = tree_bytes(params)
    qparams = quantize_tree(params, lambda p, l: l.ndim >= 2, bits=8)
    assert isinstance(qparams["attn"]["wq"], QTensor)
    assert not isinstance(qparams["norm"]["scale"], QTensor)
    qbytes = tree_bytes(qparams)
    # int8 + scales ≈ 1/4 of f32 storage for the matrix part
    assert qbytes < dense_bytes * 0.35


def test_quantize_composes_with_packing():
    """pack → quantize: serving weights shrink by sparsity × 4 (f32→int8)."""
    from repro.core.packing import pack_ffn
    rng = np.random.RandomState(5)
    d, ff = 32, 512
    up = rng.randn(d, ff).astype(np.float32)
    down = rng.randn(ff, d).astype(np.float32)
    m = np.ones((d, ff), np.float32)
    m[:, 128:] = 0.0                      # 75% columns dead
    md = np.ones((ff, d), np.float32)
    md[128:, :] = 0.0
    up_p, _, down_p, ffp = pack_ffn(up, None, down, m, None, md)
    q_up = quantize(up_p, 8)
    dense_bytes = up.size * 4
    assert q_up.nbytes < dense_bytes * 0.08   # 4× (int8) × 4× (packing)
