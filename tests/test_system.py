"""End-to-end behaviour tests for the paper's system.

The full story on one small CNN: baseline training reaches high
accuracy → ReaLPrune finds a sparse ticket with no accuracy drop →
the ticket's sparsity translates to crossbar savings and an iso-area
ReRAM training speedup > 1 → the surviving masks drive the TPU
block-sparse kernel with matching tile accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNNConfig, ConvSpec, PruneConfig
from repro.core import algorithm as alg
from repro.core import perf_model as pm
from repro.core.hardware import analyze_masks, cnn_activation_volumes
from repro.core.masks import apply_masks, cnn_prunable, path_str
from repro.data import SyntheticImages
from repro.models import cnn as cnn_lib
from repro.optim import exponential_epoch_decay, masked, sgd

# the module fixture trains a real (small) CNN through Algorithm 1 —
# ~85s of the default suite; CI's slow job keeps the coverage
pytestmark = pytest.mark.slow

CFG = CNNConfig(name="sys-cnn", family="cnn",
                convs=(ConvSpec(32, pool=True), ConvSpec(64, pool=True),
                       ConvSpec(64)),
                fc=(), num_classes=10, image_size=16)
DATA = SyntheticImages(image_size=16, noise=0.25)
CONV_PRED = lambda p: "convs" in p    # noqa: E731


@pytest.fixture(scope="module")
def pipeline():
    rng = jax.random.PRNGKey(0)
    params0, bn0 = cnn_lib.init_params(rng, CFG)
    holder = {"bn": bn0}

    def train_fn(params, masks, steps=70):
        opt = masked(sgd(exponential_epoch_decay(0.05, 0.95, 40)), masks)
        opt_state = opt.init(params)
        state, params = bn0, apply_masks(params, masks)

        @jax.jit
        def step(params, opt_state, state, batch):
            def lf(p):
                loss, (nst, _) = cnn_lib.loss_fn(p, state, CFG, batch, True)
                return loss, nst
            (loss, nst), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, nst, loss

        for i in range(steps):
            b = DATA.batch(i, 64)
            params, opt_state, state, _ = step(
                params, opt_state, state,
                {"images": jnp.asarray(b["images"]),
                 "labels": jnp.asarray(b["labels"])})
        holder["bn"] = state
        return params

    def eval_fn(params, masks):
        accs = [float(cnn_lib.accuracy(
            params, holder["bn"], CFG,
            jnp.asarray(DATA.batch(10_000 + i, 128)["images"]),
            jnp.asarray(DATA.batch(10_000 + i, 128)["labels"])))
            for i in range(3)]
        return float(np.mean(accs))

    res = alg.realprune(
        init_params=params0, train_fn=train_fn, eval_fn=eval_fn,
        prunable=cnn_prunable, conv_pred=CONV_PRED,
        cfg=PruneConfig(prune_fraction=0.15, max_iters=10,
                        accuracy_tolerance=0.02))
    return res, eval_fn, train_fn


def test_ticket_is_sparse_with_no_accuracy_drop(pipeline):
    res, eval_fn, train_fn = pipeline
    assert res.sparsity > 0.3
    # last ACCEPTED event's accuracy met the gate by construction
    accepted = [e for e in res.history if e.accepted]
    assert accepted, "no prune step was accepted"
    assert accepted[-1].accuracy >= 0.95


def test_coarse_to_fine_schedule_followed(pipeline):
    res, _, _ = pipeline
    order = {"filter": 0, "channel": 1, "index": 2}
    seen = [order[e.granularity] for e in res.history]
    assert seen == sorted(seen)        # never goes back to coarser


def test_sparsity_translates_to_hardware_savings(pipeline):
    res, _, _ = pipeline
    rep = analyze_masks(res.masks, CONV_PRED,
                        activation_volumes=cnn_activation_volumes(CFG))
    assert rep.cell_savings > 0.1
    assert rep.xbar_savings > 0.1
    vols = cnn_activation_volumes(CFG)
    unpruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.n_xbars for l in rep.layers}, vols)
    pruned = pm.conv_layer_perf(
        CFG, {l.path: l.stats.xbars_needed_packed for l in rep.layers},
        vols)
    assert pm.iso_area_speedup(unpruned, pruned) > 1.0


def test_masks_drive_bsmm_consistently(pipeline):
    res, _, _ = pipeline
    from repro.core.crossbar import conv_to_matrix
    from repro.kernels.ops import sparse_dense, tile_density

    leaf = None

    def grab(path, x):
        nonlocal leaf
        if x is not None and path_str(path) == "convs/2/w":
            leaf = np.asarray(x)
        return x

    jax.tree_util.tree_map_with_path(grab, res.masks,
                                     is_leaf=lambda x: x is None)
    mat_mask = conv_to_matrix(leaf)
    rng = np.random.RandomState(0)
    K, N = mat_mask.shape
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    x = jnp.asarray(rng.randn(4, K), jnp.float32)
    out = sparse_dense(x, w, mat_mask)
    ref = x @ (w * jnp.asarray(mat_mask, jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)
