"""MoE routing/dispatch/combine vs a dense per-expert reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig
from repro.models import moe as moe_lib
from repro.models.layers import _act, mlp


def dense_reference(params, x, moe, act, gated):
    """Loop over experts densely; no capacity limit."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xt)
    for e in range(moe.num_experts):
        up = xt @ params["up"][e]
        h = _act(act, xt @ params["gate"][e]) * up if "gate" in params \
            else _act(act, up)
        y_e = h @ params["down"][e]
        w_e = jnp.where(top_e == e, top_w, 0.0).sum(-1)
        out = out + y_e * w_e[:, None]
    if "shared" in params:
        out = out + mlp(params["shared"], xt, act)
    return out.reshape(B, S, d)


@pytest.fixture(scope="module")
def setup():
    moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                    num_shared_experts=1, d_ff_shared=32,
                    capacity_factor=8.0)     # high cf → no drops
    rng = jax.random.PRNGKey(0)
    params = moe_lib.moe_init(rng, 16, moe, gated=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16)) * 0.5
    return moe, params, x


def test_moe_matches_dense_reference(setup):
    moe, params, x = setup
    out = moe_lib.moe_forward(params, x, moe, "silu", True)
    ref = dense_reference(params, x, moe, "silu", True)
    assert float(out.drop_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens(setup):
    moe, params, x = setup
    tight = dataclasses.replace(moe, capacity_factor=0.25)
    out = moe_lib.moe_forward(params, x, tight, "silu", True)
    assert float(out.drop_fraction) > 0.0
    assert not np.any(np.isnan(np.asarray(out.y)))


def test_aux_loss_penalizes_imbalance():
    """Switch aux ≈ 1 for balanced routing; grows when dispatch and
    router probabilities concentrate on few experts."""
    moe = MoEConfig(num_experts=8, top_k=1, d_ff_expert=16)
    rng = jax.random.PRNGKey(2)
    params = moe_lib.moe_init(rng, 8, moe, gated=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 8))
    out = moe_lib.moe_forward(params, x, moe, "gelu", False)
    aux_init = float(out.aux_loss)
    assert 0.8 < aux_init < 3.0      # near-uniform at init
    # skew the router hard toward one expert
    params2 = dict(params)
    params2["router"] = jnp.zeros_like(params["router"]
                                       ).at[:, 0].set(10.0)
    out2 = moe_lib.moe_forward(params2, x, moe, "gelu", False)
    assert float(out2.aux_loss) > 1.5 * aux_init


def test_moe_grads_flow_to_experts(setup):
    moe, params, x = setup

    def loss(p):
        return jnp.sum(moe_lib.moe_forward(p, x, moe, "silu", True).y ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["up"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_expert_capacity_rounding():
    moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16,
                    capacity_factor=1.25)
    c = moe_lib.expert_capacity(1024, moe)
    assert c % 8 == 0 and c >= 1024 * 2 * 1.25 / 8
