"""True elastic restart: checkpoint on an 8-device mesh, restore and
continue on a 4-device mesh (subprocess with forced host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.distributed.sharding import ShardingRules, install
    from repro.models import transformer as tfm
    from repro.configs import get_arch, scaled_down

    ckpt_dir = sys.argv[1]
    cfg = scaled_down(get_arch("llama3.2-3b"), dtype="float32",
                      d_model=128, n_heads=4, n_kv_heads=4, head_dim=32)

    def make(mesh_shape, axes):
        mesh = jax.make_mesh(mesh_shape, axes)
        rules = ShardingRules(mesh)
        install(rules)
        return mesh, rules

    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((8, 16), jnp.int32),
             "labels": jnp.ones((8, 16), jnp.int32)}

    # phase 1: big mesh (2 data × 4 model) — train one step, checkpoint
    mesh, rules = make((2, 4), ("data", "model"))
    p1 = jax.device_put(params, rules.params_shardings(params))
    with mesh:
        loss1, _ = jax.jit(lambda p, b: tfm.loss_fn(p, cfg, b))(p1, batch)
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(1, {"params": p1})

    # phase 2: "lost half the hosts" — restore onto (2 data × 2 model)
    mesh2, rules2 = make((2, 2), ("data", "model"))
    template = {"params": jax.tree.map(jnp.zeros_like, params)}
    step, tree = mgr.restore(
        template, shardings={"params": rules2.params_shardings(params)})
    assert step == 1
    with mesh2:
        loss2, _ = jax.jit(lambda p, b: tfm.loss_fn(p, cfg, b))(
            tree["params"], batch)
    assert abs(float(loss1) - float(loss2)) < 1e-3, (float(loss1),
                                                     float(loss2))
    # verify the restored leaves really live on the new 4-device mesh
    leaf = jax.tree.leaves(tree["params"])[0]
    assert len(leaf.sharding.mesh.devices.reshape(-1)) == 4
    print("ELASTIC_OK", float(loss1), float(loss2))
""")


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
