"""Recurrent cells: parallel forms vs sequential oracles; decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as R

B, S, w, H, d = 2, 64, 32, 4, 16
# eager per-token step loops are dispatch-bound: a shorter window still
# proves step==scan while keeping the default suite fast
S_STEP = 24


@pytest.fixture(scope="module")
def rngs():
    return jax.random.split(jax.random.PRNGKey(0), 4)


def test_mlstm_chunkwise_equals_sequential(rngs):
    p = R.mlstm_cell_init(rngs[0], w, H)
    u = jax.random.normal(rngs[1], (B, S, w)) * 0.5
    h_seq, st_seq = R.mlstm_sequential(p, u, H)
    for chunk in (8, 16, 32):
        h_chk, st_chk = R.mlstm_chunkwise(p, u, H, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_seq), np.asarray(h_chk),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st_seq.C),
                                   np.asarray(st_chk.C),
                                   rtol=2e-4, atol=2e-4)


def test_mlstm_state_carries_across_calls(rngs):
    p = R.mlstm_cell_init(rngs[0], w, H)
    u = jax.random.normal(rngs[1], (B, S, w)) * 0.5
    h_full, st_full = R.mlstm_sequential(p, u, H)
    h1, st1 = R.mlstm_sequential(p, u[:, : S // 2], H)
    h2, st2 = R.mlstm_sequential(p, u[:, S // 2:], H, st1)
    np.testing.assert_allclose(np.asarray(h_full[:, S // 2:]),
                               np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_rglru_parallel_equals_stepwise(rngs):
    p = R.rglru_init(rngs[0], d, w, H, 4)
    x = jax.random.normal(rngs[2], (B, S_STEP, d)) * 0.5
    y_full, st = R.rglru_make_cache(p, x)
    st2 = R.rglru_init_state(p, B)
    ys = []
    for t in range(S_STEP):
        yt, st2 = R.rglru_step(p, st2, x[:, t:t + 1])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2.h), np.asarray(st.h),
                               rtol=1e-4, atol=1e-4)


def test_rglru_stability_long_sequence(rngs):
    p = R.rglru_init(rngs[0], d, w, H, 4)
    x = jax.random.normal(rngs[2], (1, 1024, d)) * 3.0
    y = R.rglru_forward(p, x)
    assert not np.any(np.isnan(np.asarray(y)))
    assert np.abs(np.asarray(y)).max() < 1e3    # decay keeps state bounded


def test_slstm_step_equals_scan(rngs):
    p = R.slstm_cell_init(rngs[0], d, w, H)
    x = jax.random.normal(rngs[3], (B, S_STEP, d)) * 0.5
    h_full, st_full = R.slstm_forward(p, x)
    st = R.slstm_init_state(B, w)
    hs = []
    for t in range(S_STEP):
        ht, st = R.slstm_step(p, st, x[:, t:t + 1])
        hs.append(ht)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(hs, 1)),
                               np.asarray(h_full), rtol=1e-4, atol=1e-4)


def test_slstm_exponential_gate_stability(rngs):
    p = R.slstm_cell_init(rngs[0], d, w, H)
    x = jax.random.normal(rngs[3], (B, 512, d)) * 5.0
    h, _ = R.slstm_forward(p, x)
    assert not np.any(np.isnan(np.asarray(h)))
    assert np.abs(np.asarray(h)).max() <= 1.0 + 1e-5   # o·c/n bounded


def test_conv1d_step_equals_full(rngs):
    p = R.conv1d_init(rngs[0], w, 4)
    u = jax.random.normal(rngs[1], (B, S, w))
    full = R.conv1d_apply(p, u)
    state = jnp.zeros((B, 3, w))
    outs = []
    for t in range(S):
        y, state = R.conv1d_step(p, state, u[:, t])
        outs.append(y[:, None])
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
