"""Registry/test-suite coverage meta-checks.

The rule registry (``analysis.findings.RULES``) is the single source of
truth for codes, titles, and docs; these tests pin its contract:

* every registered code is exercised as a quoted literal in a
  seeded-defect test (R/P/J in tests/test_analysis.py, K3xx in
  tests/test_kernel_audit.py — each file's own terminal coverage test
  enforces the *semantic* half, this one catches a code being added to
  the registry with no test at all);
* the README rules table is generated from the registry and agrees
  with it verbatim.
"""
import re
from pathlib import Path

from repro.analysis import RULES, rules_markdown

TESTS = Path(__file__).parent
README = TESTS.parent / "README.md"

_DEFECT_FILES = {
    "R": "test_analysis.py",
    "P": "test_analysis.py",
    "J": "test_analysis.py",
    "K": "test_kernel_audit.py",
}


def test_every_rule_code_appears_in_its_defect_test_file():
    sources = {f: (TESTS / f).read_text()
               for f in set(_DEFECT_FILES.values())}
    missing = [code for code, rule in RULES.items()
               if f'"{code}"' not in sources[_DEFECT_FILES[code[0]]]]
    assert not missing, \
        f"registered rules with no seeded-defect test: {sorted(missing)}"


def test_rule_families_tile_the_registry():
    assert {c[0] for c in RULES} == set(_DEFECT_FILES)
    for rule in RULES.values():
        assert rule.title and rule.doc, rule.code
        assert re.fullmatch(r"[RPJK]\d{3}", rule.code)


def test_readme_rules_table_matches_registry():
    readme = README.read_text()
    for line in rules_markdown().splitlines():
        assert line in readme, \
            f"README rules table out of date; regenerate with\n" \
            f"  PYTHONPATH=src python -c \"from repro.analysis import " \
            f"rules_markdown; print(rules_markdown())\"\n" \
            f"missing line: {line}"
