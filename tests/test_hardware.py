"""Hardware savings accounting + ReRAM perf model (paper Figs 6-8)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_cnn
from repro.core import hardware as hw
from repro.core import perf_model as pm


def _masks(conv_mask, fc_mask):
    return {"convs": [{"w": jnp.asarray(conv_mask)}],
            "fc": [{"w": jnp.asarray(fc_mask)}]}


def test_unstructured_vs_structured_savings_gap():
    """The paper's central claim (Fig 5 vs 6): high unstructured sparsity
    yields low hardware savings; structured sparsity converts ~1:1."""
    rng = np.random.RandomState(0)
    # unstructured 90%: nonzeros scattered
    m_unstruct = (rng.rand(3, 3, 64, 128) < 0.1).astype(np.float32)
    # structured: 90% of columns (filters) dead
    m_struct = np.ones((3, 3, 64, 128), np.float32)
    dead = rng.choice(128, size=115, replace=False)
    m_struct[:, :, :, dead] = 0.0

    fc = np.ones((128, 10), np.float32)
    rep_u = hw.analyze_masks(_masks(m_unstruct, fc), lambda p: "convs" in p)
    rep_s = hw.analyze_masks(_masks(m_struct, fc), lambda p: "convs" in p)
    assert rep_u.sparsity > 0.85
    assert rep_s.sparsity > 0.85
    assert rep_u.cell_savings < 0.25          # scattered → little savings
    assert rep_s.cell_savings > 0.80          # structured → ~sparsity


def test_savings_never_exceed_sparsity():
    rng = np.random.RandomState(1)
    m = (rng.rand(3, 3, 32, 64) < 0.5).astype(np.float32)
    rep = hw.analyze_masks(_masks(m, np.ones((64, 10), np.float32)),
                           lambda p: "convs" in p)
    assert rep.cell_savings <= rep.sparsity + 1e-9


def test_activation_savings_only_from_dead_filters():
    m = np.ones((3, 3, 8, 16), np.float32)
    m[:, :, :4, :] = 0.0          # channel pruning: no filter fully dead
    vols = {"convs/0/w": 1024.0}
    rep = hw.analyze_masks(_masks(m, np.ones((16, 10), np.float32)),
                           lambda p: "convs" in p,
                           activation_volumes=vols)
    assert rep.activation_savings == 0.0
    m2 = np.ones((3, 3, 8, 16), np.float32)
    m2[:, :, :, :8] = 0.0         # filter pruning: half the outputs dead
    rep2 = hw.analyze_masks(_masks(m2, np.ones((16, 10), np.float32)),
                            lambda p: "convs" in p,
                            activation_volumes=vols)
    assert rep2.activation_savings == pytest.approx(0.5, abs=0.01)


def test_cnn_activation_volumes_geometry():
    cfg = get_cnn("vgg11")
    vols = hw.cnn_activation_volumes(cfg)
    assert vols["convs/0/w"] == 32 * 32 * 64
    assert vols["convs/1/w"] == 16 * 16 * 128     # after one pool


# ---------------- perf model ----------------
def _layers(xbars, positions):
    return [pm.LayerPerf(f"C{i}", p, x)
            for i, (x, p) in enumerate(zip(xbars, positions))]


def test_waterfill_equalizes_pipeline():
    layers = _layers([100, 100, 100], [1024.0, 256.0, 64.0])
    res = pm.waterfill(layers, budget=2000)
    times = [l.out_positions / r
             for l, r in zip(layers, res.replication)]
    # slowest layers get replicas; spread must shrink vs r=1
    assert max(times) < 1024.0
    assert res.cycles_per_image == pytest.approx(max(times) * 3.0)


def test_iso_area_speedup_increases_with_pruning():
    unpruned = _layers([400, 400, 400], [1024.0, 256.0, 64.0])
    half = _layers([200, 200, 200], [1024.0, 256.0, 64.0])
    tenth = _layers([40, 40, 40], [1024.0, 256.0, 64.0])
    s_half = pm.iso_area_speedup(unpruned, half, budget=1500)
    s_tenth = pm.iso_area_speedup(unpruned, tenth, budget=1500)
    assert s_half > 1.0
    assert s_tenth > s_half


def test_iso_perf_savings_match_xbar_reduction():
    unpruned = _layers([100, 200], [256.0, 64.0])
    pruned = _layers([25, 50], [256.0, 64.0])
    out = pm.iso_perf_xbars(unpruned, pruned, budget=1000)
    assert out["savings"] == pytest.approx(0.75, abs=0.02)


def test_resnet18_early_layers_dominate_time():
    """Fig 8: C1-C5 slowest despite few weights; C11+ hold most xbars."""
    cfg = get_cnn("resnet18")
    ones = {}
    from repro.core import crossbar as xb
    for i, spec in enumerate(cfg.convs):
        ic = cfg.in_channels if i == 0 else cfg.convs[i - 1].out_channels
        g = xb.grid_of((ic * 9, spec.out_channels))
        ones[f"convs/{i}/w"] = g.n_xbars
    layers = pm.conv_layer_perf(cfg, ones)
    times = [l.out_positions for l in layers]
    xbars = [l.xbars for l in layers]
    assert np.argmax(times) < 5                     # early layers slowest
    assert sum(xbars[10:]) / sum(xbars) > 0.6       # late layers hold xbars
