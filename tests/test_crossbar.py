"""Crossbar mapping + savings accounting (paper Figs 2-3 semantics)."""
import numpy as np
import pytest

from repro.core import crossbar as xb


def test_fig2_worst_case_no_savings():
    """75% sparsity, one nonzero per row/col → zero hardware savings."""
    m = np.zeros((4, 4), bool)
    m[0, 1] = m[1, 3] = m[2, 0] = m[3, 2] = True
    st = xb.xbar_stats(m, xr=4, xc=4)
    assert st.nonzero_cells == 4
    assert st.saved_cells == 0
    assert st.xbars_needed_packed == 1
    assert st.xbars_fully_free == 0


def test_fig2_128_worst_case():
    """128 nonzeros on the diagonal of a 128×128 crossbar: 99.2% sparse,
    zero savings (paper §III.B)."""
    m = np.eye(128, dtype=bool)
    st = xb.xbar_stats(m)
    assert st.nonzero_cells == 128
    assert st.saved_cells == 0
    assert st.xbars_needed_strict == 1


def test_column_and_row_savings():
    m = np.zeros((128, 128), bool)
    m[:, 5] = True          # one live column
    st = xb.xbar_stats(m)
    assert st.saved_cells == 128 * 127
    m2 = np.zeros((128, 128), bool)
    m2[7, :] = True         # one live row
    st2 = xb.xbar_stats(m2)
    assert st2.saved_cells == 127 * 128


def test_fully_free_crossbar():
    m = np.zeros((256, 128), bool)
    m[:128] = True
    st = xb.xbar_stats(m)
    assert st.n_xbars == 2
    assert st.xbars_fully_free == 1
    assert st.xbars_needed_strict == 1


def test_conv_unroll_roundtrip_and_layout():
    w = np.random.randn(3, 3, 8, 16)
    m = xb.conv_to_matrix(w)
    assert m.shape == (72, 16)
    np.testing.assert_array_equal(xb.matrix_to_conv(m, w.shape), w)
    # channel ic of filter oc = contiguous K² rows of column oc
    np.testing.assert_array_equal(m[9:18, 3], w[:, :, 1, 3].reshape(-1))
    # index (ic, kx, ky) = one row across filters
    np.testing.assert_array_equal(m[9 * 2 + 3 * 1 + 2, :], w[1, 2, 2, :])


def test_leaf_matrices_tags():
    conv = np.random.randn(3, 3, 4, 8)
    m, tag = xb.leaf_matrices(conv, conv=True)
    assert tag == "conv" and m.shape == (1, 36, 8)
    dense = np.random.randn(64, 32)
    m, tag = xb.leaf_matrices(dense)
    assert tag == "dense" and m.shape == (1, 64, 32)
    stacked = np.random.randn(5, 64, 32)
    m, tag = xb.leaf_matrices(stacked)
    assert tag == "stack" and m.shape == (5, 64, 32)
    back = xb.matrices_to_leaf(m, stacked.shape, tag)
    np.testing.assert_array_equal(back, stacked)


def test_merge_rejects_mismatched_geometry():
    """Stats from different crossbar geometries must not be summed —
    the packed count is recomputed under one geometry and would lie."""
    m = np.ones((256, 256), bool)
    a = xb.xbar_stats(m, xr=128, xc=128)
    b = xb.xbar_stats(m, xr=64, xc=64)
    with pytest.raises(ValueError, match="geometr"):
        a.merge(b)
    # same geometry still merges and re-packs
    c = xb.xbar_stats(m, xr=128, xc=128)
    a.merge(c)
    assert a.n_xbars == 8
    assert a.xbars_needed_packed == 8


def test_edge_crossbars_actual_extent():
    """Non-multiple dims: savings counted over actual extents only."""
    m = np.ones((130, 100), bool)
    st = xb.xbar_stats(m)
    assert st.total_cells == 130 * 100
    assert st.n_xbars == 2
    assert st.saved_cells == 0
    m[128:, :] = False          # kill the 2-row remainder crossbar
    st = xb.xbar_stats(m)
    assert st.xbars_fully_free == 1
    assert st.saved_cells == 2 * 100
