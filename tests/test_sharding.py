"""Sharding rules (pure PartitionSpec math + an 8-device subprocess run)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class FakeMesh:
    """Duck-typed mesh for pure spec tests (no devices)."""
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def rules(shape=None):
    from repro.distributed.sharding import ShardingRules
    mesh = FakeMesh(shape or {"data": 16, "model": 16})
    return ShardingRules(mesh)


def test_col_parallel_shards_last_dim():
    r = rules()
    spec = r.param_spec("segments/0/0/attn/wq", (8192, 8192))
    assert spec == __import__("jax").sharding.PartitionSpec(None, "model")


def test_row_parallel_shards_first_matrix_dim():
    r = rules()
    spec = r.param_spec("segments/0/0/attn/wo", (64, 8192, 8192))
    # stacked (reps, in, out): row-parallel on in
    assert tuple(spec) == (None, "model", None)


def test_moe_experts_sharded():
    r = rules()
    spec = r.param_spec("segments/1/0/moe/up", (58, 256, 7168, 2048))
    assert tuple(spec) == (None, "model", None, None)


def test_vocab_parallel_embed():
    r = rules()
    spec = r.param_spec("embed/table", (131072, 4096))
    assert tuple(spec) == ("model", None)


def test_indivisible_falls_back():
    r = rules()
    # 10 heads × 256 = 2560 — divisible; but a 10-dim leaf is not
    spec = r.param_spec("segments/0/0/attn/wq", (2560, 10))
    assert tuple(spec) == ("model", None)   # falls back to in-dim
    spec = r.param_spec("x/unknown", (6, 10))
    assert tuple(spec) == (None, None)


def test_norms_replicated():
    r = rules()
    assert tuple(r.param_spec("norm1/scale", (8192,))) == (None,)


def test_batch_spec_dp_axes():
    r = rules({"pod": 2, "data": 16, "model": 16})
    spec = r.batch_spec((256, 4096))
    assert tuple(spec) == (("pod", "data"), None)
    # batch=1 (long_500k): unshardable → replicated
    assert tuple(r.batch_spec((1, 4096))) == (None, None)


def _norm(spec):
    out = []
    for s in tuple(spec):
        out.append(s[0] if isinstance(s, tuple) and len(s) == 1 else s)
    return tuple(out)


def test_cache_spec_prefers_heads_then_seq():
    r = rules()
    # (B, C, Hkv, hd): heads=32 divisible → heads sharded
    spec = r.cache_spec("c", (128, 32768, 32, 128))
    assert _norm(spec) == ("data", None, "model", None)
    # kv=8 heads < 16: falls to the sequence dim (SP decode)
    spec = r.cache_spec("c", (128, 32768, 8, 128))
    assert _norm(spec) == ("data", "model", None, None)


SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch, scaled_down
    from repro.distributed.sharding import ShardingRules, install
    from repro.models import transformer as tfm

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    install(rules)
    cfg = scaled_down(get_arch("yi-6b"), dtype="float32", d_model=128,
                      n_heads=4, n_kv_heads=4, head_dim=32)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    shardings = rules.params_shardings(params)
    params = jax.device_put(params, shardings)
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.ones((8, 32), jnp.int32)}
    batch = jax.device_put(batch, rules.batch_shardings(batch))
    with mesh:
        loss, _ = jax.jit(lambda p, b: tfm.loss_fn(p, cfg, b))(params, batch)
    # compare against single-device value
    install(None)
    params_local = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), params)
    batch_local = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)), batch)
    loss2, _ = tfm.loss_fn(params_local, cfg, batch_local)
    assert abs(float(loss) - float(loss2)) < 1e-3, (float(loss), float(loss2))
    print("SHARDED_OK", float(loss))
""")


def test_sharded_loss_matches_single_device():
    """Real 8-device (host platform) run in a subprocess: the sharded
    jitted loss equals the unsharded value."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
