"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated at a REDUCED config of the
same family and runs one forward + one train step + one prefill→decode
step on CPU, asserting output shapes and no NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).

``test_make_adapter_session_smoke`` additionally drives EVERY
registered name — archs AND CNNs — through a one-round scaled-down
``PruningSession`` via the family registry (``repro.api.
make_adapter``), the acceptance bar for "one tool that prunes anything
registered".
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, list_cnns, scaled_down
from repro.models import encdec
from repro.models import transformer as tfm
from repro.optim import adamw, constant

B, S = 2, 32
# the heaviest reduced configs (deep scans / MoE / enc-dec) go to CI's
# slow job; two fast representatives stay in the default tier-1 run
_HEAVY = {"deepseek-v3-671b", "whisper-tiny", "recurrentgemma-2b",
          "llama4-maverick-400b-a17b", "command-r-35b", "phi-3-vision-4.2b",
          "qwen2-72b", "xlstm-125m"}
_ALL = list_archs()
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
         for a in _ALL]

# session smoke covers CNNs too; one CNN + one LM stay in tier-1, the
# rest (every remaining family) go to the slow job
_SESSION_FAST = {"llama3.2-3b", "vgg11"}
_ALL_NAMES = list(list_archs()) + list(list_cnns())
ADAPTABLE = [a if a in _SESSION_FAST
             else pytest.param(a, marks=pytest.mark.slow)
             for a in _ALL_NAMES]


def _batch(cfg, rng):
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(
                rng, (B, cfg.encoder_seq_len, cfg.d_model)),
            "tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.num_patch_tokens:
        b["patches"] = jax.random.normal(
            rng, (B, cfg.num_patch_tokens, cfg.d_model))
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = scaled_down(get_arch(arch), dtype="float32")
    mod = encdec if cfg.is_encoder_decoder else tfm
    params = mod.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    logits, _aux = mod.forward(params, cfg, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)

    def lf(p):
        loss, _ = mod.loss_fn(p, cfg, batch)
        return loss

    loss0, grads = jax.value_and_grad(lf)(params)
    params2, _ = opt.update(grads, opt_state, params)
    loss1 = lf(params2)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    # one step on the same batch must reduce loss (sanity of the update)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch, rng):
    cfg = scaled_down(get_arch(arch), dtype="float32")
    mod = encdec if cfg.is_encoder_decoder else tfm
    params = mod.init_params(rng, cfg)
    batch = {k: v for k, v in _batch(cfg, rng).items() if k != "labels"}
    logits, caches = mod.prefill(params, cfg, batch, capacity=S + 8)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, caches = mod.decode_step(params, cfg, caches, tok)
    logits3, caches = mod.decode_step(params, cfg, caches, tok)
    for lg in (logits, logits2, logits3):
        assert not np.any(np.isnan(np.asarray(lg, np.float32)))


@pytest.mark.parametrize("arch", ADAPTABLE)
def test_make_adapter_session_smoke(arch):
    """Every registered name completes a one-round scaled-down
    PruningSession through the family registry."""
    from repro.api import PruningSession, make_adapter
    from repro.configs import PruneConfig

    adapter = make_adapter(arch, scale="tiny")
    session = PruningSession(
        adapter, PruneConfig(prune_fraction=0.25, max_iters=1,
                             accuracy_tolerance=1e9))
    res = session.run()
    assert len(res.history) == 1
    assert res.history[0].accepted
    assert 0.1 < res.sparsity < 0.5
    # the family schedule came from the registry (MoE leads with
    # whole-expert pruning, everything else with the paper's 'filter')
    cfg = adapter.cfg
    expected_first = "expert" if cfg.family == "moe" else "filter"
    assert res.history[0].granularity == expected_first
    assert np.isfinite(res.history[0].accuracy)


def _mask_leaves(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _mask_leaves(v, f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _mask_leaves(v, f"{prefix}/{i}")
    elif tree is not None:
        yield prefix, np.asarray(tree)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b",
                                  "deepseek-v3-671b"])
def test_moe_session_prunes_whole_experts(arch):
    """MoE archs lead with the 'expert' granularity: after one accepted
    round, some expert slices of the stacked (E, d, d_ff) tensors are
    entirely dead while non-expert leaves are untouched."""
    from repro.api import PruningSession, make_adapter
    from repro.configs import PruneConfig

    adapter = make_adapter(arch, scale="tiny")
    assert adapter.granularities[0] == "expert"
    res = PruningSession(
        adapter, PruneConfig(prune_fraction=0.25, max_iters=1,
                             accuracy_tolerance=1e9)).run()
    assert res.history[0].granularity == "expert"
    expert_leaves = [(p, m) for p, m in _mask_leaves(res.masks)
                     if "/moe/" in p and m.ndim >= 3]
    assert expert_leaves, "scaled MoE config must have expert masks"
    dead_sliced = pruned_elsewhere = 0
    for p, m in expert_leaves:
        slices = m.reshape(-1, m.shape[-2] * m.shape[-1])
        dead_sliced += int((slices.sum(axis=1) == 0).sum())
    for p, m in _mask_leaves(res.masks):
        if "/moe/" not in p:
            pruned_elsewhere += int(m.size - m.sum())
    assert dead_sliced > 0            # whole experts turned off
    assert pruned_elsewhere == 0      # expert granularity touches only MoE


def test_all_ten_assigned_archs_present():
    expected = {
        "recurrentgemma-2b", "phi-3-vision-4.2b", "yi-6b", "command-r-35b",
        "llama3.2-3b", "qwen2-72b", "deepseek-v3-671b",
        "llama4-maverick-400b-a17b", "whisper-tiny", "xlstm-125m",
    }
    assert expected.issubset(set(_ALL))
