"""Checkpointing: atomicity, resume, retention, async, resharding API."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def tree(v=1.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32),
            "none_leaf": None}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, tree(2.5))
    step, got = mgr.restore(tree(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.full((4, 4), 2.5))
    assert got["none_leaf"] is None


def test_latest_wins_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree(float(s)))
    assert mgr.latest_step() == 4
    # keep=2 → steps 1,2 garbage-collected
    assert not os.path.isdir(mgr._step_dir(1))
    assert not os.path.isdir(mgr._step_dir(2))
    step, got = mgr.restore(tree())
    assert float(np.asarray(got["params"]["w"])[0, 0]) == 4.0


def test_uncommitted_checkpoint_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, tree(5.0))
    # simulate a torn write: dir exists but no COMMITTED marker
    save_pytree(tree(9.0), mgr._step_dir(9))
    assert mgr.latest_step() == 5        # step 9 ignored


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(3, tree(3.0))
    mgr.wait()
    assert mgr.latest_step() == 3


def test_restore_empty_dir_returns_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, got = mgr.restore(tree(1.5))
    assert step is None
    assert float(np.asarray(got["params"]["w"])[0, 0]) == 1.5


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10)
    mgr.save(1, tree(1.0))
    mgr.save(2, tree(2.0))
    step, got = mgr.restore(tree(), step=1)
    assert step == 1
    assert float(np.asarray(got["params"]["w"])[0, 0]) == 1.0


def test_save_pytree_load_pytree_direct(tmp_path):
    d = str(tmp_path / "direct")
    save_pytree(tree(4.0), d)
    got = load_pytree(d, tree(0.0))
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.full((4, 4), 4.0))
