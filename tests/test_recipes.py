"""Declarative PruneRecipe API: serialization round-trips, the session
recipe interpreter (mid-stage resume, stage budgets, quantize/ablate
stages), legacy ``granularities=`` shim equivalence, and ticket
metadata embedding."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (FunctionAdapter, PruningSession, Recipe, Stage,
                       ablate_stage, available_recipes, from_granularities,
                       get_recipe, prune_stage, quantize_stage,
                       resolve_recipe)
from repro.configs import PruneConfig
from repro.core import lottery
from repro.core.masks import sparsity_fraction
from repro.core.quantize import fake_quantize, fake_quantize_tree


def _params(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(3, 3, 4, 8), jnp.float32),
            "b": jnp.asarray(r.randn(256, 128), jnp.float32)}


def _scripted_adapter(params, cliff=0.45):
    """Deterministic adapter: accuracy collapses past ``cliff`` sparsity."""
    return FunctionAdapter(
        params=params,
        train_fn=lambda p, m: p,
        eval_fn=lambda p, m: 1.0 if sparsity_fraction(m) < cliff else 0.5,
        prunable=lambda p, l: l.ndim >= 2,
        conv_pred=lambda p: p == "a")


def _hist_tuple(history):
    return [(e.iteration, e.stage_idx, e.stage, e.kind, e.granularity,
             e.accepted, round(e.sparsity_after, 9)) for e in history]


# ---------------------------------------------------------------------------
# Stage / Recipe construction + serialization
# ---------------------------------------------------------------------------
def test_stage_validation():
    with pytest.raises(ValueError):
        Stage(kind="nope")
    with pytest.raises(ValueError):
        prune_stage(None)                      # granularity required
    with pytest.raises(KeyError):
        prune_stage("not-a-granularity")
    with pytest.raises(ValueError):
        prune_stage("filter", rate=1.5)
    with pytest.raises(ValueError):
        quantize_stage(7)                      # only 8/16 fixed point
    with pytest.raises(KeyError):
        ablate_stage(["filter", "bogus"])
    with pytest.raises(ValueError):
        Recipe(name="empty", stages=())


def test_stage_default_names_and_ablate_default_sweep():
    assert prune_stage("filter").name == "prune:filter"
    assert quantize_stage(16).name == "quantize:int16"
    ab = ablate_stage()
    assert ab.granularities[0] == "xbar"       # coarsest first
    assert set(("filter", "channel", "index")) <= set(ab.granularities)


def test_recipe_dict_json_roundtrip(tmp_path):
    for name in available_recipes():
        r = get_recipe(name)
        assert Recipe.from_dict(r.to_dict()) == r
        assert Recipe.from_json(r.to_json()) == r
    r = get_recipe("paper-quant")
    path = str(tmp_path / "r.json")
    r.save(path)
    assert Recipe.load(path) == r
    assert resolve_recipe(path) == r
    assert resolve_recipe(r.to_dict()) == r
    assert resolve_recipe("paper-quant") is r


def test_resolve_recipe_errors(tmp_path):
    with pytest.raises(KeyError):
        resolve_recipe("never-registered")
    with pytest.raises(FileNotFoundError):
        resolve_recipe(str(tmp_path / "missing.json"))
    with pytest.raises(TypeError):
        resolve_recipe(42)


def test_loaded_recipe_runs_identically(tmp_path):
    """Serialize → load → the loaded recipe reproduces the original
    run history exactly."""
    params = _params()
    rec = Recipe(name="rt", stages=(
        prune_stage("filter", rate=0.25),
        prune_stage("index", rate=0.2, max_rounds=2)))
    path = str(tmp_path / "rt.json")
    rec.save(path)
    cfg = PruneConfig(max_iters=10)
    h1 = PruningSession(_scripted_adapter(params), cfg, recipe=rec,
                        baseline_accuracy=1.0).run().history
    h2 = PruningSession(_scripted_adapter(params), cfg, recipe=path,
                        baseline_accuracy=1.0).run().history
    assert _hist_tuple(h1) == _hist_tuple(h2)


# ---------------------------------------------------------------------------
# Legacy shim equivalence
# ---------------------------------------------------------------------------
def test_granularities_shim_compiles_to_equivalent_recipe():
    """``granularities=`` and the compiled single-stage-per-granularity
    recipe run the exact same program (and inherit
    ``cfg.prune_fraction`` as the stage rate)."""
    params = _params()
    cfg = PruneConfig(prune_fraction=0.2, max_iters=20)
    legacy = PruningSession(_scripted_adapter(params), cfg,
                            granularities=["filter", "channel", "index"],
                            baseline_accuracy=1.0)
    assert [s.rate for s in legacy.recipe.stages] == [0.2] * 3
    h1 = legacy.run().history
    h2 = PruningSession(
        _scripted_adapter(params), cfg,
        recipe=from_granularities(["filter", "channel", "index"],
                                  rate=0.2),
        baseline_accuracy=1.0).run().history
    assert _hist_tuple(h1) == _hist_tuple(h2)


def test_config_recipe_field_resolves():
    params = _params()
    sess = PruningSession(_scripted_adapter(params),
                          PruneConfig(max_iters=2, recipe="paper-xbar"),
                          baseline_accuracy=1.0)
    assert sess.recipe.name == "paper-xbar"
    # explicit granularities still win over cfg.recipe
    sess2 = PruningSession(_scripted_adapter(params),
                           PruneConfig(max_iters=2, recipe="paper-xbar"),
                           granularities=["index"],
                           baseline_accuracy=1.0)
    assert sess2.recipe.prune_granularities == ("index",)
    # cfg.recipe (caller intent) outranks the family registry's
    # schedule data on the adapter
    adapter = _scripted_adapter(params)
    adapter.granularities = ("expert", "filter")     # registry default
    sess3 = PruningSession(adapter,
                           PruneConfig(max_iters=2, recipe="paper-quant"),
                           baseline_accuracy=1.0)
    assert sess3.recipe.name == "paper-quant"


# ---------------------------------------------------------------------------
# Interpreter semantics: budgets, quantize, ablate
# ---------------------------------------------------------------------------
def test_stage_max_rounds_and_target_sparsity():
    params = _params()
    res = PruningSession(
        _scripted_adapter(params, cliff=2.0),         # accept everything
        PruneConfig(max_iters=20),
        recipe=Recipe(name="b", stages=(
            prune_stage("filter", rate=0.25, max_rounds=2),
            prune_stage("index", rate=0.25, target_sparsity=0.5))),
        baseline_accuracy=1.0).run()
    by_stage = {}
    for e in res.history:
        by_stage.setdefault(e.stage_idx, []).append(e)
    assert len(by_stage[0]) == 2                      # max_rounds honoured
    assert by_stage[1][-1].sparsity_after >= 0.5      # target reached
    assert res.history[-1].sparsity_after == pytest.approx(res.sparsity)


def test_global_prune_budget_skips_prune_not_quantize():
    """cfg.max_iters caps prune rounds; a trailing quantize stage still
    runs after the budget is spent."""
    params = _params()
    res = PruningSession(
        _scripted_adapter(params, cliff=2.0),
        PruneConfig(max_iters=2),
        recipe=Recipe(name="q", stages=(
            prune_stage("filter"), prune_stage("index"),
            quantize_stage(8))),
        baseline_accuracy=1.0).run()
    kinds = [e.kind for e in res.history]
    assert kinds.count("prune") == 2
    assert kinds[-1] == "quantize"


def test_quantize_stage_gates_and_records_bits():
    params = _params()
    sess = PruningSession(
        _scripted_adapter(params, cliff=2.0),
        PruneConfig(max_iters=1),
        recipe=Recipe(name="q8", stages=(prune_stage("filter"),
                                         quantize_stage(8))),
        baseline_accuracy=1.0)
    res = sess.run()
    q = [e for e in res.history if e.kind == "quantize"]
    assert len(q) == 1 and q[0].accepted and q[0].granularity == "int8"
    assert sess.quantize_bits == 8
    # a rejected quantize stage records nothing
    sess2 = PruningSession(
        FunctionAdapter(params=params, train_fn=lambda p, m: p,
                        eval_fn=lambda p, m: 0.0,   # always fails the gate
                        prunable=lambda p, l: True,
                        conv_pred=lambda p: False),
        PruneConfig(max_iters=0),
        recipe=Recipe(name="q", stages=(quantize_stage(8),)),
        baseline_accuracy=1.0)
    sess2.run()
    assert sess2.quantize_bits is None


def test_ablate_stage_commits_nothing_and_reports_table():
    params = _params()
    res = PruningSession(_scripted_adapter(params),
                         PruneConfig(max_iters=20), recipe="ablation",
                         baseline_accuracy=1.0).run()
    assert res.sparsity == 0.0                        # nothing committed
    rows = res.ablation
    assert [e.granularity for e in rows] == \
        ["xbar", "filter", "channel", "index"]
    assert all(e.kind == "ablate" and not e.accepted for e in rows)
    assert all(e.sparsity_after > 0 for e in rows)    # each was scored


# ---------------------------------------------------------------------------
# Mid-stage resume
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("preempt_at", [2, 4])
def test_resume_mid_stage_equals_uninterrupted(tmp_path, preempt_at):
    params = _params()
    cfg = PruneConfig(max_iters=20)
    rec = Recipe(name="multi", stages=(
        prune_stage("filter", rate=0.25),
        prune_stage("index", rate=0.25, max_rounds=2),
        quantize_stage(8),
        ablate_stage(["xbar", "filter"])))
    full = PruningSession(_scripted_adapter(params), cfg, recipe=rec,
                          baseline_accuracy=1.0).run()

    class Preempted(Exception):
        pass

    def preempt(event):
        if event.iteration == preempt_at:
            raise Preempted()

    ckpt = str(tmp_path / f"ck{preempt_at}")
    with pytest.raises(Preempted):
        PruningSession(_scripted_adapter(params), cfg, recipe=rec,
                       baseline_accuracy=1.0, ckpt_dir=ckpt,
                       callbacks=[preempt]).run()
    resumed_sess = PruningSession(_scripted_adapter(params), cfg,
                                  recipe=rec, baseline_accuracy=1.0,
                                  ckpt_dir=ckpt)
    resumed = resumed_sess.run()
    assert _hist_tuple(resumed.history) == _hist_tuple(full.history)
    for x, y in zip(jax.tree.leaves(full.masks),
                    jax.tree.leaves(resumed.masks)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert resumed_sess.quantize_bits == 8            # re-derived
    assert resumed.recipe == rec.to_dict()


def test_resume_refuses_pre_recipe_checkpoint_layout(tmp_path):
    """A checkpoint from the pre-recipe session (no fmt marker) must be
    refused loudly — missing template keys restore as zeros, so without
    the marker the session would silently re-prune pruned masks."""
    from repro.checkpoint import CheckpointManager
    from repro.core.masks import make_masks

    params = _params()
    adapter = _scripted_adapter(params)
    masks = make_masks(params, adapter.prunable)
    # old layout: masks/g_idx/baseline/hist, no fmt/state/recipe
    CheckpointManager(str(tmp_path), async_save=False).save(3, {
        "masks": masks,
        "g_idx": np.asarray(1, np.int32),
        "baseline": np.asarray(0.9, np.float64),
        "hist": np.zeros((2, 6), np.float64)}, blocking=True)
    sess = PruningSession(adapter, PruneConfig(max_iters=2),
                          baseline_accuracy=1.0, ckpt_dir=str(tmp_path))
    with pytest.raises(ValueError, match="older"):
        sess.run()


def test_resume_under_different_recipe_refuses(tmp_path):
    params = _params()
    cfg = PruneConfig(max_iters=20)

    class Preempted(Exception):
        pass

    def preempt(event):
        raise Preempted()

    with pytest.raises(Preempted):
        PruningSession(_scripted_adapter(params), cfg, recipe="paper",
                       baseline_accuracy=1.0, ckpt_dir=str(tmp_path),
                       callbacks=[preempt]).run()
    with pytest.raises(ValueError, match="different program"):
        PruningSession(_scripted_adapter(params), cfg,
                       recipe="paper-xbar", baseline_accuracy=1.0,
                       ckpt_dir=str(tmp_path)).run()


# ---------------------------------------------------------------------------
# QAT machinery + ticket embedding
# ---------------------------------------------------------------------------
def test_fake_quantize_straight_through_gradient():
    w = jnp.asarray(np.random.RandomState(0).randn(16, 8), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(fake_quantize(x, 8) ** 2))(w)
    # STE: d/dw sum(q(w)^2) == 2*q(w) with identity pass-through
    np.testing.assert_allclose(np.asarray(g),
                               2 * np.asarray(fake_quantize(w, 8)),
                               rtol=1e-6)
    # masked zeros survive the fake pass exactly
    wm = w.at[:, 0].set(0.0)
    assert (np.asarray(fake_quantize(wm, 8))[:, 0] == 0).all()


def test_fake_quantize_tree_skips_1d_leaves():
    tree = {"w": jnp.ones((8, 4)), "gain": jnp.ones((4,))}
    out = fake_quantize_tree(tree, lambda p, l: True, 8)
    np.testing.assert_array_equal(np.asarray(out["gain"]), np.ones((4,)))
    assert out["w"].shape == (8, 4)


def test_ticket_embeds_recipe_and_roundtrips(tmp_path):
    params = _params()
    rec = Recipe(name="emb", stages=(prune_stage("filter"),
                                     quantize_stage(8)))
    sess = PruningSession(_scripted_adapter(params, cliff=2.0),
                          PruneConfig(max_iters=1), recipe=rec,
                          baseline_accuracy=1.0)
    res = sess.run()
    tdir = str(tmp_path / "ticket")
    sess.export_ticket(tdir)
    meta = lottery.ticket_meta(tdir)
    assert meta["quantize_bits"] == 8
    assert meta["sparsity"] == pytest.approx(res.sparsity)
    # the embedded recipe reconstructs the exact program
    assert Recipe.from_dict(meta["recipe"]) == rec
    # ...and the ticket payload still round-trips
    w, m = lottery.import_ticket(tdir, params, res.masks)
    for a, b in zip(jax.tree.leaves(m), jax.tree.leaves(res.masks)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pre_metadata_tickets_read_as_empty_meta(tmp_path):
    lottery.export_ticket(str(tmp_path), _params(),
                          {"b": jnp.ones((256, 128))})
    # overwrite ticket.json with the old (meta-less) layout
    with open(str(tmp_path / "ticket.json"), "w") as f:
        json.dump({"treedef": "x"}, f)
    assert lottery.ticket_meta(str(tmp_path)) == {}


def test_hwreport_weight_bytes_compose():
    from repro.core.hardware import analyze_masks

    masks = {"b": jnp.asarray(
        (np.random.RandomState(0).rand(256, 128) > 0.5), jnp.float32)}
    rep = analyze_masks(masks, lambda p: False, quant_bits=8)
    b = rep.weight_bytes()
    live = int(np.asarray(masks["b"]).sum())
    assert b["dense_bytes"] == 256 * 128 * 4
    assert b["pruned_bytes"] == live * 4
    # int8 applies to the SAME live cells (plus per-live-column scales):
    # pruning and quantization compose instead of double-counting
    assert b["quantized_bytes"] < b["pruned_bytes"]
    assert b["quantized_bytes"] >= live  # at least 1 byte per live cell
    assert rep.weight_bytes(bits=None) is not None


def test_events_serialize_losslessly():
    """PruneEvent → dict → PruneEvent (the checkpoint history codec)."""
    from repro.core.algorithm import PruneEvent

    e = PruneEvent(3, "filter", 0.1, 0.2, 0.9, True,
                   stage="prune:filter", stage_idx=1, kind="prune")
    assert PruneEvent(**dataclasses.asdict(e)) == e
