"""launch.hlo_analysis: shape parsing, collective summation, roofline.

The parser feeds both the launch-time roofline report and the lint's
compiled-artifact cross-check (J206/J207), so its corner cases —
tuple shapes, unknown dtypes, sub-byte s4/u4 — get pinned here.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                       RooflineTerms, _shape_bytes,
                                       collective_bytes, hlo_dtype_census,
                                       roofline_from_compiled,
                                       while_trip_counts)


# ---------------------------------------------------------------------------
# _shape_bytes
# ---------------------------------------------------------------------------
def test_shape_bytes_basic():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[8,128,4096]{2,1,0}") == 8 * 128 * 4096 * 2
    assert _shape_bytes("f32[]") == 4          # scalar: empty dims


def test_shape_bytes_tuple_shapes_sum_parts():
    # tuple-result ops list every component; all parseable parts count
    assert _shape_bytes("(f32[2,3], s32[4])") == 24 + 16
    assert _shape_bytes("(bf16[2], pred[3], u8[5])") == 4 + 3 + 5


def test_shape_bytes_unknown_dtype_skipped():
    assert _shape_bytes("opaque[8]") == 0
    assert _shape_bytes("token[]") == 0
    # unknown part skipped, known part still counted
    assert _shape_bytes("(opaque[8], f16[4])") == 8


def test_shape_bytes_subbyte_s4_u4():
    # s4/u4 are billed at 1 byte per element (packing is backend detail)
    assert _shape_bytes("s4[16]") == 16
    assert _shape_bytes("u4[3,3]") == 9


# ---------------------------------------------------------------------------
# collective_bytes
# ---------------------------------------------------------------------------
_HLO = """\
ENTRY %main {
  %x = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = f32[128]{0} all-reduce-start(%y), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %noise = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
}
"""


def test_collective_bytes_sums_by_kind():
    stats = collective_bytes(_HLO)
    assert stats.bytes_by_kind["all-gather"] == 16 * 4096 * 2
    assert stats.bytes_by_kind["all-reduce"] == 128 * 4      # -start form
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 4
    assert stats.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                   "reduce-scatter": 1}
    assert stats.total_bytes == 16 * 4096 * 2 + 128 * 4 + 64 * 4


def test_collective_bytes_ignores_plain_ops():
    assert collective_bytes("%r = f32[8]{0} add(%a, %b)").total_bytes == 0


def test_while_trip_counts():
    text = 'while(...), backend_config={"trip_count":"12"}\n' \
           "trip_count=3\n"
    assert sorted(while_trip_counts(text)) == [3, 12]
    assert while_trip_counts("no loops") == []


# ---------------------------------------------------------------------------
# hlo_dtype_census
# ---------------------------------------------------------------------------
def test_hlo_dtype_census_counts_known_dtypes():
    census = hlo_dtype_census(_HLO)
    assert census["bf16"] == 2
    assert census["f32"] >= 4
    assert "opaque" not in census
    assert hlo_dtype_census("no shapes here") == {}


# ---------------------------------------------------------------------------
# RooflineTerms + roofline_from_compiled
# ---------------------------------------------------------------------------
def test_roofline_terms_math():
    t = RooflineTerms(flops=PEAK_FLOPS, bytes_accessed=HBM_BW * 2,
                      collective_b=ICI_BW * 0.5, n_chips=4,
                      model_flops=PEAK_FLOPS)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.bottleneck == "memory"
    assert t.step_time_s == pytest.approx(2.0)
    assert t.useful_flops_ratio == pytest.approx(0.25)
    assert t.mfu == pytest.approx(1.0 / (2.0 * 4))
    d = t.as_dict()
    assert d["bottleneck"] == "memory" and d["n_chips"] == 4


def test_roofline_from_compiled_tiny_matmul():
    n = 64

    @jax.jit
    def f(x):
        return x @ x

    compiled = f.lower(jnp.ones((n, n), jnp.float32)).compile()
    terms = roofline_from_compiled(compiled, n_chips=1,
                                   model_flops=2 * n ** 3)
    assert terms.flops > 0
    assert terms.bytes_accessed > 0
    assert terms.collective_b == 0            # single device, no ICI
    assert terms.step_time_s > 0
    assert terms.bottleneck in ("compute", "memory", "collective")
    # explicit hlo_text path agrees with the compiled.as_text() default
    again = roofline_from_compiled(compiled, n_chips=1,
                                   hlo_text=compiled.as_text())
    assert again.collective_b == terms.collective_b
